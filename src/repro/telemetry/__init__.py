"""Shared observability core: one metrics/tracing tier for serving AND
training.

PR 7 built the serving observability stack (``serve/metrics.py`` /
``serve/tracing.py``); this package is that code promoted to a shared home
so the Trainer rides the same registry, the same snapshot schema
(:func:`~repro.telemetry.metrics.validate_snapshot`, checked in CI against
both serving and training artifacts), the same Prometheus exporter and the
same JSONL sinks.  ``repro.serve.metrics`` / ``repro.serve.tracing`` remain
as re-export shims, so nothing serving-side changed.

Layout
------
``metrics``   Counter / Gauge / fixed-bucket Histogram, MetricsRegistry
              (snapshot + Prometheus text), validate_snapshot, clocks.
``tracing``   annotate (profiler spans), maybe_profile (REPRO_PROFILE_DIR
              capture), JsonlSink/ListSink, RequestTracer (serving
              lifecycle), TrainTracer (training lifecycle).
``probes``    On-device QAT health probes: an ambient collector that
              forward-pass tap sites record into, scan-boundary helpers,
              the param-side probe computations and the cadenced
              democratization snapshot.

Metric name registry
--------------------
One namespace across the codebase — names are stable, CI artifacts and
dashboards key on them.  Prometheus-safe (``[a-zA-Z_][a-zA-Z0-9_]*``).

Serving (wired by the engines / scheduler / kv_pool — see PR 7/9):
  ``requests_submitted_total`` / ``requests_finished_total{reason=...}``
  ``tokens_generated_total``, ``prefill_chunks_total``, ``decode_chunks_total``
  ``queue_depth``, ``batch_occupancy``, ``pool_blocks_used``
  ``ttft_seconds``, ``itl_seconds``, ``request_latency_seconds``
  ``prefix_cache_hits_total`` / ``prefix_cache_misses_total`` /
  ``prefix_cache_hit_tokens_total`` / ``prefix_cache_cow_total`` /
  ``prefix_cache_evictions_total``

Training (wired by ``repro.train.trainer.Trainer``):
  counters   ``train_steps_total``, ``train_recoveries_total``,
             ``train_restores_total``, ``train_checkpoints_total``
  gauges     ``train_loss``, ``train_nll``, ``train_lr``, ``train_wd``,
             ``train_grad_norm``, ``train_step`` (latest step id)
  histogram  ``train_step_seconds``

QAT health probes (join the per-step ``metrics`` dict when
``TrainerConfig.probes`` is on; all computed ON DEVICE inside
``train_step`` — no extra host syncs):
  ``qat_flip_attn`` / ``qat_flip_ffn1`` / ``qat_flip_ffn8`` /
  ``qat_flip_embed``        latent-weight sign-flip rate vs the previous
                            step, per layer family (centered sign,
                            matching the AbsMean binarizer)
  ``qat_clip_w8``           INT8-branch weight saturation rate (|q|=127)
  ``qat_clip_act``          INT8 activation saturation rate across every
                            act-quant site in the forward
  ``qat_scale_drift_absmean`` / ``qat_scale_drift_absmax``
                            relative per-step drift of the 1-bit AbsMean
                            scales (lambda) / 8-bit AbsMax scales
  ``qat_branch_share8``     fraction of decoupled-layer output norm
                            carried by the 8-bit branch (alpha*y8) vs the
                            1-bit trunk (beta*y1) — the paper's
                            allocation claim, live
  ``qat_gnorm_ffn8`` / ``qat_gnorm_ffn1`` / ``qat_gnorm_share8``
                            per-branch gradient-norm split
  ``qat_router_entropy``    routed-expert load entropy (1.0 = perfectly
                            balanced top-1 routing, 0.0 = collapsed)

Cadenced democratization snapshot (host-side, every
``TrainerConfig.sensitivity_every`` steps, off the jit path; reuses
``core/sensitivity``): ``demo_score_<fam>``, ``demo_kurtosis_<fam>``,
``demo_top1pct_<fam>`` for ``fam`` in attn / ffn1 / ffn8.

Reserved (wired by upcoming PRs — see ROADMAP):
  ``spec_tokens_proposed_total`` / ``spec_tokens_accepted_total``
  (self-speculative decoding acceptance accounting).

Reading a train trace
---------------------
``TrainerConfig.trace_path`` streams the run lifecycle as JSONL (one
compact object per line, flushed per event — a crash leaves a replayable
prefix).  Events, all carrying ``{"t": run-relative seconds,
"event": ..., "step": ...}``:

  ``run_start``    config digest: arch name, quant mode, total steps
  ``step``         per-step record: loss/nll/lr/grad_norm + every qat_*
                   probe — the JSONL twin of the history record
  ``sensitivity``  cadenced democratization snapshot (demo_* keys)
  ``checkpoint``   async checkpoint save issued at ``step``
  ``restore``      state restored from ``from_step`` (startup resume)
  ``recovery``     auto-recovery: non-finite loss at ``step``, rolled
                   back to ``from_step``; ``recoveries`` = running count
  ``heartbeat``    liveness mark at ``log_every`` cadence
  ``run_end``      final step + total recoveries

A minimal reader::

    import json
    events = [json.loads(l) for l in open("train_trace.jsonl")]
    steps = [e for e in events if e["event"] == "step"]
    flips = [e.get("qat_flip_ffn1") for e in steps]

Healthy pQuant runs show ``qat_flip_*`` decaying toward 0 as latents
settle, ``qat_branch_share8`` well above 0 (the 8-bit branch is carrying
signal — democratization is being broken), and ``qat_clip_act`` low;
spikes in ``qat_scale_drift_*`` precede the loss spikes that trigger
``recovery`` events (paper Fig. 10).

The invariant that makes all of this free: with telemetry disabled
(``probes=False``, no tracer/registry attached), ``train_step`` lowers to
a byte-identical program — pinned by ``tests/test_train_telemetry.py``,
exactly like the serving-side pin in ``tests/test_metrics.py``.
"""

from repro.telemetry.metrics import (  # noqa: F401
    DEFAULT_TIME_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    ManualClock,
    MetricsRegistry,
    MonotonicClock,
    resolve_clock,
    validate_snapshot,
)
from repro.telemetry.tracing import (  # noqa: F401
    PROFILE_DIR_ENV,
    JsonlSink,
    ListSink,
    RequestTracer,
    TrainTracer,
    annotate,
    fault_hook,
    maybe_profile,
)
