"""Tracing and profiling hooks shared by the serving stack and the Trainer.

(Originally ``repro.serve.tracing``, PR 7; promoted here so training and
serving trace through one core.  The serving module re-exports.)

Four layers, all zero-overhead when disabled:

1. **Request lifecycle tracing** — :class:`RequestTracer` turns every
   request's life into an ordered span record::

       submitted -> admitted -> prefill_chunk* -> first_token ->
       decode_chunk* -> finished(reason)

   plus block-alloc/free events, preemptions, fired faults and the
   prefix-cache lifecycle (``prefix_hit`` when an admission walk reuses
   cached blocks — with ``n_blocks``/``n_tokens`` — and ``block_cow``
   when a fully-cached prompt copies its final shared page before
   diverging), each a flat JSON-serialisable dict ``{"t": ...,
   "event": ..., "uid": ..., **fields}`` pushed through a pluggable sink (:class:`JsonlSink` for
   structured JSONL on disk, :class:`ListSink` for in-memory assertions).
   Timestamps come from the ENGINE's clock — the same ``now()`` that
   drives deadline math and the latency histograms — so a chaos failure
   or a ``SchedulerStall`` ships a replayable timeline on one timebase
   instead of a bare exception.  ``tracer=None`` (the default) skips
   every emit site behind one ``is not None`` check.

2. **Profiler annotations** — :func:`annotate` is a context manager
   combining ``jax.profiler.TraceAnnotation`` (host-timeline span) with
   ``jax.named_scope`` (HLO metadata, so device kernel time is
   attributable by name in a TensorBoard trace).  It is safe both around
   host-side dispatch (the scheduler's chunk boundaries) and inside
   traced code (the chunk fns, the kernel dispatch wrappers in
   ``repro.kernels.ops``) — it never changes numerics or lowered
   programs, only metadata, and it is applied unconditionally so
   enabling/disabling metrics cannot perturb compiled programs.

3. **Trace capture** — :func:`maybe_profile` brackets a region with
   ``jax.profiler.start_trace`` / ``stop_trace`` when the opt-in
   ``REPRO_PROFILE_DIR`` env var is set (no-op otherwise), giving a
   TensorBoard-loadable trace where the :func:`annotate` names attribute
   prefill / decode / kernel time.  Re-entrant (inner brackets no-op) and
   best-effort: a broken profiler must never break serving.

4. **Training lifecycle tracing** — :class:`TrainTracer` is the Trainer's
   counterpart to :class:`RequestTracer`: per-step records plus
   checkpoint / restore / recovery / heartbeat events through the same
   sinks, self-clocked (run-relative seconds) because a training run has
   no engine clock.  Event vocabulary and a reader example live in
   ``repro.telemetry.__init__``'s "reading a train trace" section.
"""

from __future__ import annotations

import contextlib
import json
import logging
import os
from typing import IO, Callable, Optional, Union

import jax

_log = logging.getLogger(__name__)

#: Opt-in profiler env var: set to a directory to capture a
#: TensorBoard-readable trace of engine runs.
PROFILE_DIR_ENV = "REPRO_PROFILE_DIR"


@contextlib.contextmanager
def annotate(name: str):
    """Profiler span ``name`` for the enclosed region: a host-timeline
    ``TraceAnnotation`` plus a ``named_scope`` so any ops traced inside
    carry the name into HLO metadata (kernel attribution in the device
    timeline).  Metadata only — numerics and lowering semantics are
    untouched."""
    with jax.profiler.TraceAnnotation(name), jax.named_scope(name):
        yield


# start_trace is process-global and errors when nested: engine runs can
# nest (a CB engine warms itself with an inner run), so the outermost
# bracket wins and inner ones no-op.
_PROFILING = False


@contextlib.contextmanager
def maybe_profile(tag: str = "serve"):
    """Bracket a region with ``jax.profiler.start_trace/stop_trace`` into
    ``$REPRO_PROFILE_DIR`` when that env var is set; otherwise (or when a
    bracket is already active) a no-op.  Best-effort by design: profiling
    failures are logged once and swallowed — observability must never
    take serving down."""
    global _PROFILING
    out = os.environ.get(PROFILE_DIR_ENV)
    if not out or _PROFILING:
        yield
        return
    started = False
    try:
        jax.profiler.start_trace(out)
        started = True
    except Exception as e:  # noqa: BLE001 — profiler breakage must not break serving
        _log.warning("profiler start_trace(%s) failed for %s: %s", out, tag, e)
    _PROFILING = started or _PROFILING
    try:
        with annotate(f"repro/{tag}"):
            yield
    finally:
        if started:
            _PROFILING = False
            try:
                jax.profiler.stop_trace()
            except Exception as e:  # noqa: BLE001
                _log.warning("profiler stop_trace failed for %s: %s", tag, e)


# ---------------------------------------------------------------------------
# Request tracing
# ---------------------------------------------------------------------------


class ListSink:
    """In-memory sink: ``records`` is the list of emitted event dicts (the
    test suite's sink)."""

    def __init__(self):
        self.records: list[dict] = []

    def write(self, record: dict) -> None:
        self.records.append(record)

    def close(self) -> None:
        pass


class JsonlSink:
    """Structured JSONL sink: one compact JSON object per line, flushed
    per event so a crash mid-run still leaves a replayable prefix (the
    whole point of shipping a timeline with a failure)."""

    def __init__(self, path_or_file: Union[str, os.PathLike, IO[str]]):
        if hasattr(path_or_file, "write"):
            self._f: IO[str] = path_or_file
            self._owns = False
        else:
            self._f = open(path_or_file, "w", encoding="utf-8")
            self._owns = True

    def write(self, record: dict) -> None:
        self._f.write(json.dumps(record, sort_keys=True, default=str) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._owns:
            self._f.close()


class RequestTracer:
    """Emit lifecycle events through a sink.

    The tracer is deliberately thin: it holds no per-request state (the
    sink's output IS the record — no unbounded in-memory lists riding
    along with the bounded histograms), stamps nothing itself (callers
    pass ``t`` from the one engine clock), and counts events so tests can
    assert emission without parsing."""

    def __init__(self, sink):
        self.sink = sink
        self.events = 0

    def emit(
        self, event: str, *, t: float, uid: Optional[int] = None, **fields
    ) -> None:
        record = {"t": float(t), "event": str(event)}
        if uid is not None:
            record["uid"] = int(uid)
        for k, v in fields.items():
            if v is not None:
                record[k] = v
        self.events += 1
        self.sink.write(record)

    def close(self) -> None:
        self.sink.close()


class TrainTracer:
    """Training-run lifecycle tracer: the Trainer's twin of
    :class:`RequestTracer`, writing through the same pluggable sinks.

    Differences from the request tracer, both deliberate:

    * **self-clocked** — a training run has no engine clock, so the tracer
      stamps events itself with run-relative seconds (injectable ``clock``
      with ``now()`` for tests — a :class:`~repro.telemetry.metrics.ManualClock`
      gives deterministic timestamps);
    * **step-keyed, not uid-keyed** — every event carries the training
      ``step`` instead of a request uid.

    Like the request tracer it holds no state beyond an event count: the
    sink's output IS the record, flushed per event so a crashed run still
    leaves a replayable prefix up to the failing step.
    """

    def __init__(self, sink, clock=None):
        from repro.telemetry.metrics import MonotonicClock

        self.sink = sink
        self.clock = clock if clock is not None else MonotonicClock()
        self.events = 0

    def emit(self, event: str, *, step: Optional[int] = None, **fields) -> None:
        record = {"t": float(self.clock.now()), "event": str(event)}
        if step is not None:
            record["step"] = int(step)
        for k, v in fields.items():
            if v is not None:
                record[k] = v
        self.events += 1
        self.sink.write(record)

    def close(self) -> None:
        self.sink.close()


def fault_hook(
    tracer: RequestTracer, now: Callable[[], float]
) -> Callable[[str, dict], None]:
    """Adapter: a :class:`repro.serve.faults.FaultInjector` ``on_fire``
    callback that lands every fired fault on the request timeline (event
    ``fault_<kind>``), timestamped by the engine clock."""

    def on_fire(kind: str, info: dict) -> None:
        tracer.emit(f"fault_{kind}", t=now(), **info)

    return on_fire
