"""Typed metrics registry shared by the serving stack and the Trainer.

(Originally ``repro.serve.metrics``, PR 7; promoted here so training and
serving observe through one registry.  The serving module re-exports.)

The scheduler used to expose a handful of ad-hoc cumulative counters
(``shed_requests``, ``queue_peak``, ...) and every consumer — benches,
tests, the chaos suite — recomputed its own derived statistics host-side.
This module is the single home for engine AND trainer observability state:

* :class:`Counter` — monotonically increasing total (resettable for
  bench warm-up hygiene).
* :class:`Gauge` — point-in-time level (queue depth, pool-block
  utilization, batch occupancy).
* :class:`Histogram` — **fixed log-spaced buckets**, no unbounded
  per-request lists: ``observe`` is a bisect + two adds, memory is
  O(buckets) forever, and quantiles are interpolated from the bucket
  counts (:meth:`Histogram.quantile`).  :meth:`Histogram.quantile_bounds`
  returns the containing bucket's edges — the honest error bar a
  cross-check against an exactly-computed percentile must use.
* :class:`MetricsRegistry` — get-or-create factory keyed by
  (name, labels), a :meth:`~MetricsRegistry.snapshot` dict (stable,
  JSON-serialisable — the schema :func:`validate_snapshot` checks in CI),
  and a Prometheus text exporter (:meth:`~MetricsRegistry.prometheus_text`).

Everything here is plain host-side Python over data the scheduler already
holds at chunk boundaries: attaching (or omitting) a registry can never
change a compiled program — the byte-identical-lowering test in
``tests/test_metrics.py`` pins that.

Clocks
------
The engine's deadline math, traced timestamps and latency histograms must
all read ONE clock.  :class:`ManualClock` is the test clock (``sleep``
advances virtual time — no real sleeping), :class:`MonotonicClock` wraps
``time.monotonic`` (never ``time.time``: wall-clock steps would corrupt
latency math).  Both satisfy the scheduler's clock protocol: ``now()``
plus an optional ``sleep(dt)``.

Prefix-cache metrics (wired by the ref-counted prefix-caching engine):
``prefix_cache_hits_total`` / ``prefix_cache_misses_total`` count
full-prompt-block hits/misses at the admission hash walk,
``prefix_cache_hit_tokens_total`` the prompt tokens whose prefill was
skipped, ``prefix_cache_cow_total`` copy-on-write page copies, and
``prefix_cache_evictions_total`` cached (refcount-0) blocks reclaimed by
the allocator's LRU.  All are registered unconditionally by the engine /
allocator, so a snapshot carries the hit rate even when caching is off.

Reserved metric names (wired by upcoming PRs — see ROADMAP):
``spec_tokens_proposed_total`` / ``spec_tokens_accepted_total``
(self-speculative decoding).

The full cross-cutting name registry (serving + training + QAT probes)
lives in ``repro.telemetry.__init__``'s module docs.
"""

from __future__ import annotations

import bisect
import math
import time
from typing import Callable, Optional

# Log-spaced (factor 2) latency buckets: 100us .. ~860ks upper edges.  One
# fixed ladder serves both real-second clocks and the engine's virtual
# tick clock (ticks are order 1..100) — quantile error is bounded by the
# 2x bucket ratio, which quantile_bounds exposes honestly.
DEFAULT_TIME_BUCKETS: tuple[float, ...] = tuple(
    1e-4 * (2.0 ** i) for i in range(34)
)


def _fmt_labels(labels: tuple[tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class Counter:
    """Cumulative total.  ``value`` is a plain attribute so legacy call
    sites (``engine.shed_requests = 0`` bench resets) keep working through
    the scheduler's compatibility-alias setters."""

    kind = "counter"

    def __init__(self, name: str, labels=()):
        self.name, self.labels = name, tuple(labels)
        self.value: float = 0

    def inc(self, n: float = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Point-in-time level."""

    kind = "gauge"

    def __init__(self, name: str, labels=()):
        self.name, self.labels = name, tuple(labels)
        self.value: float = 0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1) -> None:
        self.value += n

    def dec(self, n: float = 1) -> None:
        self.value -= n

    def reset(self) -> None:
        self.value = 0


class Histogram:
    """Fixed-bucket histogram: ``buckets`` are ascending upper edges, with
    an implicit overflow bucket above the last edge.  ``counts`` has
    ``len(buckets) + 1`` entries; bucket ``i`` covers
    ``(edge[i-1], edge[i]]`` (the first covers ``[0 or -inf, edge[0]]``).
    """

    kind = "histogram"

    def __init__(self, name: str, buckets=DEFAULT_TIME_BUCKETS, labels=()):
        edges = tuple(float(b) for b in buckets)
        if not edges or any(a >= b for a, b in zip(edges, edges[1:])):
            raise ValueError("buckets must be non-empty and ascending")
        self.name, self.labels = name, tuple(labels)
        self.buckets = edges
        self.counts = [0] * (len(edges) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, x: float) -> None:
        x = float(x)
        self.counts[bisect.bisect_left(self.buckets, x)] += 1
        self.sum += x
        self.count += 1

    def reset(self) -> None:
        self.counts = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0

    def _quantile_bucket(self, q: float) -> tuple[int, int, int]:
        """(bucket index, cumulative count below it, its count) for the
        bucket containing the q-quantile."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            raise ValueError("quantile of an empty histogram")
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if cum + c >= target and c > 0:
                return i, cum, c
            cum += c
        i = len(self.counts) - 1  # q == 0 with leading empties, etc.
        return i, self.count - self.counts[i], self.counts[i]

    def quantile_bounds(self, q: float) -> tuple[float, float]:
        """(lo, hi) edges of the bucket holding the q-quantile — the
        resolution limit any cross-check against an exact percentile must
        allow for.  The overflow bucket reports ``(last_edge, inf)``."""
        i, _, _ = self._quantile_bucket(q)
        lo = self.buckets[i - 1] if i > 0 else 0.0
        hi = self.buckets[i] if i < len(self.buckets) else math.inf
        return lo, hi

    def quantile(self, q: float) -> float:
        """Interpolated q-quantile from the bucket counts (exact only up
        to bucket resolution — see :meth:`quantile_bounds`)."""
        i, cum, c = self._quantile_bucket(q)
        lo, hi = self.quantile_bounds(q)
        if math.isinf(hi):
            return lo
        frac = (q * self.count - cum) / c
        return lo + (hi - lo) * min(max(frac, 0.0), 1.0)

    def to_dict(self) -> dict:
        return {
            "buckets": list(self.buckets),
            "counts": list(self.counts),
            "sum": self.sum,
            "count": self.count,
            "p50": self.quantile(0.5) if self.count else None,
            "p95": self.quantile(0.95) if self.count else None,
            "p99": self.quantile(0.99) if self.count else None,
        }


class MetricsRegistry:
    """Get-or-create registry over (name, labels)-keyed metrics.

    ``register_collector(fn)`` attaches a zero-argument callable returning
    ``{name: number}`` evaluated at snapshot time — the hook process-wide
    stats that live outside the engine (the kernel autotune cache in
    :mod:`repro.kernels.tile_cache`) ride in on.
    """

    def __init__(self):
        self._metrics: dict[tuple, object] = {}
        self._collectors: list[Callable[[], dict]] = []

    # -- factories ----------------------------------------------------------

    def _get(self, cls, name: str, labels: dict, **kw):
        key = (name, tuple(sorted(labels.items())))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, labels=key[1], **kw)
            self._metrics[key] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}"
            )
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(
        self, name: str, buckets=DEFAULT_TIME_BUCKETS, **labels
    ) -> Histogram:
        return self._get(Histogram, name, labels, buckets=buckets)

    def register_collector(self, fn: Callable[[], dict]) -> None:
        self._collectors.append(fn)

    def family(self, name: str) -> dict[tuple, object]:
        """All metrics registered under ``name`` keyed by their label
        tuples — e.g. the per-``finish_reason`` counter family."""
        return {
            key[1]: m for key, m in self._metrics.items() if key[0] == name
        }

    # -- output -------------------------------------------------------------

    def reset(self) -> None:
        """Zero every metric (bench warm-up hygiene: warm the compiled
        programs, reset, then measure)."""
        for m in self._metrics.values():
            m.reset()

    def snapshot(self) -> dict:
        """Stable JSON-serialisable view: ``{"counters": {...},
        "gauges": {...}, "histograms": {...}, "collected": {...}}`` with
        labeled metrics keyed ``name{label="value"}``.  This is the schema
        :func:`validate_snapshot` checks and CI validates from the smoke
        bench artifact."""
        out = {"counters": {}, "gauges": {}, "histograms": {}, "collected": {}}
        for (name, labels), m in sorted(self._metrics.items()):
            key = name + _fmt_labels(labels)
            if isinstance(m, Counter):
                out["counters"][key] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][key] = m.value
            else:
                out["histograms"][key] = m.to_dict()
        for fn in self._collectors:
            for k, v in fn().items():
                out["collected"][str(k)] = v
        return out

    def prometheus_text(self) -> str:
        """Prometheus exposition format (text/plain version 0.0.4)."""
        lines: list[str] = []
        seen_type: set[str] = set()
        for (name, labels), m in sorted(self._metrics.items()):
            if name not in seen_type:
                seen_type.add(name)
                lines.append(f"# TYPE {name} {m.kind}")
            lab = _fmt_labels(labels)
            if isinstance(m, Histogram):
                cum = 0
                for edge, c in zip(m.buckets, m.counts):
                    cum += c
                    le = tuple(labels) + (("le", repr(edge)),)
                    lines.append(f"{name}_bucket{_fmt_labels(le)} {cum}")
                le = tuple(labels) + (("le", "+Inf"),)
                lines.append(f"{name}_bucket{_fmt_labels(le)} {m.count}")
                lines.append(f"{name}_sum{lab} {m.sum}")
                lines.append(f"{name}_count{lab} {m.count}")
            else:
                lines.append(f"{name}{lab} {m.value}")
        return "\n".join(lines) + "\n"


def validate_snapshot(snap: dict) -> None:
    """Assert ``snap`` matches the :meth:`MetricsRegistry.snapshot` schema
    (keys + types).  Raises ``AssertionError`` with the offending key —
    used by CI against the smoke-bench metrics artifact and by the test
    suite, so the schema cannot drift silently."""
    assert isinstance(snap, dict), "snapshot must be a dict"
    for section in ("counters", "gauges", "histograms", "collected"):
        assert section in snap, f"missing section {section!r}"
        assert isinstance(snap[section], dict), f"{section} must be a dict"
    num = (int, float)
    for section in ("counters", "gauges", "collected"):
        for k, v in snap[section].items():
            assert isinstance(k, str), f"non-string key {k!r} in {section}"
            assert isinstance(v, num) and not isinstance(v, bool), (
                f"{section}[{k!r}] must be a number, got {type(v).__name__}"
            )
    for k, h in snap["histograms"].items():
        assert isinstance(k, str), f"non-string histogram key {k!r}"
        assert isinstance(h, dict), f"histograms[{k!r}] must be a dict"
        for field in ("buckets", "counts", "sum", "count"):
            assert field in h, f"histograms[{k!r}] missing {field!r}"
        assert isinstance(h["buckets"], list) and isinstance(h["counts"], list)
        assert len(h["counts"]) == len(h["buckets"]) + 1, (
            f"histograms[{k!r}]: counts must be len(buckets) + 1"
        )
        assert all(isinstance(x, num) for x in h["buckets"])
        assert all(isinstance(x, int) for x in h["counts"])
        assert isinstance(h["sum"], num) and isinstance(h["count"], int)
        for q in ("p50", "p95", "p99"):
            assert q in h and (h[q] is None or isinstance(h[q], num))


# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------


class ManualClock:
    """A fake clock for tests: ``now()`` returns virtual time, ``sleep``
    and ``advance`` move it forward instantly.  An engine driven by one
    runs arrival waits, deadlines, TTFT/ITL histograms and trace
    timestamps on the same virtual timeline with zero real sleeping."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)
        self.sleeps: list[float] = []

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)

    def sleep(self, dt: float) -> None:
        self.sleeps.append(float(dt))
        self.t += max(0.0, float(dt))


class MonotonicClock:
    """``time.monotonic``-based real clock (zeroed at construction so
    timestamps read as run-relative seconds).  Monotonic by contract —
    deadline math must never see wall-clock steps, hence no ``time.time``
    anywhere in the serving stack."""

    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def sleep(self, dt: float) -> None:
        time.sleep(max(0.0, dt))


def resolve_clock(
    clock,
) -> tuple[Optional[Callable[[], float]], Callable[[float], None]]:
    """Normalize the engine's ``clock`` argument to ``(now, sleep)``.

    ``None`` -> ``(None, no-op)`` (the engine's virtual tick clock — it
    never sleeps, it jumps).  A bare callable (the legacy form) ->
    ``(clock, time.sleep)``.  An object with ``now()`` (and optionally
    ``sleep(dt)``) -> its own pair, so a :class:`ManualClock` test drives
    waiting without real sleeps and deadline math, traces and histograms
    all share one timeline.
    """
    if clock is None:
        return None, lambda dt: None
    now = getattr(clock, "now", None)
    if callable(now):
        return now, getattr(clock, "sleep", time.sleep)
    if callable(clock):
        return clock, time.sleep
    raise TypeError(f"clock must be callable or have .now(), got {clock!r}")
