"""On-device QAT health probes (see the metric registry in
``repro.telemetry.__init__``).

Two halves, both riding the existing per-step ``metrics`` transfer so
enabling probes adds ZERO extra host syncs:

**Forward-pass taps** — the quantizers and the decoupled FFN can't return
extra values without changing every signature in the model stack, so tap
sites record into an *ambient collector* instead: a module-global that is
``None`` except inside the trainer's :func:`collect` scope.  Activation
clip fractions, branch output norms and router load entropy land here.
``active()`` is a plain trace-time Python check — when the collector is
absent (every serving path, and training with probes off) a tap site emits
no jnp ops at all, which is what makes the disabled-telemetry
byte-identical-lowering invariant trivial.

**Scan discipline** — values recorded inside a ``jax.lax.scan`` body (the
layer scan, the grad-accum scan) are tracers of the *body* trace and must
leave as scan outputs, not via the closure.  The contract:

* wrap the ``lax.scan`` call in :func:`scan_scope` (holds values recorded
  *before* the scan, so the body's drain can't re-emit them once per
  iteration);
* the body returns :func:`scan_drain` as its ``ys``;
* after the scan, :func:`scan_merge` sums the stacked ``ys`` over the
  layer axis and re-records them into the ambient collector.

The final escape hatch is ``models.api.loss_fn`` folding
:func:`summaries` into its aux metrics — from there the values flow
through ``value_and_grad(..., has_aux=True)`` like any other metric.

**Param-side probes** — :func:`train_step_probes` needs no taps: sign-flip
rates, scale drift, INT8 weight saturation and the per-branch gradient
split are pure functions of (old params, new params, grads) computed
directly inside ``train_step``.  Layer families are classified from tree
paths (``w8_*`` 8-bit branch, ``w1*`` 1-bit trunk, ``mixer`` attention,
``embed``/``lm_head``); norm/router/scalar leaves are excluded.

This module deliberately imports nothing from ``repro.core`` at module
level (the quantizers import *us* for the tap sites); the few shared
constants are imported lazily inside the probe functions.
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
import jax.numpy as jnp
import jax.tree_util as jtu

Array = jax.Array

_COLLECTOR: Optional["ProbeCollector"] = None


class ProbeCollector:
    """Accumulates named device scalars by summation.  ``<name>_sum`` /
    ``<name>_w`` pairs (via :meth:`add_mean`) become weighted means in
    :func:`summaries`; raw names pass through the ratio rules there."""

    def __init__(self):
        self.sums: dict[str, Array] = {}

    def add(self, name: str, value) -> None:
        v = jnp.asarray(value, jnp.float32)
        self.sums[name] = self.sums[name] + v if name in self.sums else v

    def drain(self) -> dict[str, Array]:
        d, self.sums = self.sums, {}
        return d


def active() -> bool:
    """True inside a :func:`collect` scope — a trace-time Python check, so
    tap sites are free (no ops, no lowering change) when probes are off."""
    return _COLLECTOR is not None


@contextlib.contextmanager
def collect():
    """Activate an ambient collector for the enclosed forward/backward
    trace.  Scopes nest by shadowing (inner scope wins, outer restored)."""
    global _COLLECTOR
    prev = _COLLECTOR
    _COLLECTOR = ProbeCollector()
    try:
        yield _COLLECTOR
    finally:
        _COLLECTOR = prev


def add(name: str, value) -> None:
    if _COLLECTOR is not None:
        _COLLECTOR.add(name, value)


def add_mean(name: str, value, weight) -> None:
    """Record one term of a weighted mean (summaries divides the pair)."""
    if _COLLECTOR is not None:
        _COLLECTOR.add(name + "_sum", jnp.asarray(value, jnp.float32) * weight)
        _COLLECTOR.add(name + "_w", jnp.asarray(weight, jnp.float32))


# -- scan boundary helpers ---------------------------------------------------


@contextlib.contextmanager
def scan_scope():
    """Bracket a ``lax.scan`` whose body records probes: values recorded
    before the scan are held aside (so the body's :func:`scan_drain` only
    sees in-body records — a pre-scan value returned as ``ys`` would be
    broadcast and counted once per iteration) and re-added on exit."""
    if _COLLECTOR is None:
        yield
        return
    held = _COLLECTOR.drain()
    try:
        yield
    finally:
        for k, v in held.items():
            _COLLECTOR.add(k, v)


def scan_drain() -> Optional[dict[str, Array]]:
    """Inside a scan body: pull this iteration's records out as ``ys``.
    Returns None when probes are off (a valid, empty scan output)."""
    if _COLLECTOR is None:
        return None
    return _COLLECTOR.drain()


def scan_merge(stacked: Optional[dict[str, Array]]) -> None:
    """After a scan: fold the stacked ``ys`` back into the ambient
    collector, summing over the leading (iteration) axis."""
    if stacked is None:
        return
    for name, v in stacked.items():
        add(name, jnp.sum(v, axis=0))


def merge(drained: Optional[dict[str, Array]]) -> None:
    """Re-record a :func:`scan_drain` result as-is (the non-scan remat
    boundary: values must leave ``jax.checkpoint`` as outputs too)."""
    if drained is None:
        return
    for name, v in drained.items():
        add(name, v)


def summaries() -> dict[str, Array]:
    """Drain the ambient collector into final named metrics:

    * ``<name>_sum`` / ``<name>_w`` pairs -> ``qat_<name>`` weighted mean
      (activation clip rate, router load entropy);
    * ``branch1_sq`` / ``branch8_sq`` -> ``qat_branch_share8`` =
      ||alpha*y8||^2 / (||alpha*y8||^2 + ||beta*y1||^2).
    """
    if _COLLECTOR is None:
        return {}
    d = _COLLECTOR.drain()
    out: dict[str, Array] = {}
    for base in sorted(n[: -len("_sum")] for n in d if n.endswith("_sum")):
        out["qat_" + base] = d[base + "_sum"] / jnp.maximum(d[base + "_w"], 1e-9)
    if "branch8_sq" in d and "branch1_sq" in d:
        tot = d["branch8_sq"] + d["branch1_sq"]
        out["qat_branch_share8"] = d["branch8_sq"] / jnp.maximum(tot, 1e-20)
    return out


# ---------------------------------------------------------------------------
# Param-side probes (no taps needed: pure functions of params/grads)
# ---------------------------------------------------------------------------

#: Layer families for per-family probes; ``other`` leaves are skipped.
FAMILIES = ("attn", "ffn1", "ffn8", "embed")


def leaf_path(path) -> str:
    """jtu key path -> "a/b/c" string."""
    return "/".join(
        str(getattr(e, "key", getattr(e, "idx", ""))) for e in path
    )


def family_of(key: str) -> Optional[str]:
    """Classify a parameter path into a probe family (None = skip).

    Branch fragments win over ``mixer`` so a decoupled *projection*
    (SSM-family mixer: ``w1``/``w8_a``/``w8_b``) splits into trunk/branch
    like the FFN does.
    """
    parts = key.split("/")
    if any("router" in p or "norm" in p or "subln" in p for p in parts):
        return None
    if any(p.startswith("w8") for p in parts):
        return "ffn8"
    if any(p.startswith("w1") for p in parts):
        return "ffn1"
    if "mixer" in parts:
        return "attn"
    if "embed" in parts or "lm_head" in parts:
        return "embed"
    return None


def _slice_axes(w: Array) -> tuple[int, ...]:
    """Per-slice reduction axes: the trailing (d_in, d_out) matrix of a
    possibly layer/expert-stacked leaf — matching how the fake-quant path
    scales each 2-D weight independently inside the layer scan."""
    return tuple(range(w.ndim - 2, w.ndim))


def _centered_sign(w: Array) -> Array:
    """The binarizer's sign grid: Sign(W - mu) per slice (paper Eq. 4)."""
    mu = jnp.mean(w, axis=_slice_axes(w), keepdims=True)
    return jnp.where(w - mu >= 0, 1.0, -1.0)


def _family_leaves(*trees):
    """Yield (family, leaf_0, leaf_1, ...) for classified >=2-D float
    leaves, zipping identically-structured trees (params old/new, grads)."""
    flat = [jtu.tree_flatten_with_path(t)[0] for t in trees]
    for entries in zip(*flat):
        key = leaf_path(entries[0][0])
        leaf = entries[0][1]
        if leaf.ndim < 2 or not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        fam = family_of(key)
        if fam is None:
            continue
        yield (fam,) + tuple(e[1] for e in entries)


def train_step_probes(old_params, new_params, grads) -> dict[str, Array]:
    """All param/grad-side QAT health probes for one step, on device.

    Returns (families present in the tree decide which keys exist — a
    static, per-config decision so the metrics dict structure is stable):

    * ``qat_flip_<fam>``: fraction of latent weights whose centered sign
      flipped between ``old_params`` and ``new_params``;
    * ``qat_scale_drift_absmean`` / ``qat_scale_drift_absmax``: mean
      relative per-slice drift of the 1-bit lambda / 8-bit amax scales;
    * ``qat_clip_w8``: fraction of 8-bit-branch weights saturating the
      INT8 grid (|q| = 127) under the new params;
    * ``qat_gnorm_ffn8`` / ``qat_gnorm_ffn1`` / ``qat_gnorm_share8``:
      gradient norms of the two decoupled branches and the 8-bit share
      of their combined squared norm.
    """
    from repro.core.quantization import EPS, INT8_QMAX  # lazy: import cycle

    f32 = jnp.float32
    zero = jnp.zeros((), f32)
    flips = {f: zero for f in FAMILIES}
    counts = {f: 0 for f in FAMILIES}
    drift = {"absmean": zero, "absmax": zero}
    drift_n = {"absmean": 0, "absmax": 0}
    clip8_hits, clip8_n = zero, 0
    gsq = {"ffn1": zero, "ffn8": zero}
    gsq_seen = {"ffn1": False, "ffn8": False}

    for fam, w_old, w_new, g in _family_leaves(old_params, new_params, grads):
        w_old, w_new = w_old.astype(f32), w_new.astype(f32)
        axes = _slice_axes(w_old)
        flips[fam] = flips[fam] + jnp.sum(
            _centered_sign(w_old) != _centered_sign(w_new)
        )
        counts[fam] += w_old.size
        n_slices = w_old.size // (w_old.shape[-1] * w_old.shape[-2])
        if fam in ("attn", "ffn1"):
            lam_old = jnp.mean(jnp.abs(w_old), axis=axes) + EPS
            lam_new = jnp.mean(jnp.abs(w_new), axis=axes) + EPS
            drift["absmean"] += jnp.sum(jnp.abs(lam_new - lam_old) / lam_old)
            drift_n["absmean"] += n_slices
        elif fam == "ffn8":
            amax_old = jnp.max(jnp.abs(w_old), axis=axes)
            amax_new = jnp.max(jnp.abs(w_new), axis=axes, keepdims=True)
            drift["absmax"] += jnp.sum(
                jnp.abs(amax_new.reshape(amax_old.shape) - amax_old)
                / (amax_old + EPS)
            )
            drift_n["absmax"] += n_slices
            scale = INT8_QMAX / (amax_new + EPS)
            q = jnp.round(w_new * scale)
            clip8_hits += jnp.sum(jnp.abs(q) >= INT8_QMAX)
            clip8_n += w_new.size
        if fam in gsq:
            gsq[fam] = gsq[fam] + jnp.sum(jnp.square(g.astype(f32)))
            gsq_seen[fam] = True

    out: dict[str, Array] = {}
    for fam in FAMILIES:
        if counts[fam]:
            out[f"qat_flip_{fam}"] = flips[fam] / counts[fam]
    if drift_n["absmean"]:
        out["qat_scale_drift_absmean"] = drift["absmean"] / drift_n["absmean"]
    if drift_n["absmax"]:
        out["qat_scale_drift_absmax"] = drift["absmax"] / drift_n["absmax"]
    if clip8_n:
        out["qat_clip_w8"] = clip8_hits / clip8_n
    if gsq_seen["ffn8"]:
        out["qat_gnorm_ffn8"] = jnp.sqrt(gsq["ffn8"])
    if gsq_seen["ffn1"]:
        out["qat_gnorm_ffn1"] = jnp.sqrt(gsq["ffn1"])
    if gsq_seen["ffn8"] and gsq_seen["ffn1"]:
        out["qat_gnorm_share8"] = gsq["ffn8"] / jnp.maximum(
            gsq["ffn8"] + gsq["ffn1"], 1e-20
        )
    return out


# ---------------------------------------------------------------------------
# Cadenced democratization snapshot (host-side, off the jit path)
# ---------------------------------------------------------------------------


def sensitivity_snapshot(params, max_elems: int = 1 << 20) -> dict[str, float]:
    """Democratization statistics per layer family, reusing
    ``core/sensitivity``'s metrics with the squared latent weight as the
    sensitivity proxy (the isotropic-input OBS limit: ``s ~ w^2`` when
    ``H ~ c*I`` — see ``obs_sensitivity``; running real calibration
    batches per family every N steps would cost a second forward).

    Host-side and cadenced (``TrainerConfig.sensitivity_every``), so it
    never touches the compiled ``train_step``.  Each family's flattened
    ``w^2`` population is strided down to ``max_elems`` to bound cost.
    """
    from repro.core.sensitivity import (
        democratization_score,
        sensitivity_kurtosis,
        top_fraction_mass,
    )

    pools: dict[str, list] = {"attn": [], "ffn1": [], "ffn8": []}
    for fam, w in _family_leaves(params):
        if fam in pools:
            pools[fam].append(jnp.square(w.astype(jnp.float32)).reshape(-1))
    out: dict[str, float] = {}
    for fam, vecs in pools.items():
        if not vecs:
            continue
        s = jnp.concatenate(vecs)
        if s.size > max_elems:
            s = s[:: -(-s.size // max_elems)]
        out[f"demo_score_{fam}"] = float(democratization_score(s))
        out[f"demo_kurtosis_{fam}"] = float(sensitivity_kurtosis(s))
        out[f"demo_top1pct_{fam}"] = float(top_fraction_mass(s, 0.01))
    return out
