"""Fault-tolerant checkpointing (no orbax in this environment).

Guarantees:
  * atomic: data written to ``step_N.tmp/`` then os.replace'd into place —
    a crash mid-save never corrupts the latest valid checkpoint;
  * async: saves run on a background thread off the training loop
    (``wait()`` joins before the next save or at exit);
  * elastic: arrays are stored with logical (unsharded) shapes + a manifest
    of tree structure, so a restore can re-shard onto ANY mesh (grow or
    shrink the pod count between runs);
  * bounded retention: keeps the last ``keep`` checkpoints.

Layout:  <dir>/step_<N>/manifest.json + arrays.npz
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    import jax.tree_util as jtu

    paths, treedef = jtu.tree_flatten_with_path(tree)
    flat = []
    for path, leaf in paths:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", ""))) for e in path)
        flat.append((key, leaf))
    return flat, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False):
        """Snapshot to host memory synchronously, write to disk async."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            flat, _ = _flatten(host_tree)
            # npz can't hold bfloat16 — store as a uint16 bit view and
            # record the true dtype in the manifest
            arrays = {}
            for k, v in flat:
                a = np.asarray(v)
                if a.dtype == jnp.bfloat16:
                    a = a.view(np.uint16)
                arrays[k] = a
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            manifest = {
                "step": step,
                "keys": [k for k, _ in flat],
                "shapes": {k: list(np.shape(v)) for k, v in flat},
                "dtypes": {k: str(np.asarray(v).dtype) for k, v in flat},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like_tree, step: Optional[int] = None, shardings=None):
        """Restore into the structure of ``like_tree``.

        ``shardings``: optional pytree of NamedShardings — arrays are placed
        (and thus re-sharded) directly onto the target mesh, enabling
        elastic mesh changes between save and restore.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        data = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat, treedef = _flatten(like_tree)
        import ml_dtypes
        import jax.tree_util as jtu

        sh_flat = None
        if shardings is not None:
            sh_flat = [s for _, s in _flatten(shardings)[0]]
        leaves = []
        for i, (key, like) in enumerate(flat):
            arr = data[key]
            if manifest["dtypes"].get(key) == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            assert tuple(arr.shape) == tuple(np.shape(like)), (
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {np.shape(like)}"
            )
            if sh_flat is not None:
                leaves.append(jax.device_put(arr, sh_flat[i]))
            else:
                leaves.append(jnp.asarray(arr, dtype=like.dtype))
        return jtu.tree_unflatten(treedef, leaves)
