"""INT8 gradient compression with error feedback (DESIGN.md §4).

For cross-pod data parallelism the gradient all-reduce dominates DCI/ICI
traffic.  Each worker quantizes its local gradient to INT8 against a
*shared* per-chunk scale (one extra scalar all-reduce), sums in INT32, and
dequantizes; the local quantization residual is carried to the next step
(error feedback), which keeps SGD/Adam convergence (Karimireddy et al.).

Two entry points:
  * ``compress_psum`` — inside shard_map: explicit psum path (true wire
    compression; used by the ddp-compressed trainer mode and tests).
  * ``fake_compress`` — pure local quantize+residual (models the numerics
    under pjit where the partitioner owns the collective).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _chunk_absmax(g: Array, chunk: int) -> Array:
    flat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % chunk
    flat = jnp.pad(flat, (0, pad))
    return jnp.max(jnp.abs(flat.reshape(-1, chunk)), axis=1)


def _quant_chunks(g: Array, scales: Array, chunk: int):
    flat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % chunk
    flat = jnp.pad(flat, (0, pad)).reshape(-1, chunk)
    q = jnp.clip(jnp.round(flat / scales[:, None]), -127, 127).astype(jnp.int8)
    return q, n


def _dequant_chunks(q: Array, scales: Array, n: int, shape) -> Array:
    flat = (q.astype(jnp.float32) * scales[:, None]).reshape(-1)[:n]
    return flat.reshape(shape)


def compress_psum(g: Array, residual: Array, axis_name: str, chunk: int = 1024):
    """Error-feedback INT8 all-reduce for one gradient tensor.

    Call inside shard_map with ``axis_name`` mapped.  Returns
    (mean_gradient fp32, new_residual).
    """
    g = g.astype(jnp.float32) + residual
    # shared scale: max over workers so every worker uses the same grid
    amax = _chunk_absmax(g, chunk)
    amax = jax.lax.pmax(amax, axis_name)
    scales = jnp.maximum(amax, 1e-12) / 127.0
    q, n = _quant_chunks(g, scales, chunk)
    local_dq = _dequant_chunks(q, scales, n, g.shape)
    new_residual = g - local_dq  # what this worker failed to transmit
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    world = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    mean = _dequant_chunks(
        summed.astype(jnp.float32) / world.astype(jnp.float32), scales, n, g.shape
    )
    # NOTE: summed is int32 on the wire conceptually; XLA moves int32 here.
    # Byte win comes from q being int8 at the ring stage in a real ICI
    # implementation (reduce-scatter int8 + all-gather int8), modeled in
    # EXPERIMENTS.md §Perf via collective-bytes accounting.
    return mean, new_residual


def fake_compress(g: Array, residual: Array, chunk: int = 1024):
    """Local-only quantize + error feedback (numerics model, no collective)."""
    g = g.astype(jnp.float32) + residual
    amax = _chunk_absmax(g, chunk)
    scales = jnp.maximum(amax, 1e-12) / 127.0
    q, n = _quant_chunks(g, scales, chunk)
    dq = _dequant_chunks(q, scales, n, g.shape)
    return dq, g - dq


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def tree_compress_psum(grads, residuals, axis_name: str, chunk: int = 1024):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [compress_psum(g, r, axis_name, chunk) for g, r in zip(flat_g, flat_r)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )
