"""Logical-axis sharding (MaxText-style).

Model code annotates parameters and activations with *logical* axis names;
a rule table maps logical axes to mesh axes.  ``shard_hint`` is a no-op
when no mesh is active, so single-device tests/examples run unchanged.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

# ---------------------------------------------------------------------------
# Logical -> mesh axis rules
# ---------------------------------------------------------------------------

# Default production rules (see DESIGN.md §4).  Order matters only for
# documentation; each logical axis maps to zero or more mesh axes.
DEFAULT_RULES: dict[str, Any] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,            # sequence kept unsharded by default (SP optional)
    "resid_seq": None,      # residual stream between blocks (SP: -> "model")
    "act_embed": None,
    "act_heads": "model",
    "act_ffn": "model",
    "cache_seq": None,      # long_500k overrides to "data"
    "cache_heads": "model",
    # parameters
    "vocab": "model",
    "embed": "data",        # FSDP: weights sharded over the data axis
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "ffn8": None,           # pQuant 8-bit branch hidden dim (small; see §Perf)
    "experts": "model",     # stacked expert axis (pQuant branches / MoE -> EP)
    "expert_ffn": None,     # per-expert hidden dim (EP shards experts instead)
    "lora": None,           # MLA low-rank dims stay replicated
    "conv": None,
    "state": None,
}


class _RuleState(threading.local):
    def __init__(self):
        self.rules: dict[str, Any] = dict(DEFAULT_RULES)
        self.mesh: Optional[Mesh] = None


_STATE = _RuleState()


@contextlib.contextmanager
def sharding_rules(mesh: Optional[Mesh], overrides: Optional[dict] = None):
    """Activate a mesh + rule overrides for model tracing."""
    old_rules, old_mesh = _STATE.rules, _STATE.mesh
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    _STATE.rules, _STATE.mesh = rules, mesh
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh = old_rules, old_mesh


def active_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def _mesh_axes_for(logical: Optional[str], mesh: Mesh):
    if logical is None:
        return None
    mapped = _STATE.rules.get(logical, None)
    if mapped is None:
        return None
    if isinstance(mapped, str):
        mapped = (mapped,)
    # drop mesh axes that don't exist (e.g. "pod" on the single-pod mesh)
    present = tuple(a for a in mapped if a in mesh.axis_names)
    if not present:
        return None
    return present if len(present) > 1 else present[0]


def logical_to_spec(axes: Sequence[Optional[str]], mesh: Optional[Mesh] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    mesh = mesh or _STATE.mesh
    if mesh is None:
        return P()
    return P(*[_mesh_axes_for(a, mesh) for a in axes])


def _dim_divisible(shape, spec: P, mesh: Mesh) -> bool:
    for size, ax in zip(shape, tuple(spec)):
        if ax is None:
            continue
        axes = (ax,) if isinstance(ax, str) else ax
        n = int(np.prod([mesh.shape[a] for a in axes]))
        if size % n != 0:
            return False
    return True


def shard_hint(x: Array, *axes: Optional[str]) -> Array:
    """Constrain an activation's sharding by logical axes.  No-op without an
    active mesh; silently relaxes axes whose dim isn't divisible (e.g. MQA's
    single KV head on a 16-way model axis)."""
    mesh = _STATE.mesh
    if mesh is None:
        return x
    spec = logical_to_spec(axes, mesh)
    if not _dim_divisible(x.shape, spec, mesh):
        relaxed = []
        for size, a in zip(x.shape, axes):
            s = _mesh_axes_for(a, mesh)
            if s is None:
                relaxed.append(None)
                continue
            saxes = (s,) if isinstance(s, str) else s
            n = int(np.prod([mesh.shape[m] for m in saxes]))
            relaxed.append(s if size % n == 0 else None)
        spec = P(*relaxed)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_sharding(axes_tree, mesh: Mesh) -> Any:
    """Map an axes pytree (from init) to NamedShardings (no shape check)."""

    def one(axes):
        return NamedSharding(mesh, logical_to_spec(axes, mesh))

    return jax.tree.map(one, axes_tree, is_leaf=lambda t: isinstance(t, tuple))


def _lookup_path(tree, path):
    node = tree
    for entry in path:
        key = getattr(entry, "key", None)  # DictKey
        if key is None:
            key = getattr(entry, "idx", None)  # SequenceKey
        if key is None:
            key = getattr(entry, "name", None)  # GetAttrKey (NamedTuple)
        if isinstance(node, tuple) and hasattr(node, "_fields") and isinstance(key, str):
            node = getattr(node, key)
        else:
            node = node[key]
    return node


def relaxed_spec(shape, axes: Sequence[Optional[str]], mesh: Mesh) -> P:
    """logical axes -> PartitionSpec under the current rules, dropping any
    axis whose dim isn't divisible by its mesh extent (per-dim, unlike
    ``_dim_divisible``'s all-or-nothing check)."""
    relaxed = []
    for size, a in zip(shape, axes):
        s = _mesh_axes_for(a, mesh)
        if s is None:
            relaxed.append(None)
            continue
        saxes = (s,) if isinstance(s, str) else s
        n = int(np.prod([mesh.shape[m] for m in saxes]))
        relaxed.append(s if size % n == 0 else None)
    return P(*relaxed)


def param_sharding_for(params_tree, axes_tree, mesh: Mesh) -> Any:
    """Map params (arrays or ShapeDtypeStructs) + their logical-axes tree to
    NamedShardings, relaxing any axis whose dim isn't divisible by the mesh
    (e.g. a single MQA KV head against a 16-way model axis)."""
    import jax.tree_util as jtu

    paths_and_leaves, treedef = jtu.tree_flatten_with_path(params_tree)
    out = []
    for path, p in paths_and_leaves:
        axes = _lookup_path(axes_tree, path)
        assert len(axes) == len(p.shape), f"{axes} vs {p.shape} at {path}"
        out.append(NamedSharding(mesh, relaxed_spec(p.shape, axes, mesh)))
    return jtu.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Serving (tensor-parallel inference) rules
# ---------------------------------------------------------------------------

# Rule overrides for the serving engines (see serve/__init__.py §sharded
# serving).  Serving is column-parallel only: weights shard on their LAST
# (output/N-major) dim, so no dot-product reduction is ever split — a
# 1-device mesh stays bit-for-bit the unsharded engine and a multi-device
# mesh differs only where XLA re-associates the per-layer collective.
#   embed -> None : no FSDP at inference; row-side weights (wo, w1_down,
#                   embedding table) replicate, so the one collective per
#                   sublayer is the all-gather of the N-sharded activation
#                   at the replicated down-projection boundary.
#   batch -> None : per-slot state (tok/pos/PRNG/masks, block tables)
#                   replicates; the host-side scheduler stays global.
#   experts -> None : stacked 8-bit branches are r-narrow; replicate.
SERVING_OVERRIDES: dict[str, Any] = {
    "embed": None,
    "batch": None,
    "experts": None,
}


def nmajor_axis(n: int, logical: Optional[str]) -> Optional[str]:
    """Mesh axis an N-major (last) weight dim of size ``n`` shards over
    under the active rules, or None (no mesh / unmapped / multi-axis /
    indivisible / size-1 axis).  The kernel dispatchers use this to decide
    whether to open a ``shard_map`` island around a packed-weight call."""
    mesh = _STATE.mesh
    if mesh is None or logical is None:
        return None
    s = _mesh_axes_for(logical, mesh)
    if s is None or not isinstance(s, str):
        return None
    ws = mesh.shape[s]
    return s if ws > 1 and n % ws == 0 else None


def nmajor_param_sharding(params_tree, axes_tree, mesh: Mesh) -> Any:
    """Column-parallel parameter placement: shard ONLY each leaf's last dim
    (when its logical axis maps to a present mesh axis and divides); every
    other dim replicates.  This is the serving-engine placement — it keeps
    every dot-product reduction whole (exact numerics per shard) while the
    packed-weight bytes split N-major across the model axis."""
    import jax.tree_util as jtu

    paths_and_leaves, treedef = jtu.tree_flatten_with_path(params_tree)
    out = []
    for path, p in paths_and_leaves:
        axes = _lookup_path(axes_tree, path)
        assert len(axes) == len(p.shape), f"{axes} vs {p.shape} at {path}"
        masked = (None,) * (len(axes) - 1) + (axes[-1],) if axes else ()
        out.append(NamedSharding(mesh, relaxed_spec(p.shape, masked, mesh)))
    return jtu.tree_unflatten(treedef, out)
