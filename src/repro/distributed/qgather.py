"""Quantized weight gather — a beyond-paper distributed optimization.

Under FSDP, latent weights are sharded over the `data` axis and all-gathered
per layer.  Because pQuant's backbone weights are sign(+-1) x one scalar,
the gather can move **INT8 signs** instead of bf16/fp32 latents: the
collective payload that exists only because of the paper's quantization
shrinks 2-4x (and 16x in the packed variant, tracked in §Perf).

Mechanics: a custom_vjp wraps (binarize -> int8 cast -> sharding constraint
that drops the fsdp axis -> dequantize).  The constraint on the *int8*
tensor forces the SPMD partitioner to all-gather 1-byte data; the backward
pass constrains the gradient back to the sharded spec, which transposes to
a reduce-scatter.  STE semantics are preserved (gradient passes straight
through the quantizer to the latent shard).
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_hint

Array = jax.Array

EPS = 1e-5

# logical axes that map to the fsdp (`data`) mesh axis in DEFAULT_RULES;
# the post-gather spec replaces them with None (replicated)
FSDP_LOGICAL = ("embed",)


def _gathered_axes(axes: Sequence[Optional[str]]):
    return tuple(None if a in FSDP_LOGICAL else a for a in axes)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def binarize_gather(w: Array, axes: tuple) -> Array:
    """1-bit quantize + gather-as-int8 + dequantize.  Returns +-lambda values
    replicated over the fsdp axis, sharded as before elsewhere."""
    y, _ = _fwd(w, axes)
    return y


def _fwd(w: Array, axes: tuple):
    mu = jnp.mean(w)
    lam = jnp.mean(jnp.abs(w)) + EPS
    signs = jnp.where(w - mu >= 0, jnp.int8(1), jnp.int8(-1))
    # the all-gather happens HERE, on int8 payload
    signs = shard_hint(signs, *_gathered_axes(axes))
    y = signs.astype(w.dtype) * lam.astype(w.dtype)
    return y, axes


def _bwd(axes, res, g):
    # STE: gradient passes straight through to the latent shard; the
    # constraint transposes the gather into a reduce-scatter.
    del res
    return (shard_hint(g, *axes),)


def _fwd_vjp(w, axes):
    y, _ = _fwd(w, axes)
    return y, None


binarize_gather.defvjp(_fwd_vjp, _bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def binarize_gather_stacked(w: Array, axes: tuple) -> Array:
    """Per-slice (stacked expert) 1-bit quantize + int8 gather: stats are
    computed over the trailing two axes so each expert keeps its own
    mu/lambda (matches core.quantization.binarize_weights_stacked)."""
    y, _ = _fwd_stacked(w, axes)
    return y


def _fwd_stacked(w: Array, axes: tuple):
    red = tuple(range(max(0, w.ndim - 2), w.ndim))
    mu = jnp.mean(w, axis=red, keepdims=True)
    lam = jnp.mean(jnp.abs(w), axis=red, keepdims=True) + EPS
    signs = jnp.where(w - mu >= 0, jnp.int8(1), jnp.int8(-1))
    signs = shard_hint(signs, *_gathered_axes(axes))
    return signs.astype(w.dtype) * lam.astype(w.dtype), axes


def _bwd_stacked(axes, res, g):
    del res
    return (shard_hint(g, *axes),)


def _fwd_stacked_vjp(w, axes):
    y, _ = _fwd_stacked(w, axes)
    return y, None


binarize_gather_stacked.defvjp(_fwd_stacked_vjp, _bwd_stacked)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def int8_gather(w: Array, axes: tuple) -> Array:
    """AbsMax-INT8 quantize + gather-as-int8 + dequantize (for the 8-bit
    branch weights under FSDP)."""
    y, _ = _fwd8(w, axes)
    return y


def _fwd8(w: Array, axes: tuple):
    amax = jnp.max(jnp.abs(w)) + EPS
    scale = 127.0 / amax
    q = jnp.clip(jnp.round(w * scale), -127, 127).astype(jnp.int8)
    q = shard_hint(q, *_gathered_axes(axes))
    return q.astype(w.dtype) / scale.astype(w.dtype), axes


def _bwd8(axes, res, g):
    del res
    return (shard_hint(g, *axes),)


def _fwd8_vjp(w, axes):
    y, _ = _fwd8(w, axes)
    return y, None


int8_gather.defvjp(_fwd8_vjp, _bwd8)
