"""Production training launcher.

Single-process on local devices by default; on a real cluster each host
runs this same entrypoint with ``--coordinator`` set and jax.distributed
wires the pods together (the mesh spans all hosts; per-host data sharding
comes from the deterministic pipeline, DESIGN.md §4).

  python -m repro.launch.train --arch pquant-300m --steps 200 \
      --seq-len 512 --global-batch 8 --ckpt-dir /tmp/ckpt

Fault tolerance: checkpoints are atomic + async; on restart the Trainer
resumes from the latest manifest automatically (same flag set).  The
orchestrator (launch/orchestrator.py) adds supervised restarts.
"""

from __future__ import annotations

import argparse
import json
import logging
import os
from typing import Optional

import jax

from repro.configs.registry import get_config, reduced
from repro.data.pipeline import DataConfig, PrefetchIterator, SyntheticSource, TextFileSource
from repro.train.trainer import Trainer, TrainerConfig


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--quant-mode", default="pquant",
                    choices=["pquant", "bitnet", "bitnet158", "none"])
    ap.add_argument("--n-experts", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (CPU-scale) variant of the arch")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--peak-lr", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default=None, help="text file path (default: synthetic)")
    ap.add_argument("--dtype", default=None, choices=["float32", "bfloat16"],
                    help="model compute dtype override (fp32 is faster on CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--history-out", default=None)
    # telemetry (name registry + trace format: repro.telemetry docs)
    ap.add_argument("--probes", action="store_true",
                    help="on-device QAT health probes in the step metrics")
    ap.add_argument("--sensitivity-every", type=int, default=0,
                    help="democratization snapshot cadence in steps (0=off)")
    ap.add_argument("--trace-jsonl", default=None,
                    help="stream the run lifecycle trace (JSONL) here")
    ap.add_argument("--history-jsonl", default=None,
                    help="stream history records as JSONL instead of "
                         "holding them in host memory")
    ap.add_argument("--metrics-out", default=None,
                    help="write the trainer's metrics snapshot "
                         "(validate_snapshot schema) as JSON on exit")
    # multi-host
    ap.add_argument("--coordinator", default=None,
                    help="host:port of jax.distributed coordinator")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    return ap


def main(argv: Optional[list[str]] = None):
    args = build_argparser().parse_args(argv)

    if args.coordinator:
        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_processes,
            process_id=args.process_id,
        )

    cfg = get_config(args.arch, quant_mode=args.quant_mode, n_experts=args.n_experts)
    if args.reduced:
        cfg = reduced(cfg)
    if args.dtype:
        import dataclasses

        cfg = dataclasses.replace(cfg, dtype=args.dtype)

    host_count = jax.process_count()
    dcfg = DataConfig(
        seq_len=args.seq_len,
        global_batch=args.global_batch,
        host_index=jax.process_index(),
        host_count=host_count,
        seed=args.seed,
    )
    if args.data:
        source = TextFileSource([args.data])
        assert source.vocab <= cfg.vocab_size, "tokenizer vocab exceeds model"
    else:
        source = SyntheticSource(cfg.vocab_size, seed=args.seed)
    data = PrefetchIterator(source, dcfg)

    tcfg = TrainerConfig(
        total_steps=args.steps,
        log_every=args.log_every,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        accum=args.accum,
        seed=args.seed,
        peak_lr=args.peak_lr,
        probes=args.probes,
        sensitivity_every=args.sensitivity_every,
        trace_path=args.trace_jsonl,
        history_path=args.history_jsonl,
    )
    if not logging.getLogger().handlers:
        logging.basicConfig(
            level=logging.INFO, format="%(asctime)s %(name)s %(message)s"
        )
    trainer = Trainer(cfg, tcfg, data)
    history = trainer.run()
    data.close()
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(trainer.snapshot(), f, indent=2)
    final = [h for h in history if "loss" in h and "event" not in h]
    if final:
        logging.getLogger(__name__).info(
            "final loss: %.4f (recoveries: %d)",
            final[-1]["loss"], trainer.recoveries,
        )
    return history


if __name__ == "__main__":
    main()
