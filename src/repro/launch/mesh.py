"""Production meshes (assignment spec).

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — `pod` is pure
data parallelism across the DCI; `data` doubles as the FSDP axis for
parameters; `model` is tensor/expert parallelism.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over locally available devices (tests / CPU examples).

    Validates the requested shape against the visible device count before
    handing off to jax, so a bad request fails with an actionable message
    instead of an opaque mesh-construction error.
    """
    if data < 1 or model < 1:
        raise ValueError(
            f"mesh axes must be positive, got data={data} model={model}")
    need = data * model
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"requested a {data}x{model} (data, model) mesh = {need} devices "
            f"but only {have} are visible; on CPU, force extra devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_from_env(var: str = "REPRO_MESH"):
    """Build a host mesh from ``REPRO_MESH=data,model`` (e.g. ``1,2``).

    Returns None when the variable is unset or empty, so call sites can do
    ``mesh = mesh_from_env()`` and fall through to unsharded serving.
    """
    import os

    spec = os.environ.get(var, "").strip()
    if not spec:
        return None
    parts = spec.replace("x", ",").split(",")
    if len(parts) != 2:
        raise ValueError(
            f"{var} must be 'data,model' (e.g. '1,2'), got {spec!r}")
    try:
        data, model = (int(p) for p in parts)
    except ValueError:
        raise ValueError(
            f"{var} must hold two integers 'data,model', got {spec!r}")
    return make_host_mesh(data, model)


def mesh_chip_count(mesh) -> int:
    import numpy as np

    return int(np.prod(list(mesh.shape.values())))
