"""Production meshes (assignment spec).

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — `pod` is pure
data parallelism across the DCI; `data` doubles as the FSDP axis for
parameters; `model` is tensor/expert parallelism.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over locally available devices (tests / CPU examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_chip_count(mesh) -> int:
    import numpy as np

    return int(np.prod(list(mesh.shape.values())))
