"""Render dry-run / roofline JSON artifacts into the EXPERIMENTS.md tables."""

from __future__ import annotations

import json
import sys


def fmt_bytes(b: float) -> str:
    if b >= 2**30:
        return f"{b/2**30:.2f}G"
    if b >= 2**20:
        return f"{b/2**20:.1f}M"
    return f"{b/2**10:.0f}K"


def roofline_table(path: str) -> str:
    recs = [r for r in json.load(open(path)) if "error" not in r]
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "MODEL_FLOPS | useful-FLOPs | peak GiB/dev | bound step s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['bottleneck']}** | {r['model_flops']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['memory']['peak_bytes']/2**30:.2f} | "
            f"{r['step_time_lower_bound_s']:.3f} |"
        )
    return "\n".join(lines)


def dryrun_table(path: str) -> str:
    recs = [r for r in json.load(open(path)) if "error" not in r]
    lines = [
        "| arch | shape | mesh | compile s | HLO FLOPs/dev | peak GiB/dev | "
        "AG | AR | RS | A2A/CP |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        c = r["collective_bytes_per_device"]
        a2a = c.get("all-to-all", 0) + c.get("collective-permute", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} | "
            f"{r['flops_total']:.2e} | {r['memory']['peak_bytes']/2**30:.2f} | "
            f"{fmt_bytes(c.get('all-gather', 0))} | "
            f"{fmt_bytes(c.get('all-reduce', 0))} | "
            f"{fmt_bytes(c.get('reduce-scatter', 0))} | {fmt_bytes(a2a)} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    kind, path = sys.argv[1], sys.argv[2]
    print(roofline_table(path) if kind == "roofline" else dryrun_table(path))
