"""Supervised training orchestrator — the fault-tolerance wrapper.

At 1000+ nodes, worker failure is routine; the contract is: (1) training
state is never lost (atomic async checkpoints), (2) a failed/preempted
worker set restarts from the latest manifest with zero operator action,
(3) stragglers are detected by heartbeat timeout and treated as failures.

This module supervises a training subprocess per host:
  * heartbeat file touched by the trainer every log interval;
  * if the heartbeat goes stale (straggler/hang) the process is killed and
    relaunched — it resumes from the last checkpoint;
  * crash exit codes trigger the same restart path with backoff;
  * a restart budget bounds flapping.

Elastic scaling: because checkpoints store logical (unsharded) arrays with
a structure manifest (repro.checkpoint), a restart may use a DIFFERENT
process count / mesh — re-sharding happens at restore.  ``--grow`` /
``--shrink`` simply change the flag set across restarts.
"""

from __future__ import annotations

import argparse
import os
import signal
import subprocess
import sys
import time


def run_supervised(
    cmd: list[str],
    heartbeat_path: str,
    heartbeat_timeout: float = 300.0,
    max_restarts: int = 10,
    backoff_s: float = 5.0,
) -> int:
    """Supervise ``cmd`` with heartbeat-based hang detection and restart."""
    restarts = 0
    while True:
        if os.path.exists(heartbeat_path):
            os.remove(heartbeat_path)
        print(f"[orchestrator] launching (attempt {restarts + 1}): {' '.join(cmd)}")
        proc = subprocess.Popen(cmd)
        failed = False
        while True:
            try:
                rc = proc.wait(timeout=10.0)
                if rc == 0:
                    print("[orchestrator] clean exit")
                    return 0
                print(f"[orchestrator] crashed rc={rc}")
                failed = True
                break
            except subprocess.TimeoutExpired:
                pass
            # straggler / hang detection
            if os.path.exists(heartbeat_path):
                age = time.time() - os.path.getmtime(heartbeat_path)
                if age > heartbeat_timeout:
                    print(f"[orchestrator] heartbeat stale ({age:.0f}s) — "
                          "treating as straggler, restarting")
                    proc.send_signal(signal.SIGKILL)
                    proc.wait()
                    failed = True
                    break
        if failed:
            restarts += 1
            if restarts > max_restarts:
                print("[orchestrator] restart budget exhausted")
                return 1
            time.sleep(backoff_s * min(restarts, 5))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--heartbeat", default="/tmp/repro_heartbeat")
    ap.add_argument("--heartbeat-timeout", type=float, default=300.0)
    ap.add_argument("--max-restarts", type=int, default=10)
    ap.add_argument("cmd", nargs=argparse.REMAINDER,
                    help="training command (e.g. python -m repro.launch.train ...)")
    args = ap.parse_args()
    cmd = args.cmd[1:] if args.cmd and args.cmd[0] == "--" else args.cmd
    sys.exit(
        run_supervised(cmd, args.heartbeat, args.heartbeat_timeout,
                       args.max_restarts)
    )


if __name__ == "__main__":
    main()
