import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture x input shape) cell and each production mesh
(16x16 single-pod, 2x16x16 multi-pod), lower + compile the real train_step
(train shapes) or serve_step (decode shapes) against ShapeDtypeStruct
inputs, then record:
  * memory_analysis()      — per-device bytes (does it fit HBM)
  * cost_analysis()        — HLO FLOPs / bytes accessed (roofline §compute/§memory)
  * collective bytes       — parsed from the optimized HLO (roofline §collective)

Usage:
  python -m repro.launch.dryrun --arch granite-20b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""

import argparse
import json
import re
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig, shapes_for
from repro.configs.registry import ASSIGNED, get_config
from repro.distributed.sharding import (
    param_sharding_for,
    sharding_rules,
)
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.models import api
from repro.train.serve import make_serve_step
from repro.train.trainer import make_train_step, train_state_shape_and_axes

# ---------------------------------------------------------------------------
# Collective-byte accounting from optimized HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9untpd\[\]{},\- ]+?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes per collective op kind (per-device view:
    optimized HLO after SPMD partitioning has per-shard shapes)."""
    out: dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


def lower_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    rule_overrides: Optional[dict] = None,
    serve_quant: Optional[str] = None,  # None | "int8" | "packed"
):
    """Lower + compile one (arch x shape x mesh) cell.

    serve_quant: for prefill/decode cells, lower against the integer
    serving weight layout (train/quantized_serving) instead of FP latents.

    Returns (lowered, compiled, seconds).
    """
    overrides = dict(rule_overrides or {})
    if shape.name == "long_500k":
        # batch=1: shard the KV-cache sequence dim over `data` instead
        overrides.setdefault("cache_seq", "data")

    def get_params_shapes():
        if serve_quant:
            from repro.train.quantized_serving import serving_params_shape_and_axes

            return serving_params_shape_and_axes(cfg, packed=serve_quant == "packed")
        return api.params_shape_and_axes(cfg)

    specs, spec_axes = api.input_specs(cfg, shape)
    t0 = time.time()
    with sharding_rules(mesh, overrides):
        if shape.kind == "train":
            state_shapes, state_axes = train_state_shape_and_axes(cfg)
            state_sh = param_sharding_for(state_shapes, state_axes, mesh)
            batch_sh = param_sharding_for(specs, spec_axes, mesh)
            step = make_train_step(cfg, total_steps=10000)
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_shapes, specs)
        elif shape.kind == "prefill":
            state_shapes, state_axes = None, None
            p_shapes, p_axes = get_params_shapes()
            p_sh = param_sharding_for(p_shapes, p_axes, mesh)
            batch_sh = param_sharding_for(specs, spec_axes, mesh)
            from repro.train.serve import make_prefill_step

            step = make_prefill_step(cfg, cache_len=shape.seq_len)
            jitted = jax.jit(step, in_shardings=(p_sh, batch_sh))
            lowered = jitted.lower(p_shapes, specs)
        else:  # decode
            p_shapes, p_axes = get_params_shapes()
            p_sh = param_sharding_for(p_shapes, p_axes, mesh)
            tok_sh = param_sharding_for(
                {"tokens": specs["tokens"]}, {"tokens": spec_axes["tokens"]}, mesh
            )["tokens"]
            cache_sh = param_sharding_for(
                specs["caches"], spec_axes["caches"], mesh
            )
            step = make_serve_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, tok_sh, cache_sh, None),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(
                p_shapes, specs["tokens"], specs["caches"], specs["pos"]
            )
        compiled = lowered.compile()
    return lowered, compiled, time.time() - t0


def analyze_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, rule_overrides=None,
                 serve_quant=None):
    lowered, compiled, secs = lower_cell(cfg, shape, mesh, rule_overrides, serve_quant)
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    chips = mesh_chip_count(mesh)
    result = {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": "x".join(str(v) for v in mesh.shape.values()),
        "chips": chips,
        "compile_s": round(secs, 1),
        "flops_total": float(cost.get("flops", 0.0)),
        "bytes_accessed_total": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
    }
    return result, lowered, compiled


def run_cells(
    archs: list[str],
    shape_names: Optional[list[str]],
    multi_pod: bool,
    quant_mode: str,
    n_experts: int,
    out_path: Optional[str],
    rule_overrides: Optional[dict] = None,
):
    mesh = make_production_mesh(multi_pod=multi_pod)
    results = []
    for arch in archs:
        cfg = get_config(arch, quant_mode=quant_mode, n_experts=n_experts)
        for shape in shapes_for(cfg):
            if shape_names and shape.name not in shape_names:
                continue
            tag = f"{arch} x {shape.name} x {'2x16x16' if multi_pod else '16x16'}"
            try:
                res, lowered, compiled = analyze_cell(cfg, shape, mesh, rule_overrides)
                coll_total = sum(res["collective_bytes_per_device"].values())
                print(
                    f"[OK]   {tag}: compile {res['compile_s']}s, "
                    f"{res['flops_total']:.3e} FLOPs, "
                    f"peak {res['memory']['peak_bytes']/2**30:.2f} GiB/dev, "
                    f"coll {coll_total/2**20:.1f} MiB/dev"
                )
                results.append(res)
                del lowered, compiled
            except Exception as e:  # noqa: BLE001 — report and continue
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
                results.append(
                    {"arch": arch, "shape": shape.name, "error": f"{type(e).__name__}: {e}"}
                )
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {out_path}")
    n_fail = sum(1 for r in results if "error" in r)
    print(f"== {len(results) - n_fail}/{len(results)} cells OK ==")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name (default: all)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quant-mode", default="pquant",
                    choices=["pquant", "bitnet", "bitnet158", "none"])
    ap.add_argument("--n-experts", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else None
    run_cells(archs, shapes, args.multi_pod, args.quant_mode,
              args.n_experts, args.out)


if __name__ == "__main__":
    main()
