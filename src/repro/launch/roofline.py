import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

"""Roofline analysis per (arch x shape) on the single-pod production mesh.

Terms (per assignment, TPU v5e):
    compute term    = HLO_FLOPs / (chips * 197 TFLOP/s)
    memory term     = HLO_bytes / (chips * 819 GB/s)
    collective term = collective_bytes / (chips * 50 GB/s/link)
      (all-reduce counted 2x: reduce-scatter + all-gather phases)

METHODOLOGY NOTE (scan calibration): XLA's cost_analysis counts a
``lax.scan`` body ONCE, not trip-count times, and the HLO text likewise
shows in-body collectives once.  The deliverable compile (scan-over-layers,
full depth) proves the cell compiles and fits memory; the *costs* are
derived from two small UNROLLED compiles (1-group and 2-group deep) on the
same mesh: per-group cost = diff, outside cost = intercept, and
    corrected_total = outside + n_groups * per_group.
Group = the layer-pattern period (1 dense layer; 6 for gemma3's 5:1
local:global; 3 for recurrentgemma's rec/rec/attn; enc+dec pair for
whisper).  Remainder layers (gemma3: 62 = 10*6+2) are charged at the group
average (<2% error, noted per-cell).

MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (prefill, decode), with
N_active = analytic active matmul params (MoE counts shared + top-k only).
"""

import argparse
import dataclasses
import json
from typing import Optional

import jax

from repro.configs.base import ModelConfig, ShapeConfig, param_count, shapes_for
from repro.configs.registry import ASSIGNED, get_config
from repro.launch.dryrun import analyze_cell
from repro.launch.mesh import make_production_mesh

HW = {
    "peak_flops": 197e12,  # bf16 per chip (int8 is 2x — noted, not assumed)
    "hbm_bw": 819e9,
    "link_bw": 50e9,
}


# ---------------------------------------------------------------------------
# Analytic active-parameter count (for MODEL_FLOPS)
# ---------------------------------------------------------------------------


def active_matmul_params(cfg: ModelConfig) -> float:
    """Matmul-visible parameters touched per token (MoE: top-k + shared)."""
    pc = param_count(cfg)
    total = pc["total"]
    # embedding lookup is not a matmul; the LM head is
    embed = cfg.vocab_size * cfg.d_model
    total -= embed if cfg.tie_embeddings else 2 * embed
    total += cfg.vocab_size * cfg.d_model  # head matmul
    if cfg.moe:
        mats = 3 if cfg.glu else 2
        per_expert = mats * cfg.d_model * cfg.d_ff_expert
        n_moe_layers = cfg.n_layers - cfg.first_k_dense
        inactive = (cfg.n_routed_experts - cfg.moe_top_k) * per_expert * n_moe_layers
        total -= inactive
    q = cfg.quant
    if q.mode == "pquant" and q.num_experts > 1:
        mats = 3 if cfg.glu else 2
        per_branch = mats * cfg.d_model * q.r
        n_ffn_layers = cfg.n_layers + cfg.n_enc_layers
        total -= (q.num_experts - 1) * per_branch * n_ffn_layers
    return float(total)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n = active_matmul_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


# ---------------------------------------------------------------------------
# Calibration configs
# ---------------------------------------------------------------------------


def group_size(cfg: ModelConfig) -> int:
    if cfg.global_every > 0:
        return cfg.global_every
    if cfg.block_pattern:
        return len(cfg.block_pattern)
    return 1


def calib_config(cfg: ModelConfig, groups: int) -> ModelConfig:
    g = group_size(cfg)
    repl = {
        "n_layers": cfg.first_k_dense * int(cfg.moe) + groups * g,
        "scan_layers": False,
    }
    if cfg.family == "encdec":
        repl["n_enc_layers"] = groups
        repl["n_layers"] = groups
    return dataclasses.replace(cfg, **repl)


def n_groups_full(cfg: ModelConfig) -> float:
    g = group_size(cfg)
    layers = cfg.n_layers - (cfg.first_k_dense if cfg.moe else 0)
    return layers / g  # fractional remainder charged at group average


# ---------------------------------------------------------------------------
# Roofline per cell
# ---------------------------------------------------------------------------


def _coll_total(coll: dict) -> float:
    """Collective seconds numerator: AR counts 2x (RS + AG phases)."""
    t = 0.0
    for kind, b in coll.items():
        t += 2.0 * b if kind == "all-reduce" else float(b)
    return t


def roofline_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    rule_overrides: Optional[dict] = None,
    full_result: Optional[dict] = None,
    serve_quant=None,
):
    """Returns the full roofline record for one cell."""
    # 1. deliverable compile (scan, full depth): memory + compiles-at-all
    if full_result is None:
        full_result, _, _ = analyze_cell(cfg, shape, mesh, rule_overrides,
                                         serve_quant)

    # 2. calibration pair (unrolled, small)
    c1, _, _ = analyze_cell(calib_config(cfg, 1), shape, mesh, rule_overrides,
                            serve_quant)
    c2, _, _ = analyze_cell(calib_config(cfg, 2), shape, mesh, rule_overrides,
                            serve_quant)

    def corrected(key, sub=None):
        v1 = c1[key] if sub is None else c1[key].get(sub, 0)
        v2 = c2[key] if sub is None else c2[key].get(sub, 0)
        per_group = v2 - v1
        outside = v1 - per_group
        # clamp: when a term is near zero, layout noise between the two
        # calibration compiles can extrapolate slightly negative
        return max(0.0, outside + n_groups_full(cfg) * per_group)

    flops_dev = corrected("flops_total")
    bytes_dev = corrected("bytes_accessed_total")
    coll_kinds = set(c1["collective_bytes_per_device"]) | set(
        c2["collective_bytes_per_device"]
    )
    coll_dev = {k: corrected("collective_bytes_per_device", k) for k in coll_kinds}

    # DTYPE CORRECTION: the CPU backend upcasts every bf16 tensor to f32
    # during lowering (CPU dots don't support bf16), so raw HLO byte counts
    # are ~2x what the TPU artifact moves.  Principal tensors (activations,
    # forward weights, collective payloads) are bf16 on TPU; fp32 survives
    # only in scalar stats + optimizer slots (<10% of traffic).  We report
    # the /2-corrected terms and keep raw values alongside.
    BF16_CORR = 0.5
    compute_s = flops_dev / HW["peak_flops"]
    memory_s = bytes_dev * BF16_CORR / HW["hbm_bw"]
    collective_s = _coll_total(coll_dev) * BF16_CORR / HW["link_bw"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    chips = full_result["chips"]
    hlo_flops_global = flops_dev * chips

    # roofline fraction: how close the cell is to its compute roofline —
    # the fraction of the bound step time spent at peak FLOPs.  1.0 means
    # compute-bound at peak; lower means memory/collective overhang.
    return {
        **full_result,
        "flops_per_device_corrected": flops_dev,
        "bytes_per_device_raw": bytes_dev,
        "collective_bytes_corrected": coll_dev,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "useful_flops_ratio": mf / hlo_flops_global if hlo_flops_global else 0.0,
        "roofline_fraction": terms["compute"] / max(terms.values()),
        "model_roofline_fraction": (mf / full_result["chips"] / HW["peak_flops"])
        / max(terms.values()),
        "step_time_lower_bound_s": max(terms.values()),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--quant-mode", default="pquant")
    ap.add_argument("--n-experts", type=int, default=1)
    ap.add_argument("--out", default="roofline.json")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=False)
    archs = [args.arch] if args.arch else ASSIGNED
    results = []
    for arch in archs:
        cfg = get_config(arch, quant_mode=args.quant_mode, n_experts=args.n_experts)
        for shape in shapes_for(cfg):
            if args.shape and shape.name != args.shape:
                continue
            tag = f"{arch} x {shape.name}"
            try:
                rec = roofline_cell(cfg, shape, mesh)
                print(
                    f"[OK] {tag}: compute {rec['compute_s']*1e3:.1f}ms "
                    f"memory {rec['memory_s']*1e3:.1f}ms "
                    f"coll {rec['collective_s']*1e3:.1f}ms "
                    f"-> {rec['bottleneck']}-bound, "
                    f"useful-FLOPs {rec['useful_flops_ratio']:.2f}"
                )
                results.append(rec)
            except Exception as e:  # noqa: BLE001
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
                results.append({"arch": arch, "shape": shape.name,
                                "error": f"{type(e).__name__}: {e}"})
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
