"""Training loop: QAT train_step (pjit-ready), gradient accumulation,
checkpoint/restart, and the single-host Trainer used by examples/.

train_step semantics (paper §3.1 / Appendix B): latent master weights are
FP32; the forward pass casts to the model dtype (bf16) and fake-quantizes
(weights 1-bit / INT8, activations INT8) with STE gradients; AdamW with the
two-phase LR/WD schedule updates the FP32 latents.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig
from repro.models import api
from repro.optim.adamw import (
    AdamWConfig,
    AdamWState,
    adamw_state_axes,
    adamw_update,
    init_adamw,
)
from repro.optim.schedule import schedule_for_mode

Array = jax.Array


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(key: Array, cfg: ModelConfig) -> tuple[TrainState, Any]:
    """Returns (state, state_axes) — axes mirror the state for sharding."""
    params, axes = api.init_model(key, cfg)
    state = TrainState(params=params, opt=init_adamw(params))
    state_axes = TrainState(params=axes, opt=adamw_state_axes(axes))
    return state, state_axes


def train_state_shape_and_axes(cfg: ModelConfig):
    """ShapeDtypeStructs + axes without allocation (dry-run path)."""
    axes_box = {}

    def f(key):
        state, state_axes = init_train_state(key, cfg)
        axes_box["axes"] = state_axes
        return state

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, axes_box["axes"]


def cast_for_forward(params, dtype):
    """Latent FP32 master -> model dtype for the quantized forward pass."""
    if dtype == jnp.float32:
        return params

    def cast(p):
        return p.astype(dtype) if p.dtype == jnp.float32 else p

    return jax.tree.map(cast, params)


def make_train_step(
    cfg: ModelConfig,
    total_steps: int,
    accum: int = 1,
    adamw_cfg: AdamWConfig = AdamWConfig(),
    peak_lr: Optional[float] = None,
) -> Callable:
    """Build the (jit-able) train_step(state, batch) -> (state, metrics).

    ``accum`` > 1 splits the batch into microbatches scanned sequentially
    with FP32 gradient accumulation (memory relief at fixed global batch).
    """
    sched = schedule_for_mode(cfg.quant.mode, total_steps, peak_lr)
    model_dtype = jnp.dtype(cfg.dtype)

    def loss_fn(params, batch):
        fwd_params = cast_for_forward(params, model_dtype)
        loss, metrics = api.loss_fn(fwd_params, batch, cfg)
        return loss, metrics

    def grads_one(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def compute_grads(params, batch):
        if accum == 1:
            return grads_one(params, batch)
        # microbatch scan: leading batch dim must divide by accum
        def split(x):
            b = x.shape[0]
            assert b % accum == 0, (b, accum)
            return x.reshape(accum, b // accum, *x.shape[1:])

        micro = jax.tree.map(split, batch)
        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, metrics, g = grads_one(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / accum, g_acc, g
            )
            return (loss_acc + loss / accum, g_acc), metrics

        (loss, grads), metrics = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zero_g), micro
        )
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss, metrics, grads

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, metrics, grads = compute_grads(state.params, batch)
        step = state.opt.step
        lr = sched.lr(step)
        wd = sched.wd(step)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, lr, wd, adamw_cfg
        )
        out_metrics = {
            "loss": loss.astype(jnp.float32),
            "nll": metrics["nll"].astype(jnp.float32),
            **opt_metrics,
        }
        return TrainState(params=new_params, opt=new_opt), out_metrics

    return train_step


# ---------------------------------------------------------------------------
# Single-host Trainer (examples / paper-claim benchmarks)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 200
    log_every: int = 10
    ckpt_every: int = 100
    ckpt_dir: Optional[str] = None
    accum: int = 1
    seed: int = 0
    peak_lr: Optional[float] = None
    # fault tolerance: reload last checkpoint if loss goes non-finite
    # (paper Fig. 10: BitNet needs this; pQuant shouldn't)
    auto_recover: bool = True
    # heartbeat file for the orchestrator's straggler/hang detection
    heartbeat_path: Optional[str] = os.environ.get("REPRO_HEARTBEAT")


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig, data_iter):
        self.cfg, self.tcfg = cfg, tcfg
        self.data = data_iter
        self.state, self.state_axes = init_train_state(
            jax.random.PRNGKey(tcfg.seed), cfg
        )
        self.step_fn = jax.jit(
            make_train_step(cfg, tcfg.total_steps, tcfg.accum, peak_lr=tcfg.peak_lr),
            donate_argnums=(0,),
        )
        self.ckpt = Checkpointer(tcfg.ckpt_dir) if tcfg.ckpt_dir else None
        self.history: list[dict] = []
        self.recoveries = 0
        self.start_step = 0
        if self.ckpt and self.ckpt.latest_step() is not None:
            self._restore()

    def _restore(self, step: Optional[int] = None):
        restored = self.ckpt.restore(self.state._asdict(), step=step)
        self.state = TrainState(**restored)
        self.start_step = int(self.state.opt.step)

    def run(self) -> list[dict]:
        t_last = time.time()
        for step, batch in self.data:
            if step < self.start_step:
                continue
            if step >= self.tcfg.total_steps:
                break
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            self.state, metrics = self.step_fn(self.state, jb)
            loss = float(metrics["loss"])
            if not np.isfinite(loss) and self.tcfg.auto_recover and self.ckpt:
                # fault path: reload last good checkpoint (paper Fig. 10)
                self.recoveries += 1
                self._restore()
                continue
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step"] = step
            self.history.append(rec)
            if self.tcfg.heartbeat_path:
                with open(self.tcfg.heartbeat_path, "w") as hb:
                    hb.write(str(step))
            if step % self.tcfg.log_every == 0:
                dt = time.time() - t_last
                t_last = time.time()
                print(
                    f"step {step:5d} loss {rec['loss']:.4f} nll {rec['nll']:.4f} "
                    f"lr {rec['lr']:.2e} gnorm {rec['grad_norm']:.2f} ({dt:.1f}s)"
                )
            if self.ckpt and step > 0 and step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(step, self.state._asdict())
        if self.ckpt:
            self.ckpt.save(int(self.state.opt.step), self.state._asdict())
            self.ckpt.wait()
        return self.history
