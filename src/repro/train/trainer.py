"""Training loop: QAT train_step (pjit-ready), gradient accumulation,
checkpoint/restart, and the single-host Trainer used by examples/.

train_step semantics (paper §3.1 / Appendix B): latent master weights are
FP32; the forward pass casts to the model dtype (bf16) and fake-quantizes
(weights 1-bit / INT8, activations INT8) with STE gradients; AdamW with the
two-phase LR/WD schedule updates the FP32 latents.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import tempfile
import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig
from repro.models import api
from repro.optim.adamw import (
    AdamWConfig,
    AdamWState,
    adamw_state_axes,
    adamw_update,
    init_adamw,
)
from repro.optim.schedule import schedule_for_mode
from repro.telemetry import probes as qprobes
from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.tracing import JsonlSink, TrainTracer, annotate, maybe_profile

Array = jax.Array

_log = logging.getLogger(__name__)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(key: Array, cfg: ModelConfig) -> tuple[TrainState, Any]:
    """Returns (state, state_axes) — axes mirror the state for sharding."""
    params, axes = api.init_model(key, cfg)
    state = TrainState(params=params, opt=init_adamw(params))
    state_axes = TrainState(params=axes, opt=adamw_state_axes(axes))
    return state, state_axes


def train_state_shape_and_axes(cfg: ModelConfig):
    """ShapeDtypeStructs + axes without allocation (dry-run path)."""
    axes_box = {}

    def f(key):
        state, state_axes = init_train_state(key, cfg)
        axes_box["axes"] = state_axes
        return state

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, axes_box["axes"]


def cast_for_forward(params, dtype):
    """Latent FP32 master -> model dtype for the quantized forward pass."""
    if dtype == jnp.float32:
        return params

    def cast(p):
        return p.astype(dtype) if p.dtype == jnp.float32 else p

    return jax.tree.map(cast, params)


def make_train_step(
    cfg: ModelConfig,
    total_steps: int,
    accum: int = 1,
    adamw_cfg: AdamWConfig = AdamWConfig(),
    peak_lr: Optional[float] = None,
    probes: bool = False,
) -> Callable:
    """Build the (jit-able) train_step(state, batch) -> (state, metrics).

    ``accum`` > 1 splits the batch into microbatches scanned sequentially
    with FP32 gradient accumulation (memory relief at fixed global batch).

    ``probes=True`` adds the on-device QAT health probes (sign-flip /
    clip / scale-drift / branch-share / grad-split / router-entropy —
    name registry in ``repro.telemetry``) to the metrics dict.  The flag
    is a static Python gate: with ``probes=False`` no probe op is ever
    staged, so the lowered program is byte-identical to a probe-unaware
    build (pinned by ``tests/test_train_telemetry.py``).  The profiler
    annotations below are metadata-only and applied unconditionally,
    exactly like the serving stack's (PR 7 invariant).
    """
    sched = schedule_for_mode(cfg.quant.mode, total_steps, peak_lr)
    model_dtype = jnp.dtype(cfg.dtype)
    # the encdec family runs its own layer scan without probe drain
    # points, so forward taps would leak scan tracers there — force off
    probes_on = bool(probes) and cfg.family != "encdec"

    def loss_fn(params, batch):
        fwd_params = cast_for_forward(params, model_dtype)
        if probes_on:
            with qprobes.collect():
                return api.loss_fn(fwd_params, batch, cfg)
        return api.loss_fn(fwd_params, batch, cfg)

    def grads_one(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def compute_grads(params, batch):
        if accum == 1:
            return grads_one(params, batch)
        # microbatch scan: leading batch dim must divide by accum
        def split(x):
            b = x.shape[0]
            assert b % accum == 0, (b, accum)
            return x.reshape(accum, b // accum, *x.shape[1:])

        micro = jax.tree.map(split, batch)
        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, metrics, g = grads_one(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / accum, g_acc, g
            )
            return (loss_acc + loss / accum, g_acc), metrics

        with annotate("train/accum"):
            (loss, grads), metrics = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_g), micro
            )
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss, metrics, grads

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        with annotate("train/grads"):
            loss, metrics, grads = compute_grads(state.params, batch)
        step = state.opt.step
        lr = sched.lr(step)
        wd = sched.wd(step)
        with annotate("train/update"):
            new_params, new_opt, opt_metrics = adamw_update(
                grads, state.opt, state.params, lr, wd, adamw_cfg
            )
        out_metrics = {
            "loss": loss.astype(jnp.float32),
            "nll": metrics["nll"].astype(jnp.float32),
            **opt_metrics,
        }
        if probes_on:
            # forward-tap probes folded into metrics by api.loss_fn ...
            out_metrics.update(
                {
                    k: v.astype(jnp.float32)
                    for k, v in metrics.items()
                    if k.startswith("qat_")
                }
            )
            # ... plus the param/grad-side probes, all on device: they
            # ride the existing metrics transfer (no extra host syncs)
            with annotate("train/probes"):
                out_metrics.update(
                    qprobes.train_step_probes(state.params, new_params, grads)
                )
        return TrainState(params=new_params, opt=new_opt), out_metrics

    return train_step


# ---------------------------------------------------------------------------
# Single-host Trainer (examples / paper-claim benchmarks)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 200
    log_every: int = 10
    ckpt_every: int = 100
    ckpt_dir: Optional[str] = None
    accum: int = 1
    seed: int = 0
    peak_lr: Optional[float] = None
    # fault tolerance: reload last checkpoint if loss goes non-finite
    # (paper Fig. 10: BitNet needs this; pQuant shouldn't)
    auto_recover: bool = True
    # heartbeat file for the orchestrator's straggler/hang detection
    heartbeat_path: Optional[str] = os.environ.get("REPRO_HEARTBEAT")
    # --- telemetry (name registry + trace format: repro.telemetry docs) ---
    # on-device QAT health probes in the per-step metrics dict
    probes: bool = False
    # cadence (steps) of the host-side democratization snapshot; 0 = off
    sensitivity_every: int = 0
    # JSONL run-lifecycle trace (TrainTracer); None = no trace
    trace_path: Optional[str] = None
    # stream history records to this JSONL path instead of growing an
    # unbounded host list (run() then returns an empty list)
    history_path: Optional[str] = None


def _write_atomic(path: str, text: str) -> None:
    """Crash-atomic small-file write: tmp in the same directory, fsync,
    ``os.replace`` (the ``tile_cache.store`` pattern) — a reader or a
    crash sees the old or the new content, never a torn write.  The
    heartbeat rides this: a torn heartbeat looks like a hang to the
    orchestrator's straggler detection."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    ok = False
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        ok = True
    finally:
        if not ok:
            try:
                os.unlink(tmp)
            except OSError:
                pass


class Trainer:
    """Single-host training loop with the shared observability tier:

    * ``metrics`` — a :class:`~repro.telemetry.metrics.MetricsRegistry`
      (own one by default, injectable for tests/aggregation) updated every
      step; :meth:`snapshot` exports the CI-validated schema and
      ``metrics.prometheus_text()`` the scrape format.
    * ``tracer`` — a :class:`~repro.telemetry.tracing.TrainTracer` wired
      to ``tcfg.trace_path`` (or injected) streaming the run lifecycle as
      JSONL: step records, checkpoint/restore/recovery events, heartbeats.
    * console output goes through ``logging`` (logger ``repro.train``):
      the human one-liner at ``log_every`` on INFO, a structured JSON
      record per step on DEBUG.
    * ``REPRO_PROFILE_DIR`` captures a profiler trace of :meth:`run` with
      ``train/grads`` / ``train/accum`` / ``train/update`` annotations.

    All of it detaches cleanly: no registry/tracer and ``probes=False``
    reproduce the bare loop, with ``train_step`` lowering byte-identical.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainerConfig,
        data_iter,
        metrics: Optional[MetricsRegistry] = None,
        tracer: Optional[TrainTracer] = None,
    ):
        self.cfg, self.tcfg = cfg, tcfg
        self.data = data_iter
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._owns_tracer = tracer is None and tcfg.trace_path is not None
        if tracer is not None:
            self.tracer = tracer
        elif tcfg.trace_path:
            self.tracer = TrainTracer(JsonlSink(tcfg.trace_path))
        else:
            self.tracer = None
        self.state, self.state_axes = init_train_state(
            jax.random.PRNGKey(tcfg.seed), cfg
        )
        self.step_fn = jax.jit(
            make_train_step(
                cfg,
                tcfg.total_steps,
                tcfg.accum,
                peak_lr=tcfg.peak_lr,
                probes=tcfg.probes,
            ),
            donate_argnums=(0,),
        )
        self.ckpt = Checkpointer(tcfg.ckpt_dir) if tcfg.ckpt_dir else None
        self.history: list[dict] = []
        self.recoveries = 0
        self.start_step = 0
        if self.ckpt and self.ckpt.latest_step() is not None:
            self._restore()

    def _restore(self, step: Optional[int] = None):
        restored = self.ckpt.restore(self.state._asdict(), step=step)
        self.state = TrainState(**restored)
        self.start_step = int(self.state.opt.step)
        self.metrics.counter("train_restores_total").inc()
        if self.tracer:
            self.tracer.emit("restore", step=self.start_step,
                             from_step=self.start_step)

    def snapshot(self) -> dict:
        """The run's metrics in the CI-validated snapshot schema
        (:func:`repro.telemetry.metrics.validate_snapshot`)."""
        return self.metrics.snapshot()

    def _record(self, rec: dict, hist_f) -> None:
        """History record: streamed as JSONL (``history_path``) or
        appended to the in-memory list; mirrored to the tracer and to
        the per-step DEBUG log."""
        if hist_f is not None:
            hist_f.write(json.dumps(rec, sort_keys=True) + "\n")
            hist_f.flush()
        else:
            self.history.append(rec)
        if self.tracer:
            event = rec.get("event", "step")
            fields = {k: v for k, v in rec.items()
                      if k not in ("step", "event")}
            self.tracer.emit(event, step=rec["step"], **fields)
        if _log.isEnabledFor(logging.DEBUG):
            _log.debug("%s", json.dumps(rec, sort_keys=True))

    def _gauges(self, rec: dict) -> None:
        g = self.metrics.gauge
        for k, v in rec.items():
            if k == "step":
                g("train_step").set(v)
            elif k in ("loss", "nll", "lr", "wd", "grad_norm"):
                g("train_" + k).set(v)
            elif k.startswith(("qat_", "demo_")):
                g(k).set(v)

    def run(self) -> list[dict]:
        tcfg = self.tcfg
        hist_f = open(tcfg.history_path, "a") if tcfg.history_path else None
        steps_total = self.metrics.counter("train_steps_total")
        step_seconds = self.metrics.histogram("train_step_seconds")
        if self.tracer:
            self.tracer.emit(
                "run_start", step=self.start_step, arch=self.cfg.name,
                quant=self.cfg.quant.mode, total_steps=tcfg.total_steps,
            )
        t_last = time.time()
        try:
            with maybe_profile("train"):
                for step, batch in self.data:
                    if step < self.start_step:
                        continue
                    if step >= tcfg.total_steps:
                        break
                    jb = {k: jnp.asarray(v) for k, v in batch.items()}
                    t0 = time.time()
                    self.state, metrics = self.step_fn(self.state, jb)
                    loss = float(metrics["loss"])  # the one host sync
                    dt_step = time.time() - t0
                    if not np.isfinite(loss) and tcfg.auto_recover and self.ckpt:
                        # fault path: reload last good ckpt (paper Fig. 10)
                        # — recorded, not silent: the history/trace carry
                        # (step, restored-from step, running count)
                        self.recoveries += 1
                        self._restore()
                        self.metrics.counter("train_recoveries_total").inc()
                        rec = {
                            "step": step, "event": "recovery", "loss": loss,
                            "from_step": self.start_step,
                            "recoveries": self.recoveries,
                        }
                        self._record(rec, hist_f)
                        _log.warning(
                            "step %d: non-finite loss, restored from step %d "
                            "(recovery #%d)",
                            step, self.start_step, self.recoveries,
                        )
                        continue
                    rec = {k: float(v) for k, v in metrics.items()}
                    rec["step"] = step
                    rec["step_time_s"] = dt_step
                    if (
                        tcfg.sensitivity_every > 0
                        and step % tcfg.sensitivity_every == 0
                    ):
                        # cadenced democratization snapshot — host-side,
                        # off the jit path (repro.telemetry.probes)
                        rec.update(
                            qprobes.sensitivity_snapshot(self.state.params)
                        )
                    self._record(rec, hist_f)
                    steps_total.inc()
                    step_seconds.observe(dt_step)
                    self._gauges(rec)
                    if tcfg.heartbeat_path:
                        _write_atomic(tcfg.heartbeat_path, str(step))
                    if step % tcfg.log_every == 0:
                        dt = time.time() - t_last
                        t_last = time.time()
                        _log.info(
                            "step %5d loss %.4f nll %.4f lr %.2e gnorm %.2f "
                            "(%.1fs)", step, rec["loss"], rec["nll"],
                            rec["lr"], rec["grad_norm"], dt,
                        )
                        if self.tracer:
                            self.tracer.emit("heartbeat", step=step)
                    if self.ckpt and step > 0 and step % tcfg.ckpt_every == 0:
                        self.ckpt.save(step, self.state._asdict())
                        self.metrics.counter("train_checkpoints_total").inc()
                        if self.tracer:
                            self.tracer.emit("checkpoint", step=step)
            if self.ckpt:
                final = int(self.state.opt.step)
                self.ckpt.save(final, self.state._asdict())
                self.ckpt.wait()
                self.metrics.counter("train_checkpoints_total").inc()
                if self.tracer:
                    self.tracer.emit("checkpoint", step=final)
            if self.tracer:
                self.tracer.emit(
                    "run_end", step=int(self.state.opt.step),
                    recoveries=self.recoveries,
                )
        finally:
            if hist_f is not None:
                hist_f.close()
            if self._owns_tracer and self.tracer:
                self.tracer.close()
        return self.history
