"""Serving-time weight quantization: export latent FP weights to the
integer layout that actually lives in HBM (paper Appendix A).

Training keeps FP latents (fake-quant + STE).  For deployment, the 1-bit
backbone becomes INT8 signs (optionally bit-PACKED uint8, 8/byte = 16x
smaller than bf16) with one AbsMean scale; the 8-bit branch becomes INT8
with an AbsMax scale.  The model apply functions accept this layout
transparently (core.quantization._dequant_stored), so the dry-run's
compiled serve_step shows integer parameters in HBM and the memory-roofline
term drops accordingly (§Perf iteration A).

Weight classification is by parameter path name:
  1-bit backbone: attention projections, FFN trunk, MoE experts, SSM/RG-LRU
  projections.  8-bit branch: w8_*.  Everything else (embeddings, norms,
  scales, routers, RG-LRU gates, conv, SSD params) stays FP.

Shardability contract (tensor-parallel serving): every exported weight is
N-major-shardable — the layout keeps N as the LAST axis (``packed`` is
``(..., K//8, N)`` uint8, ``q`` is ``(..., K, N)`` int8) so slicing the
last axis yields a valid shard of the same layout, and the AbsMean /
AbsMax ``scale`` is a per-tensor keepdims scalar (per slice for stacked
weights) that REPLICATES: a shard dequantizes with the same scalar as the
whole weight, making each per-shard kernel output a bitwise slice of the
unsharded result.  ``distributed.sharding.nmajor_param_sharding`` places
this export on a mesh (only the trailing logical axis shards) and the
``kernels.ops.*_nshard`` shard_map islands consume it with per-shard GEMV
tile keys — see ``tests/test_sharded_serving.py`` for the round-trip and
parity pins.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.packing import pack_signs

Array = jax.Array

# parent-key names of 1-bit backbone linears ({"w": array} wrappers)
INT1_WRAPPED = {
    "wq", "wk", "wv", "wo", "wq_down", "wq_up", "wkv_down", "wkv_up",
    "wx", "wy", "wout",
}
# direct-array leaf names
INT1_DIRECT = {"w1_gate", "w1_up", "w1_down", "we_up", "we_gate", "we_down", "w1"}
INT8_DIRECT = {"w8_gate", "w8_up", "w8_down", "w8_a", "w8_b"}


def _path_keys(path) -> list[str]:
    return [str(getattr(e, "key", getattr(e, "idx", ""))) for e in path]


def _binarize_export(w: Array, packed: bool, name: str = ""):
    """Latent -> {"q" | "packed", "scale"}; per-slice for stacked (layer- or
    expert-stacked) weights: ``pack_signs`` packs along the K (second-to-
    last) axis of every slice, so scanned layer stacks and MoE expert stacks
    bit-pack exactly like plain 2-D linears.  A K that isn't byte-aligned
    cannot pack (the kernels stream whole uint8 K-bytes); that case falls
    back to unpacked INT8 signs with an explicit warning instead of
    silently losing the 16x weight-traffic story."""
    red = tuple(range(max(0, w.ndim - 2), w.ndim))
    mu = jnp.mean(w, axis=red, keepdims=True)
    lam = (jnp.mean(jnp.abs(w), axis=red, keepdims=True) + 1e-5).astype(jnp.float32)
    signs = jnp.where(w - mu >= 0, jnp.int8(1), jnp.int8(-1))
    if packed:
        if w.shape[-2] % 8 == 0:
            return {"packed": pack_signs(signs), "scale": lam}
        warnings.warn(
            f"packed export of {name or 'a 1-bit weight'} "
            f"{tuple(w.shape)}: K={w.shape[-2]} is not a multiple of 8; "
            "storing unpacked INT8 signs (8x larger, no packed-kernel "
            "dispatch for this layer)",
            stacklevel=2,
        )
    return {"q": signs, "scale": lam}


def _int8_export(w: Array):
    red = tuple(range(max(0, w.ndim - 2), w.ndim))
    amax = jnp.max(jnp.abs(w), axis=red, keepdims=True) + 1e-5
    scale = (amax / 127.0).astype(jnp.float32)  # dequant multiplier
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale}


def quantize_params_for_serving(
    params, axes, cfg: ModelConfig, packed: bool = False
):
    """Transform (params, axes) into the integer serving layout.

    packed=True additionally bit-packs 1-bit weights 8/byte along the K
    axis — per slice for layer-scanned and expert-stacked weights, so the
    whole 1-bit backbone is kernel-consumable.  Weights whose K isn't a
    multiple of 8 fall back to unpacked INT8 signs with a warning.
    Returns (qparams, qaxes): axes mirror the new structure (the integer
    tensor keeps the latent's logical axes; scales are replicated).
    """
    if cfg.quant.mode == "none":
        return params, axes
    import jax.tree_util as jtu

    paths_and_leaves, treedef = jtu.tree_flatten_with_path(params)
    flat_axes = []
    new_leaves = []
    from repro.distributed.sharding import _lookup_path

    for path, leaf in paths_and_leaves:
        keys = _path_keys(path)
        leaf_axes = _lookup_path(axes, path)
        name = keys[-1]
        parent = keys[-2] if len(keys) >= 2 else ""
        is_int1 = name in INT1_DIRECT or (name == "w" and parent in INT1_WRAPPED)
        is_int8 = name in INT8_DIRECT
        if is_int1 and leaf.ndim >= 2:
            q = _binarize_export(leaf, packed, name="/".join(keys))
            if "packed" in q:
                # packed dim0 = K//8: same logical axis, 1/8 length
                qa = {"packed": tuple(leaf_axes), "scale": ((None,) * leaf.ndim)}
            else:
                qa = {"q": tuple(leaf_axes), "scale": ((None,) * leaf.ndim)}
            new_leaves.append(q)
            flat_axes.append(qa)
        elif is_int8 and leaf.ndim >= 2:
            new_leaves.append(_int8_export(leaf))
            flat_axes.append(
                {"q": tuple(leaf_axes), "scale": ((None,) * leaf.ndim)}
            )
        else:
            new_leaves.append(leaf)
            flat_axes.append(tuple(leaf_axes))
    qparams = jtu.tree_unflatten(treedef, new_leaves)
    qaxes = jtu.tree_unflatten(treedef, flat_axes)
    return qparams, qaxes


def serving_params_shape_and_axes(cfg: ModelConfig, packed: bool = False):
    """ShapeDtypeStructs + axes of the quantized serving layout, without
    allocating (dry-run path)."""
    from repro.models import api

    axes_box = {}

    def f(key):
        p, a = api.init_model(key, cfg)
        qp, qa = quantize_params_for_serving(p, a, cfg, packed)
        axes_box["axes"] = qa
        return qp

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, axes_box["axes"]


def serving_bytes(params_shapes) -> int:
    """Total parameter bytes in the serving layout."""
    return sum(
        int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
        for l in jax.tree.leaves(params_shapes)
    )
