"""Serving: batched prefill + decode with sampling, and the serve_step the
decode-shape dry-runs lower.

Decode is the paper's headline efficiency case (W1A8 GEMV is bandwidth
bound; 1-bit weights cut weight traffic 16x) — the packed-weight Pallas
path (repro.kernels.ops) is used on TPU; CPU examples run the fake-quant
path for identical numerics.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api

Array = jax.Array


def make_serve_step(cfg: ModelConfig):
    """decode_step(params, tokens, caches, pos) -> (logits, caches).

    This is what decode_32k / long_500k cells lower: one new token against a
    KV cache of seq_len."""

    def serve_step(params, tokens, caches, pos):
        return api.decode_step(params, tokens, caches, pos, cfg)

    return serve_step


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    def prefill_step(params, batch):
        return api.prefill(params, batch, cfg, cache_len)

    return prefill_step


# ---------------------------------------------------------------------------
# Sampling loop (examples/serve_lm.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SamplerConfig:
    temperature: float = 0.8
    top_k: int = 40
    max_new_tokens: int = 32


def sample_token(key: Array, logits: Array, scfg: SamplerConfig) -> Array:
    """logits (B, V) -> (B,) int32."""
    if scfg.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / scfg.temperature
    if scfg.top_k > 0 and scfg.top_k < logits.shape[-1]:
        kth = jax.lax.top_k(logits, scfg.top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


class BatchedServer:
    """Fixed-batch serving engine: prefill a batch of prompts, then decode
    them in lockstep (the paper's batched-requests scenario)."""

    def __init__(self, params, cfg: ModelConfig, max_len: int):
        self.params, self.cfg, self.max_len = params, cfg, max_len
        self._prefill = jax.jit(make_prefill_step(cfg, max_len))
        self._decode = jax.jit(make_serve_step(cfg))
        self._sample = jax.jit(
            lambda key, logits, t, k: sample_token(
                key, logits, SamplerConfig(temperature=t, top_k=k)
            ),
            static_argnums=(2, 3),
        )

    def generate(
        self,
        prompts: Array,  # (B, S) int32, right-aligned equal-length prompts
        scfg: SamplerConfig = SamplerConfig(),
        extra_inputs: Optional[dict] = None,
        seed: int = 0,
    ) -> np.ndarray:
        b, s = prompts.shape
        batch = {"tokens": prompts, **(extra_inputs or {})}
        logits, caches = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(seed)
        out = []
        pos_off = self.cfg.n_image_tokens if (extra_inputs and "image_embeds" in extra_inputs) else 0
        tok = None
        for i in range(scfg.max_new_tokens):
            key, sub = jax.random.split(key)
            tok = self._sample(sub, logits if i == 0 else logits[:, 0],
                               scfg.temperature, scfg.top_k)
            out.append(np.asarray(tok))
            pos = jnp.asarray(s + pos_off + i, jnp.int32)
            logits, caches = self._decode(self.params, tok[:, None], caches, pos)
        return np.stack(out, axis=1)  # (B, new_tokens)
