"""Serving: batched prefill + decode with sampling, and the serve_step the
decode-shape dry-runs lower.

Decode is the paper's headline efficiency case (W1A8 GEMV is bandwidth
bound; 1-bit weights cut weight traffic 16x) — exporting the model with
``quantized_serving.quantize_params_for_serving(packed=True)`` makes every
backbone linear execute the packed-weight Pallas tier (repro.kernels.ops:
``w1a8_gemv`` / ``decoupled_gemv`` on decode shapes, compiled on TPU,
interpret mode on CPU); latent fake-quant weights keep the float path with
identical quantization grids.

The generation loop itself lives in :mod:`repro.serve.engine`
(``DecodeEngine``): prefill + ``lax.scan`` decode + on-device sampling
compiled into one program, a single device->host transfer per call.
``BatchedServer`` is a thin wrapper over it; ``generate_python_loop``
keeps the legacy per-token host loop as the benchmark baseline
(``benchmarks/bench_decode.py``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.serve.engine import (  # noqa: F401  (re-exported for back-compat)
    DecodeEngine,
    SamplerConfig,
    decode_logits,
    sample_token,
)

Array = jax.Array


def make_serve_step(cfg: ModelConfig):
    """decode_step(params, tokens, caches, pos) -> (logits (B, V), caches).

    This is what decode_32k / long_500k cells lower: one new token against a
    KV cache of seq_len.  Logits are normalized to the (B, V) next-token
    contract (same as prefill), so samplers never branch on step index."""

    def serve_step(params, tokens, caches, pos):
        return decode_logits(params, tokens[:, 0], caches, pos, cfg)

    return serve_step


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    def prefill_step(params, batch):
        return api.prefill(params, batch, cfg, cache_len)

    return prefill_step


class BatchedServer:
    """Fixed-batch serving engine: prefill a batch of prompts, then decode
    them in lockstep (the paper's batched-requests scenario).

    ``generate`` delegates to the compiled :class:`DecodeEngine`;
    ``generate_python_loop`` is the legacy per-token host loop, kept as the
    decode-benchmark baseline and the scan-equivalence test oracle."""

    def __init__(self, params, cfg: ModelConfig, max_len: int, *, mesh=None):
        self.params, self.cfg, self.max_len = params, cfg, max_len
        self.engine = DecodeEngine(params, cfg, max_len, mesh=mesh)
        self._prefill = jax.jit(make_prefill_step(cfg, max_len))
        self._decode = jax.jit(make_serve_step(cfg))
        self._sample = jax.jit(
            lambda key, logits, t, k: sample_token(
                key, logits, SamplerConfig(temperature=t, top_k=k)
            ),
            static_argnums=(2, 3),
        )

    def generate(
        self,
        prompts: Array,  # (B, S) int32, right-aligned equal-length prompts
        scfg: Optional[SamplerConfig] = None,
        extra_inputs: Optional[dict] = None,
        seed: int = 0,
    ) -> np.ndarray:
        return self.engine.generate(prompts, scfg, extra_inputs, seed)

    def generate_stream(
        self,
        prompts: Array,
        scfg: Optional[SamplerConfig] = None,
        extra_inputs: Optional[dict] = None,
        seed: int = 0,
        chunk: int = 8,
    ):
        return self.engine.generate_stream(prompts, scfg, extra_inputs, seed,
                                           chunk)

    def generate_python_loop(
        self,
        prompts: Array,
        scfg: Optional[SamplerConfig] = None,
        extra_inputs: Optional[dict] = None,
        seed: int = 0,
    ) -> np.ndarray:
        """Legacy loop: one jitted decode + one host sync PER TOKEN.

        Kept as the baseline the compiled engine is measured against; both
        paths produce identical tokens for a given seed (prefill and decode
        logits share the (B, V) contract, and the key-split order matches
        the engine's)."""
        scfg = SamplerConfig() if scfg is None else scfg
        if scfg.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {scfg.max_new_tokens}"
            )
        s = prompts.shape[1]
        batch, pos_off = self.engine._batch_and_off(prompts, extra_inputs)
        logits, caches = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(seed)
        out = []
        for i in range(scfg.max_new_tokens):
            key, sub = jax.random.split(key)
            tok = self._sample(sub, logits, scfg.temperature, scfg.top_k)
            out.append(np.asarray(tok))  # per-token host sync (the problem)
            if i + 1 == scfg.max_new_tokens:
                break
            pos = jnp.asarray(s + pos_off + i, jnp.int32)
            logits, caches = self._decode(self.params, tok[:, None], caches,
                                          pos)
        return np.stack(out, axis=1)  # (B, new_tokens)
