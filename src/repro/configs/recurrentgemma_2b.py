"""recurrentgemma-2b — Griffin hybrid: RG-LRU recurrence + local attention
1:2 [arXiv:2402.19427].

26L with block pattern (rec, rec, attn), d_model 2560, 10 heads MQA kv=1
(head_dim 256), d_ff 7680 GeGLU, lru_width 2560, local window 2048,
vocab 256000.  Hybrid -> long_500k runs.

Quantization note (DESIGN.md §5): RG-LRU gates and Lambda stay FP.
"""

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig


def make(quant_mode: str = "pquant", n_experts: int = 1, r: int = 384) -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        glu=True,
        activation="gelu",
        block_pattern=("rec", "rec", "attn"),
        lru_width=2560,
        attn_type="swa",
        window_size=2048,
        rope_theta=10000.0,
        tie_embeddings=True,
        quant=QuantConfig(mode=quant_mode, r=r, num_experts=n_experts),
    )
