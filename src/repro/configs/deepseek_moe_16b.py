"""deepseek-moe-16b — fine-grained MoE LM [arXiv:2401.06066].

28L, d_model 2048, 16 heads (MHA), 64 routed experts top-6 + 2 shared,
expert d_ff 1408, first layer dense (d_ff 10944), vocab 102400.
Full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig


def make(quant_mode: str = "pquant", n_experts: int = 1, r: int = 128) -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="decoder",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=10944,  # dense first layer
        vocab_size=102400,
        glu=True,
        activation="silu",
        moe=True,
        n_routed_experts=64,
        moe_top_k=6,
        n_shared_experts=2,
        d_ff_expert=1408,
        first_k_dense=1,
        rope_theta=10000.0,
        tie_embeddings=False,
        quant=QuantConfig(mode=quant_mode, r=r, num_experts=n_experts),
    )
