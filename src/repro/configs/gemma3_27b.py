"""gemma3-27b — dense LM with 5:1 local:global attention [hf:google/gemma-3].

62L, d_model 5376, 32 heads GQA kv=16 (head_dim 128, decoupled from
d_model), d_ff 21504 GeGLU, vocab 262144.  Every 6th layer is global
attention (1M rope theta); the rest are 1024-window local (10k theta).
Eligible for long_500k: decode cost is dominated by the local window.
"""

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig


def make(quant_mode: str = "pquant", n_experts: int = 1, r: int = 1024) -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="decoder",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262144,
        glu=True,
        activation="gelu",
        attn_type="swa",
        window_size=1024,
        global_every=6,
        rope_theta=1_000_000.0,
        rope_theta_local=10_000.0,
        tie_embeddings=True,
        quant=QuantConfig(mode=quant_mode, r=r, num_experts=n_experts),
    )
