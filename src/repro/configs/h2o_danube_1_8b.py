"""h2o-danube-1.8b — dense LM, llama+mistral mix with sliding-window
attention [arXiv:2401.16818].

24L, d_model 2560, 32 heads GQA kv=8, d_ff 6912 SiLU-GLU, vocab 32000,
SWA window 4096 (mistral-style).  Sub-quadratic -> long_500k runs.
"""

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig


def make(quant_mode: str = "pquant", n_experts: int = 1, r: int = 384) -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="decoder",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        glu=True,
        activation="silu",
        attn_type="swa",
        window_size=4096,
        rope_theta=10000.0,
        tie_embeddings=False,
        quant=QuantConfig(mode=quant_mode, r=r, num_experts=n_experts),
    )
