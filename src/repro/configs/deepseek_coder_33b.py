"""deepseek-coder-33b — dense code LM, llama-arch [arXiv:2401.14196].

62L, d_model 7168, 56 heads GQA kv=8, d_ff 19200 SiLU-GLU, vocab 32256,
rope theta 100k.  Pure full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig


def make(quant_mode: str = "pquant", n_experts: int = 1, r: int = 1024) -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        family="decoder",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19200,
        vocab_size=32256,
        glu=True,
        activation="silu",
        rope_theta=100_000.0,
        tie_embeddings=False,
        quant=QuantConfig(mode=quant_mode, r=r, num_experts=n_experts),
    )
