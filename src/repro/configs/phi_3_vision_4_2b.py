"""phi-3-vision-4.2b — VLM backbone (phi3-mini + CLIP frontend stub)
[hf:microsoft/Phi-3-vision-128k-instruct].

32L, d_model 3072, 32 heads (MHA), d_ff 8192 SiLU-GLU, vocab 32064.  The
CLIP vision tower is a STUB: input_specs provide 576 precomputed patch
embeddings prepended to the text sequence (assignment rules).  Full
attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig


def make(quant_mode: str = "pquant", n_experts: int = 1, r: int = 384) -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        family="decoder",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        glu=True,
        activation="silu",
        rope_theta=10000.0,
        frontend="vision",
        n_image_tokens=576,
        tie_embeddings=False,
        quant=QuantConfig(mode=quant_mode, r=r, num_experts=n_experts),
    )
