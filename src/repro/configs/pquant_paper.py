"""The paper's own model sizes (Tables 1 & 4) at 300M / 700M / 1.3B / 2.6B,
plus the three trained-from-scratch baselines (BitNet 1-bit, BitNet1.58
ternary, FP16 LLaMA-2-style) under identical dims.

Table 1 (pQuant):  d_ff is the 1-bit branch width, r the 8-bit width; the
sum matches the baseline FFN width so parameter budgets are matched.
NOTE 1: the paper prints "1.3B: 5076(5400-384)" whose arithmetic is
inconsistent (5400-384=5016); we keep the matched-total invariant.
NOTE 2 (TPU alignment): 5400/5016 are not divisible by the 16-way model
axis, which silently forces full FFN replication under TP; we round to
5408/5024 (+0.15% params) — same spirit as the paper's own "r restricted
to multiples of 128 for hardware efficiency" (§4.6).

2.6B layer count is not printed; 24 layers reproduces the stated 2.6B total
with d_model 2880 / d_ff 7680 (documented estimate).
"""

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig

# size -> (layers, d_model, heads, baseline_d_ff, pquant_d_ff_1bit, r)
SIZES = {
    # 100m: CPU-trainable end-to-end driver size (examples/train_lm.py),
    # same family/recipe as the paper's models
    "100m": (14, 768, 12, 1920, 1792, 128),
    "300m": (24, 1024, 16, 2400, 2272, 128),
    "700m": (24, 1536, 24, 4096, 3840, 256),
    "1.3b": (24, 2048, 32, 5408, 5024, 384),
    "2.6b": (24, 2880, 36, 7680, 7168, 512),
}

VOCAB = 32000  # paper: BPE tokenizer, 32K vocab
SEQ = 2048


def make(
    size: str = "1.3b",
    quant_mode: str = "pquant",
    n_experts: int = 1,
) -> ModelConfig:
    layers, d, heads, d_ff_base, d_ff_1bit, r = SIZES[size]
    is_pq = quant_mode == "pquant"
    return ModelConfig(
        name=f"pquant-{size}" if is_pq else f"{quant_mode}-{size}",
        family="decoder",
        n_layers=layers,
        d_model=d,
        n_heads=heads,
        n_kv_heads=heads,
        d_ff=d_ff_1bit if is_pq else d_ff_base,
        vocab_size=VOCAB,
        max_seq_len=SEQ,
        glu=True,
        activation="silu",
        rope_theta=10000.0,
        tie_embeddings=True,
        quant=QuantConfig(
            mode=quant_mode, r=r if is_pq else 0, num_experts=n_experts
        ),
    )
