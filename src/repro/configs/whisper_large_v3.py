"""whisper-large-v3 — encoder-decoder audio backbone [arXiv:2212.04356].

32 encoder + 32 decoder layers, d_model 1280, 20 heads (MHA), d_ff 5120
GELU (non-GLU), vocab 51866, learned decoder positions, sinusoidal encoder
positions.  The conv/mel frontend is a STUB: input_specs provide 1500
precomputed frame embeddings (assignment rules).

Deviation note: real Whisper caps decoder length at 448; the assigned
decode_32k / prefill_32k shapes are supported mechanically (learned
position table sized to max_seq_len).  long_500k skipped (full attention).
"""

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig


def make(quant_mode: str = "pquant", n_experts: int = 1, r: int = 256) -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="encdec",
        n_layers=32,
        n_enc_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        glu=False,
        activation="gelu",
        pos_embedding="learned",
        use_rope=False,
        frontend="audio",
        n_frontend_tokens=1500,
        max_seq_len=32768,
        tie_embeddings=True,
        quant=QuantConfig(mode=quant_mode, r=r, num_experts=n_experts),
    )
