"""deepseek-v2-236b — MoE LM with Multi-head Latent Attention
[arXiv:2405.04434].

60L, d_model 5120, 128 heads MLA (q_lora 1536, kv_lora 512, qk_nope 128,
qk_rope 64, v 128), MoE: 160 routed experts top-6 + 2 shared experts,
expert d_ff 1536, first layer dense (d_ff 12288), vocab 102400.

pQuant composition (DESIGN.md §5): routed experts 1-bit; the shared-expert
FFN carries the decoupled 8-bit branch.  MLA is full attention over the
compressed latent -> long_500k skipped.
"""

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig


def make(quant_mode: str = "pquant", n_experts: int = 1, r: int = 256) -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="decoder",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=192,  # qk_nope + qk_rope
        d_ff=12288,  # dense first layer
        vocab_size=102400,
        glu=True,
        activation="silu",
        attn_type="mla",
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        moe=True,
        n_routed_experts=160,
        moe_top_k=6,
        n_shared_experts=2,
        d_ff_expert=1536,
        first_k_dense=1,
        rope_theta=10000.0,
        tie_embeddings=False,
        quant=QuantConfig(mode=quant_mode, r=r, num_experts=n_experts),
    )
