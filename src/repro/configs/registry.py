"""Architecture registry: ``--arch <id>`` resolution, reduced smoke configs,
and the full (arch x shape) cell enumeration used by the dry-run."""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.configs import (
    base,
    deepseek_coder_33b,
    deepseek_moe_16b,
    deepseek_v2_236b,
    gemma3_27b,
    granite_20b,
    h2o_danube_1_8b,
    mamba2_780m,
    phi_3_vision_4_2b,
    pquant_paper,
    recurrentgemma_2b,
    whisper_large_v3,
)
from repro.configs.base import ModelConfig, ShapeConfig, shapes_for

ARCHS: dict[str, Callable[..., ModelConfig]] = {
    "granite-20b": granite_20b.make,
    "gemma3-27b": gemma3_27b.make,
    "h2o-danube-1.8b": h2o_danube_1_8b.make,
    "deepseek-coder-33b": deepseek_coder_33b.make,
    "whisper-large-v3": whisper_large_v3.make,
    "deepseek-v2-236b": deepseek_v2_236b.make,
    "deepseek-moe-16b": deepseek_moe_16b.make,
    "phi-3-vision-4.2b": phi_3_vision_4_2b.make,
    "mamba2-780m": mamba2_780m.make,
    "recurrentgemma-2b": recurrentgemma_2b.make,
    # the paper's own sizes (+100m CPU-trainable driver size)
    "pquant-100m": lambda **kw: pquant_paper.make("100m", **kw),
    "pquant-300m": lambda **kw: pquant_paper.make("300m", **kw),
    "pquant-700m": lambda **kw: pquant_paper.make("700m", **kw),
    "pquant-1.3b": lambda **kw: pquant_paper.make("1.3b", **kw),
    "pquant-2.6b": lambda **kw: pquant_paper.make("2.6b", **kw),
}

ASSIGNED = [
    "granite-20b",
    "gemma3-27b",
    "h2o-danube-1.8b",
    "deepseek-coder-33b",
    "whisper-large-v3",
    "deepseek-v2-236b",
    "deepseek-moe-16b",
    "phi-3-vision-4.2b",
    "mamba2-780m",
    "recurrentgemma-2b",
]


def get_config(arch: str, **kwargs) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch](**kwargs)


def all_cells(quant_mode: str = "pquant"):
    """Every assigned (arch x shape) cell, honouring long_500k skip rules."""
    for arch in ASSIGNED:
        cfg = get_config(arch, quant_mode=quant_mode)
        for shape in shapes_for(cfg):
            yield arch, cfg, shape


def reduced(cfg: ModelConfig, vocab: int = 512) -> ModelConfig:
    """Family-faithful reduced config for CPU smoke tests: few layers, small
    width, few experts, tiny vocab — all feature flags preserved."""
    d_model = 64
    n_heads = max(2, min(4, cfg.n_heads))
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    head_dim = d_model // n_heads if cfg.head_dim == cfg.d_model // cfg.n_heads else 32
    repl = dict(
        n_layers=min(cfg.n_layers, 4 if not cfg.block_pattern else len(cfg.block_pattern) + 1),
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=96 if cfg.d_ff else 0,
        vocab_size=vocab,
        max_seq_len=128,
        window_size=min(cfg.window_size, 16) if cfg.window_size else 0,
        global_every=min(cfg.global_every, 2) if cfg.global_every else 0,
        quant=dataclasses.replace(cfg.quant, r=16 if cfg.quant.r else 0),
    )
    if cfg.attn_type == "mla":
        repl.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                    qk_rope_dim=8, v_head_dim=16, head_dim=24)
    if cfg.moe:
        repl.update(n_routed_experts=8, moe_top_k=min(cfg.moe_top_k, 2),
                    n_shared_experts=min(cfg.n_shared_experts, 1), d_ff_expert=32)
    if cfg.family == "ssm":
        repl.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16,
                    n_heads=8, n_kv_heads=8, head_dim=16)
    if cfg.family == "hybrid":
        repl.update(lru_width=d_model)
    if cfg.family == "encdec":
        repl.update(n_enc_layers=2, n_frontend_tokens=12)
    if cfg.n_image_tokens:
        repl.update(n_image_tokens=8)
    return dataclasses.replace(cfg, **repl)
