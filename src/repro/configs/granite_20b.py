"""granite-20b — dense code LM [arXiv:2405.04324].

52L, d_model 6144, 48 heads with MQA (kv=1), d_ff 24576 (4x, plain MLP +
GELU), vocab 49152.  Pure full attention -> long_500k is skipped
(DESIGN.md §5).
"""

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig


def make(quant_mode: str = "pquant", n_experts: int = 1, r: int = 1280) -> ModelConfig:
    return ModelConfig(
        name="granite-20b",
        family="decoder",
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_ff=24576,
        vocab_size=49152,
        glu=False,
        activation="gelu",
        rope_theta=10000.0,
        tie_embeddings=True,
        quant=QuantConfig(mode=quant_mode, r=r, num_experts=n_experts),
    )
