"""Unified model configuration covering all assigned architecture families
(dense / MoE / SSM / hybrid / enc-dec / audio / VLM) plus the pQuant paper's
own model sizes.  One frozen dataclass so configs hash and jit-cache cleanly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.quantization import QuantConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # decoder | encdec | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention ---
    attn_type: str = "full"  # full | swa | mla
    window_size: int = 0  # sliding-window size when attn_type == swa
    # gemma3-style interleaving: every `global_every`-th layer is global
    # (full) attention, the rest use `window_size` local attention. 0 = off.
    global_every: int = 0
    rope_theta: float = 10000.0
    rope_theta_local: float = 10000.0  # gemma3 uses a smaller theta locally
    use_rope: bool = True
    pos_embedding: str = "rope"  # rope | learned | none

    # --- MLA (DeepSeek-V2) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- FFN ---
    glu: bool = True
    activation: str = "silu"

    # --- MoE (architecture-level, e.g. DeepSeekMoE) ---
    moe: bool = False
    n_routed_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_k_dense: int = 1  # leading dense FFN layers before MoE starts
    moe_capacity_factor: float = 1.25
    # token->expert dispatch: "sort" (gather-based, FLOP-free) or "einsum"
    # (one-hot, collective-friendly — see EXPERIMENTS.md §Perf iteration B)
    moe_dispatch: str = "sort"
    moe_group_size: int = 256  # einsum dispatch group (bounds mask size)

    # --- SSM (Mamba-2 SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_groups: int = 1
    conv_kernel: int = 4

    # --- hybrid (RecurrentGemma / Griffin) ---
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    rglru_c: float = 8.0

    # --- encoder-decoder (Whisper backbone) ---
    n_enc_layers: int = 0
    n_frontend_tokens: int = 0  # encoder frames / vision patches (stub)
    frontend: str = "none"  # none | audio | vision
    # VLM: image patch tokens prepended to the text sequence
    n_image_tokens: int = 0

    # --- quantization (the paper's technique) ---
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    # pQuant decoupled-FFN dims: d_ff is the 1-bit branch width, quant.r the
    # 8-bit branch width (paper Table 1: "2272 (2400-128)").

    # --- runtime ---
    max_seq_len: int = 4096
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = True
    scan_layers: bool = True
    remat: bool = True
    logit_softcap: float = 0.0  # gemma-style final-logit soft capping

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: no layer attends to unbounded context
        quadratically at prefill, or decode cost per token is O(window)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attn_type == "swa" or self.global_every > 0

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoder-bearing


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell: (arch x input shape)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig) -> tuple[ShapeConfig, ...]:
    """Shape cells that apply to this architecture (assignment rules:
    long_500k only for sub-quadratic archs)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return tuple(out)


def param_count(cfg: ModelConfig) -> dict[str, int]:
    """Approximate parameter populations by precision class.

    Returns dict with n_1bit / n_8bit / n_fp16 (embeddings, norms, scalars
    stay high precision, per paper Table 3 footnote).
    """
    d, h = cfg.d_model, cfg.head_dim
    nq = cfg.n_heads * h
    nkv = cfg.n_kv_heads * h
    q = cfg.quant

    n_1bit = n_8bit = n_fp16 = 0

    def attn_params() -> int:
        if cfg.attn_type == "mla":
            p = 0
            if cfg.q_lora_rank:
                p += d * cfg.q_lora_rank
                p += cfg.q_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
            else:
                p += d * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
            p += d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
            p += cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
            p += cfg.n_heads * cfg.v_head_dim * d
            return p
        return d * nq + 2 * d * nkv + nq * d

    def ffn_params(width: int) -> int:
        mats = 3 if cfg.glu else 2
        return mats * d * width

    mlp_8bit_per_layer = (3 if cfg.glu else 2) * d * q.r * q.num_experts

    for layer in range(cfg.n_layers):
        blocks: list[str] = []
        if cfg.family == "hybrid":
            blocks = [cfg.block_pattern[layer % len(cfg.block_pattern)]]
        elif cfg.family == "ssm":
            blocks = ["ssm"]
        else:
            blocks = ["attn"]

        for b in blocks:
            if b == "attn":
                ap = attn_params()
                if q.mode in ("bitnet", "bitnet158", "pquant"):
                    n_1bit += ap
                else:
                    n_fp16 += ap
            elif b == "ssm":
                d_in = cfg.ssm_expand * d
                conv_dim = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
                proj = d * (2 * d_in + 2 * cfg.ssm_groups * cfg.ssm_state
                            + d_in // cfg.ssm_headdim) + d_in * d
                if q.mode in ("bitnet", "bitnet158", "pquant"):
                    n_1bit += proj
                else:
                    n_fp16 += proj
                n_fp16 += conv_dim * cfg.conv_kernel + 3 * (d_in // cfg.ssm_headdim)
            elif b == "rec":
                w = cfg.lru_width or d
                proj = 2 * d * w + w * d
                gates = 2 * w * w // 1  # block-diagonal approximated dense
                if q.mode in ("bitnet", "bitnet158", "pquant"):
                    n_1bit += proj
                else:
                    n_fp16 += proj
                n_fp16 += gates + w  # RG-LRU gates + Lambda stay FP
        # FFN / MoE
        if cfg.family == "ssm":
            continue  # no FFN block in mamba2
        if cfg.moe and layer >= cfg.first_k_dense:
            n_exp = cfg.n_routed_experts
            per_e = ffn_params(cfg.d_ff_expert) // 1
            shared = cfg.n_shared_experts * ffn_params(cfg.d_ff_expert)
            if q.mode in ("bitnet", "bitnet158", "pquant"):
                n_1bit += n_exp * per_e + shared
            else:
                n_fp16 += n_exp * per_e + shared
            if q.mode == "pquant":
                n_8bit += mlp_8bit_per_layer
            n_fp16 += d * n_exp  # router
        else:
            width = cfg.d_ff
            if q.mode == "pquant":
                n_1bit += ffn_params(width)
                n_8bit += mlp_8bit_per_layer
                n_fp16 += d * q.num_experts if q.num_experts > 1 else 0
            elif q.mode in ("bitnet", "bitnet158"):
                n_1bit += ffn_params(width)
            else:
                n_fp16 += ffn_params(width)

    # encoder stack (whisper): mirror decoder-style attn+ffn
    for _ in range(cfg.n_enc_layers):
        ap = attn_params()
        fp = ffn_params(cfg.d_ff)
        if q.mode in ("bitnet", "bitnet158", "pquant"):
            n_1bit += ap + fp
            if q.mode == "pquant":
                n_8bit += mlp_8bit_per_layer
        else:
            n_fp16 += ap + fp
        # cross-attention in decoder layers
    if cfg.family == "encdec":
        ca = cfg.n_layers * attn_params()
        if q.mode in ("bitnet", "bitnet158", "pquant"):
            n_1bit += ca
        else:
            n_fp16 += ca

    n_fp16 += cfg.vocab_size * d  # embedding
    if not cfg.tie_embeddings:
        n_fp16 += cfg.vocab_size * d
    n_fp16 += 2 * cfg.n_layers * d  # norms

    return {"n_1bit": n_1bit, "n_8bit": n_8bit, "n_fp16": n_fp16,
            "total": n_1bit + n_8bit + n_fp16}
