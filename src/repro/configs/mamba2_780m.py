"""mamba2-780m — attention-free SSM with state-space duality
[arXiv:2405.21060].

48L, d_model 1536, expand 2 (d_inner 3072), headdim 64 (48 SSD heads),
state 128, 1 group, conv kernel 4, vocab 50280.  No FFN / no attention.

pQuant adaptation (DESIGN.md §5): the in/out projections use the decoupled
*projection* (1-bit dominant + r-wide 8-bit bottleneck); SSD/conv/gate
parameters stay FP.  SSM -> long_500k runs (constant-size state decode).
"""

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig


def make(quant_mode: str = "pquant", n_experts: int = 1, r: int = 128) -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=48,  # SSD heads (d_inner / headdim)
        n_kv_heads=48,
        head_dim=64,
        d_ff=0,
        vocab_size=50280,
        glu=False,
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,
        ssm_chunk=256,
        ssm_groups=1,
        conv_kernel=4,
        tie_embeddings=True,
        quant=QuantConfig(mode=quant_mode, r=r, num_experts=n_experts),
    )
