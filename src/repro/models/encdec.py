"""Whisper-style encoder-decoder backbone.

Assignment rules: the conv/mel frontend is a STUB — ``input_specs`` supplies
precomputed frame embeddings (B, n_frames, d_model).  Everything downstream
(sinusoidal encoder positions, bidirectional encoder, causal decoder with
cross-attention, learned decoder positions) is real and pQuant-quantized
(self/cross attention 1-bit, FFNs decoupled).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_hint
from repro.models import attention as attn_mod
from repro.models.layers import (
    apply_ffn,
    cross_entropy_loss,
    embed,
    init_embedding,
    init_ffn,
    init_learned_pos,
    init_rmsnorm,
    rmsnorm,
    unembed,
)

Array = jax.Array


def sinusoid_table(length: int, d_model: int) -> Array:
    """Whisper's fixed sinusoidal positions for the encoder."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    angles = jnp.arange(length)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _scan_or_unroll(body, carry, xs, cfg: ModelConfig, length: int):
    """lax.scan when cfg.scan_layers else an unrolled python loop (used by
    roofline calibration for exact per-layer cost accounting)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for r in range(length):
        x_r = jax.tree.map(lambda t: t[r], xs)
        carry, y = body(carry, x_r)
        ys.append(y)
    if ys and ys[0] is not None:
        return carry, jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, None


def _stack_axes(axes):
    return jax.tree.map(
        lambda a: ("layers",) + tuple(a), axes, is_leaf=lambda t: isinstance(t, tuple)
    )


def _init_enc_layer(key: Array, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    p, a = {}, {}
    p["pre_norm"], a["pre_norm"] = init_rmsnorm(cfg.d_model, axis="act_embed")
    p["attn"], a["attn"] = attn_mod.init_attention(ks[0], cfg)
    p["ffn_norm"], a["ffn_norm"] = init_rmsnorm(cfg.d_model, axis="act_embed")
    p["ffn"], a["ffn"] = init_ffn(ks[1], cfg)
    return p, a


def _init_dec_layer(key: Array, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["pre_norm"], a["pre_norm"] = init_rmsnorm(cfg.d_model, axis="act_embed")
    p["self_attn"], a["self_attn"] = attn_mod.init_attention(ks[0], cfg)
    p["cross_norm"], a["cross_norm"] = init_rmsnorm(cfg.d_model, axis="act_embed")
    p["cross_attn"], a["cross_attn"] = attn_mod.init_attention(ks[1], cfg)
    p["ffn_norm"], a["ffn_norm"] = init_rmsnorm(cfg.d_model, axis="act_embed")
    p["ffn"], a["ffn"] = init_ffn(ks[2], cfg)
    return p, a


def init_model(key: Array, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}

    params["embed"], axes["embed"] = init_embedding(ks[0], cfg.vocab_size, cfg.d_model)
    params["dec_pos"], axes["dec_pos"] = init_learned_pos(
        ks[1], cfg.max_seq_len, cfg.d_model
    )

    enc = [_init_enc_layer(jax.random.fold_in(ks[2], i), cfg)
           for i in range(cfg.n_enc_layers)]
    params["encoder"] = _stack_trees([e[0] for e in enc])
    axes["encoder"] = _stack_axes(enc[0][1])

    dec = [_init_dec_layer(jax.random.fold_in(ks[3], i), cfg)
           for i in range(cfg.n_layers)]
    params["decoder"] = _stack_trees([d[0] for d in dec])
    axes["decoder"] = _stack_axes(dec[0][1])

    params["enc_final_norm"], axes["enc_final_norm"] = init_rmsnorm(
        cfg.d_model, axis="act_embed"
    )
    params["final_norm"], axes["final_norm"] = init_rmsnorm(
        cfg.d_model, axis="act_embed"
    )
    return params, axes


def encode(params, frames: Array, cfg: ModelConfig) -> Array:
    """frames: precomputed (stub) frame embeddings (B, F, D)."""
    x = frames + sinusoid_table(frames.shape[1], cfg.d_model).astype(frames.dtype)[None]
    x = shard_hint(x, "batch", "seq", "act_embed")
    dummy = jnp.zeros((frames.shape[1], cfg.head_dim // 2), jnp.float32)

    def body(x, lp):
        h = rmsnorm(lp["pre_norm"], x)
        y = attn_mod.attention(lp["attn"], h, cfg, dummy, dummy, causal=False)
        x = x + y
        h = rmsnorm(lp["ffn_norm"], x)
        y, _ = apply_ffn(lp["ffn"], h, cfg)
        x = x + y
        return shard_hint(x, "batch", "seq", "act_embed"), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = _scan_or_unroll(body, x, params["encoder"], cfg, cfg.n_enc_layers)
    return rmsnorm(params["enc_final_norm"], x)


def _dec_layer_apply(lp, x, memory, cfg: ModelConfig, cache_len=None):
    dummy = jnp.zeros((x.shape[1], cfg.head_dim // 2), jnp.float32)
    h = rmsnorm(lp["pre_norm"], x)
    out = attn_mod.attention(
        lp["self_attn"], h, cfg, dummy, dummy, causal=True, cache_len=cache_len
    )
    y, self_cache = (out if cache_len else (out, None))
    x = x + y
    h = rmsnorm(lp["cross_norm"], x)
    ck, cv = attn_mod.cross_kv(lp["cross_attn"], memory, cfg)
    x = x + attn_mod.cross_attention(lp["cross_attn"], h, ck, cv, cfg)
    h = rmsnorm(lp["ffn_norm"], x)
    y, aux = apply_ffn(lp["ffn"], h, cfg)
    x = x + y
    x = shard_hint(x, "batch", "seq", "act_embed")
    cache = {"self": self_cache, "cross_k": ck, "cross_v": cv} if cache_len else None
    return x, aux, cache


def forward(params, batch: dict, cfg: ModelConfig):
    """batch: {"frames": (B,F,D), "tokens": (B,S)}.
    Returns (logits (B,S,V), aux)."""
    memory = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, cfg)
    # input frames may arrive f32; keep the decoder carry dtype-stable
    memory = memory.astype(x.dtype)
    x = x + params["dec_pos"]["pos"][: tokens.shape[1]].astype(x.dtype)[None]
    aux_total = jnp.zeros((), jnp.float32)

    def body(carry, lp):
        x, aux_acc = carry
        x, aux, _ = _dec_layer_apply(lp, x, memory, cfg)
        return (x, aux_acc + aux), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux_total), _ = _scan_or_unroll(
        body, (x, aux_total), params["decoder"], cfg, cfg.n_layers
    )
    x = rmsnorm(params["final_norm"], x)
    logits = unembed(params["embed"], x, cfg)
    logits = shard_hint(logits, "batch", "seq", "vocab")
    return logits, aux_total


def lm_loss(params, batch: dict, cfg: ModelConfig):
    logits, aux = forward(params, batch, cfg)
    loss, nll = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return loss + aux.astype(loss.dtype), {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with self-attn KV cache and cached cross K/V
# ---------------------------------------------------------------------------


def prefill(params, batch: dict, cfg: ModelConfig, cache_len: int,
            last_pos=None):
    """Encode audio, run the decoder prompt, fill caches.

    Signature-aligned with ``models.transformer.prefill`` so the serving
    tiers never special-case enc-dec configs: ``last_pos`` (traced scalar)
    reads the logits at decoder position ``last_pos - 1`` instead of the
    final row (bucket-padded prompts)."""
    memory = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, cfg)
    memory = memory.astype(x.dtype)
    x = x + params["dec_pos"]["pos"][: tokens.shape[1]].astype(x.dtype)[None]

    def body(x, lp):
        x, _, cache = _dec_layer_apply(lp, x, memory, cfg, cache_len=cache_len)
        return x, cache

    x, caches = _scan_or_unroll(body, x, params["decoder"], cfg, cfg.n_layers)
    if last_pos is None:
        xl = x[:, -1:]
    else:
        xl = jax.lax.dynamic_slice_in_dim(
            x, jnp.asarray(last_pos, jnp.int32) - 1, 1, axis=1
        )
    x = rmsnorm(params["final_norm"], xl)
    logits = unembed(params["embed"], x, cfg)
    return logits[:, 0], caches


def decode_step(params, tokens: Array, caches, pos: Array, cfg: ModelConfig,
                active: Array | None = None):
    """tokens (B,1). caches from :func:`prefill` (stacked over layers).

    Signature-aligned with ``models.transformer.decode_step``: ``pos`` may
    be the lockstep scalar or a (B,) per-slot vector, and ``active``
    optionally masks per-slot cache writes (the self-attention cache
    adapter already speaks both; only the learned-position lookup needs
    the per-slot gather)."""
    x = embed(params["embed"], tokens, cfg)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos_emb = jax.lax.dynamic_slice_in_dim(
            params["dec_pos"]["pos"], pos, 1, axis=0
        )[None]  # (1, 1, D)
    else:
        pos_emb = jnp.take(params["dec_pos"]["pos"], pos, axis=0)[:, None]
    x = x + pos_emb.astype(x.dtype)

    def body(x, inp):
        lp, cache = inp
        h = rmsnorm(lp["pre_norm"], x)
        y, new_self = attn_mod.attention_decode(
            lp["self_attn"], h, cache["self"], pos, cfg, cfg.rope_theta,
            active=active,
        )
        x = x + y
        h = rmsnorm(lp["cross_norm"], x)
        x = x + attn_mod.cross_attention(
            lp["cross_attn"], h, cache["cross_k"], cache["cross_v"], cfg
        )
        h = rmsnorm(lp["ffn_norm"], x)
        y, _ = apply_ffn(lp["ffn"], h, cfg)
        x = x + y
        new_cache = {
            "self": new_self,
            "cross_k": cache["cross_k"],
            "cross_v": cache["cross_v"],
        }
        return x, new_cache

    x, new_caches = _scan_or_unroll(
        body, x, (params["decoder"], caches), cfg, cfg.n_layers
    )
    x = rmsnorm(params["final_norm"], x)
    logits = unembed(params["embed"], x, cfg)
    return logits, new_caches


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Decoder cache stand-in (for dry-run input_specs): stacked over layers."""
    c, a = attn_mod.init_attention_cache(cfg, batch, max_len, dtype)
    f = cfg.n_frontend_tokens
    cross = jnp.zeros((batch, f, cfg.n_kv_heads, cfg.head_dim), dtype)
    cache = {
        "self": jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (cfg.n_layers,) + t.shape), c
        ),
        "cross_k": jnp.broadcast_to(cross[None], (cfg.n_layers,) + cross.shape),
        "cross_v": jnp.broadcast_to(cross[None], (cfg.n_layers,) + cross.shape),
    }
    axes = {
        "self": _stack_axes(a),
        "cross_k": ("layers", "batch", None, "cache_heads", None),
        "cross_v": ("layers", "batch", None, "cache_heads", None),
    }
    return cache, axes
