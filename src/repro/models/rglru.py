"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Temporal mixing block: two input linears (gate branch with GELU, recurrence
branch with a short depthwise conv), the Real-Gated LRU diagonal recurrence,
and an output linear.  Training uses ``jax.lax.associative_scan`` (log-depth
parallel over sequence); decode keeps a constant-size hidden state.

Quantization (DESIGN.md §5): the three projections are BitLinear in
quantized modes; the RG-LRU gates (W_a, W_x) and Lambda stay FP — they
parameterize a recurrence decay where sign-binarization is degenerate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.bitlinear import bitlinear, init_linear
from repro.distributed.sharding import shard_hint

Array = jax.Array


def init_rglru_block(key: Array, cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    params, axes = {}, {}
    for name, k, di, do, ax in (
        ("wx", ks[0], d, w, ("embed", "ffn")),
        ("wy", ks[1], d, w, ("embed", "ffn")),
        ("wout", ks[2], w, d, ("ffn", "embed")),
    ):
        p, a = init_linear(k, di, do, ax)
        params[name], axes[name] = p, a
    # RG-LRU gates: stay FP (recurrence-critical)
    for name, k in (("wa", ks[3]), ("wi", ks[4])):
        # gates stay FP; input dim unsharded, output dim model-sharded so the
        # gated recurrence stays aligned with the conv/branch activations
        p, a = init_linear(k, w, w, (None, "ffn"))
        params[name], axes[name] = p, a
    # Lambda: a = sigmoid(Lambda) init so a^c in [0.9, 0.999]
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    a_target = u ** (1.0 / cfg.rglru_c)
    params["lam"] = jnp.log(a_target) - jnp.log1p(-a_target)  # logit
    axes["lam"] = ("act_ffn",)
    params["conv_w"] = jax.random.normal(ks[5], (cfg.conv_kernel, w), jnp.float32) * 0.02
    axes["conv_w"] = ("conv", "ffn")
    params["conv_b"] = jnp.zeros((w,), jnp.float32)
    axes["conv_b"] = ("ffn",)
    return params, axes


def _rglru_gates(params, x: Array, cfg: ModelConfig):
    """log_a (B,S,W) and gated input, computed in fp32."""
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ params["wa"]["w"])
    i = jax.nn.sigmoid(x32 @ params["wi"]["w"])
    # log a_t = -c * softplus(-Lambda) * r_t   (a = sigmoid(Lambda))
    log_a = -cfg.rglru_c * jax.nn.softplus(-params["lam"])[None, None] * r
    a_sq = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a_sq, 1e-12)) * (i * x32)
    return log_a, gated


def _assoc_scan(log_a: Array, b: Array) -> Array:
    """h_t = exp(log_a_t) * h_{t-1} + b_t along axis 1, h_0 = 0."""

    def combine(left, right):
        la_l, b_l = left
        la_r, b_r = right
        return la_l + la_r, jnp.exp(la_r) * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h


def rglru_block(
    params,
    x: Array,
    cfg: ModelConfig,
    return_cache: bool = False,
    cache: dict | None = None,
):
    """Multi-token recurrent mixing chunk. x: (B,S,D) -> (B,S,D).

    ``cache`` (hidden state + conv tail from :func:`init_rglru_cache` / a
    previous chunk) resumes the recurrence mid-stream: the initial state
    enters as ``exp(cumsum log_a) * h0`` on top of the zero-state scan,
    which is the closed form of carrying ``h0`` through the gated
    recurrence.  ``cache=None`` keeps the from-scratch training/prefill
    path (a zero cache adds an exact zero — same result).
    """
    gate = jax.nn.gelu(bitlinear(params["wy"], x, cfg.quant), approximate=True)
    u = bitlinear(params["wx"], x, cfg.quant)
    k = params["conv_w"].shape[0]
    if cache is None:
        up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        up = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)
    conv = sum(
        up[:, i : i + u.shape[1], :] * params["conv_w"][i][None, None].astype(u.dtype)
        for i in range(k)
    ) + params["conv_b"][None, None].astype(u.dtype)
    conv = shard_hint(conv, "batch", "seq", "act_ffn")
    log_a, gated = _rglru_gates(params, conv, cfg)
    h = _assoc_scan(log_a, gated)
    if cache is not None:
        h = h + jnp.exp(jnp.cumsum(log_a, axis=1)) * cache["h"][:, None, :]
    y = bitlinear(params["wout"], h.astype(x.dtype) * gate, cfg.quant)
    if not return_cache:
        return y
    if cache is None:
        tail = u[:, u.shape[1] - (k - 1) :, :]
    else:
        tail = jnp.concatenate(
            [cache["conv"], u.astype(cache["conv"].dtype)], axis=1
        )[:, -(k - 1) :, :]
    return y, {"h": h[:, -1], "conv": tail}


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype):
    w = cfg.lru_width or cfg.d_model
    cache = {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, w), dtype),
    }
    axes = {"h": ("batch", "act_ffn"), "conv": ("batch", None, "act_ffn")}
    return cache, axes


def rglru_decode(params, x: Array, cache: dict, cfg: ModelConfig):
    """One-step recurrent mixing. x: (B,1,D)."""
    gate = jax.nn.gelu(bitlinear(params["wy"], x, cfg.quant), approximate=True)
    u = bitlinear(params["wx"], x, cfg.quant)  # (B,1,W)
    win = jnp.concatenate([cache["conv"], u.astype(cache["conv"].dtype)], axis=1)
    conv = (
        jnp.einsum("bkw,kw->bw", win.astype(x.dtype), params["conv_w"].astype(x.dtype))
        + params["conv_b"][None].astype(x.dtype)
    )[:, None]
    log_a, gated = _rglru_gates(params, conv, cfg)
    h = jnp.exp(log_a[:, 0]) * cache["h"] + gated[:, 0]
    y = bitlinear(params["wout"], (h[:, None].astype(x.dtype)) * gate, cfg.quant)
    return y, {"h": h, "conv": win[:, 1:]}
