"""Shared model layers: embeddings, rotary tables, FFN block dispatch.

The FFN block is where pQuant's decoupled linear layer plugs in: mode
``pquant`` builds the dual-branch layer (1-bit + r-wide 8-bit experts),
``bitnet``/``bitnet158`` build a fully quantized FFN (r=0), ``none`` a
plain dense GLU/MLP.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.bitlinear import init_rmsnorm, rmsnorm  # noqa: F401  (re-export)
from repro.core.decoupled import decoupled_ffn, init_decoupled_ffn
from repro.core.routing import RouterConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def init_embedding(key: Array, vocab: int, d_model: int, dtype=jnp.float32):
    e = jax.random.normal(key, (vocab, d_model), dtype) * (d_model**-0.5)
    return {"table": e}, {"table": ("vocab", "embed")}


def embed(params, tokens: Array, cfg: ModelConfig) -> Array:
    x = jnp.take(params["table"], tokens, axis=0)
    # gemma-family scales embeddings by sqrt(d_model)
    if "gemma" in cfg.name:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x


def unembed(params, x: Array, cfg: ModelConfig) -> Array:
    logits = x @ params["table"].T.astype(x.dtype)
    if cfg.logit_softcap > 0:
        c = jnp.asarray(cfg.logit_softcap, logits.dtype)
        logits = c * jnp.tanh(logits / c)
    return logits


def init_learned_pos(key: Array, max_len: int, d_model: int, dtype=jnp.float32):
    p = jax.random.normal(key, (max_len, d_model), dtype) * 0.02
    return {"pos": p}, {"pos": (None, "embed")}


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_table(positions: Array, head_dim: int, theta: float) -> tuple[Array, Array]:
    """sin/cos tables for given integer positions: (len(positions), head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: Array, sin: Array, cos: Array) -> Array:
    """x: (B, S, H, D). sin/cos: (S, D/2). Rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[None, :, None, :].astype(x.dtype)
    cos = cos[None, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# FFN block (dense / fully quantized / pQuant-decoupled)
# ---------------------------------------------------------------------------


def init_ffn(key: Array, cfg: ModelConfig, d_ff: int | None = None):
    """FFN parameters for one layer, respecting cfg.quant.

    pquant mode: d_ff is the 1-bit width, quant.r the 8-bit branch width
    (paper Table 1).  Other modes: r = 0.
    """
    q = cfg.quant
    width = cfg.d_ff if d_ff is None else d_ff
    r = q.r if q.mode == "pquant" else 0
    n = q.num_experts if q.mode == "pquant" else 1
    return init_decoupled_ffn(
        key,
        cfg.d_model,
        width,
        r,
        num_experts=n,
        glu=cfg.glu,
        alpha_init=q.alpha_init,
        beta_init=q.beta_init,
    )


def apply_ffn(params, x: Array, cfg: ModelConfig):
    """Returns (y, aux_loss)."""
    q = cfg.quant
    rcfg = None
    if q.mode == "pquant" and q.num_experts > 1:
        rcfg = RouterConfig(num_experts=q.num_experts, top_k=1)
    return decoupled_ffn(
        params, x, q, glu=cfg.glu, activation=cfg.activation, router_cfg=rcfg
    )


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy_loss(
    logits: Array, labels: Array, mask: Array | None = None, z_weight: float = 1e-4
):
    """Token-level CE with z-loss, fp32 accumulation.

    logits (B, S, V), labels (B, S) int32; mask (B, S) in {0,1}.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - true_logit
    z = z_weight * jnp.square(lse)
    per_tok = nll + z
    if mask is None:
        return jnp.mean(per_tok), jnp.mean(nll)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per_tok * mask) / denom, jnp.sum(nll * mask) / denom
