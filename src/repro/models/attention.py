"""Attention variants: GQA/MQA with optional sliding window, DeepSeek-V2 MLA,
and cross-attention (Whisper).  All projections are BitLinear (pure 1-bit,
paper §3.1) in quantized modes; on packed serving weights
(``quantize_params_for_serving(packed=True)``) every projection runs the
true-integer W1A8 kernel tier — decode-shaped calls hit the fused-act-quant
``w1a8_gemv`` (see ``core.bitlinear`` / ``kernels.ops``).

Cache-adapter protocol (serving): each layer owns a dict of cache arrays
and ``*_chunk`` extends it by T tokens at per-slot position offsets — the
single cache-resident forward the serving stack runs.  Prefill is a chunk
into an empty cache, decode is a chunk with T=1 (``*_decode`` is the
preserved one-token fast path the chunk entry points dispatch to).  Two
interchangeable layouts ride the same call sites:

* dense — ``{"k", "v"}`` ring buffers ``(B, L, H, D)`` (L < max_len on
  sliding-window layers; slot(p) = p % L *is* the window).
* paged — ``{"kpool", "vpool", "table"}`` from ``repro.serve.kv_pool``: a
  shared block pool plus per-slot block tables.  The ``"table"`` key is
  the layout discriminator.  Paged scoring dispatches to the Pallas
  block-table kernel (``kernels.paged_attention``, walks each slot's
  pages in place) when ``kernels.ops.paged_attention_enabled()``; the
  ``kv_pool.read`` gather + SDPA path remains the fallback and parity
  oracle (see :func:`_paged_scores`).

``pos`` may be the model-level scalar (lockstep decode: every slot at the
same position) or a ``(B,)`` vector (continuous batching: ragged slots).
``active`` is an optional ``(B,)`` bool mask — inactive (finished /
unoccupied) slots produce **no cache writes**, which is what makes block
reclamation safe while a chunk is still in flight.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.bitlinear import bitlinear, init_linear, init_rmsnorm, rmsnorm
from repro.distributed.sharding import shard_hint
from repro.models.layers import apply_rope, rope_table

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Standard GQA attention
# ---------------------------------------------------------------------------


def init_attention(key: Array, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 5)
    params, axes = {}, {}
    for name, k, di, do, ax in (
        ("wq", ks[0], d, nq * hd, ("embed", "heads")),
        ("wk", ks[1], d, nkv * hd, ("embed", "kv_heads")),
        ("wv", ks[2], d, nkv * hd, ("embed", "kv_heads")),
        ("wo", ks[3], nq * hd, d, ("heads", "embed")),
    ):
        p, a = init_linear(k, di, do, ax)
        params[name], axes[name] = p, a
    if cfg.quant.mode != "none":
        # SubLN ahead of the output projection (BitNet placement)
        p, a = init_rmsnorm(nq * hd, axis="heads")
        params["subln"], axes["subln"] = p, a
    return params, axes


def _project_qkv(params, x: Array, cfg: ModelConfig):
    b, s, d = x.shape
    hd, nq, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = bitlinear(params["wq"], x, cfg.quant, waxes=("embed", "heads")).reshape(b, s, nq, hd)
    k = bitlinear(params["wk"], x, cfg.quant, waxes=("embed", "kv_heads")).reshape(b, s, nkv, hd)
    v = bitlinear(params["wv"], x, cfg.quant, waxes=("embed", "kv_heads")).reshape(b, s, nkv, hd)
    q = shard_hint(q, "batch", "seq", "act_heads", None)
    k = shard_hint(k, "batch", "seq", "cache_heads", None)
    v = shard_hint(v, "batch", "seq", "cache_heads", None)
    return q, k, v


def _out_proj(params, attn_out: Array, cfg: ModelConfig) -> Array:
    b, s = attn_out.shape[:2]
    flat = attn_out.reshape(b, s, -1)
    # keep heads*head_dim model-sharded through SubLN + act-quant (see the
    # sharding note in core/decoupled._branch1_apply)
    flat = shard_hint(flat, "batch", "seq", "act_heads")
    subln = params.get("subln")
    return bitlinear(
        params["wo"], flat, cfg.quant, sublayer_norm=subln, waxes=("heads", "embed")
    )


def _sdpa(
    q: Array,
    k: Array,
    v: Array,
    mask: Optional[Array],
    scale: Optional[float] = None,
) -> Array:
    """Grouped scaled-dot-product attention.

    q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    mask: broadcastable to (B, Hq, Sq, Skv); True = attend.
    """
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    dv = v.shape[-1]  # may differ from d (MLA)
    g = hq // hkv
    scale = scale if scale is not None else d**-0.5
    qg = q.reshape(b, sq, hkv, g, d)
    # logits: (B, Hkv, G, Sq, Skv)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        # mask arrives as (B|1, 1, Sq, Skv); add the group axis
        logits = jnp.where(mask[:, :, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return out.reshape(b, sq, hq, dv)


def causal_mask(sq: int, skv: int, window) -> Array:
    """(1, 1, Sq, Skv) boolean mask; ``window`` may be a traced scalar
    (<= 0 means unlimited / global)."""
    i = jnp.arange(sq)[:, None] + (skv - sq)  # absolute query positions
    j = jnp.arange(skv)[None, :]
    m = j <= i
    w = jnp.asarray(window)
    m = m & jnp.where(w > 0, (i - j) < w, True)
    return m[None, None]


def attention(
    params,
    x: Array,
    cfg: ModelConfig,
    sin: Array,
    cos: Array,
    window=0,
    causal: bool = True,
    cache_len: Optional[int] = None,
):
    """Full-sequence attention (train / prefill).

    With ``cache_len`` set, also returns a KV cache buffer of that length
    with positions [0:S] filled (prefill).
    """
    q, k, v = _project_qkv(params, x, cfg)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    s = x.shape[1]
    mask = causal_mask(s, s, window) if causal else None
    out = _sdpa(q, k, v, mask)
    y = _out_proj(params, out, cfg)
    if cache_len is None:
        return y
    if cache_len >= s:
        pad = [(0, 0), (0, cache_len - s), (0, 0), (0, 0)]
        cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    else:
        # RING cache (sliding-window layer): keep the last cache_len
        # positions, placed so that slot(p) == p % cache_len — decode then
        # overwrites the oldest entry in place.
        shift = s % cache_len
        cache = {
            "k": jnp.roll(k[:, s - cache_len :], shift, axis=1),
            "v": jnp.roll(v[:, s - cache_len :], shift, axis=1),
        }
    cache["k"] = shard_hint(cache["k"], "batch", "cache_seq", "cache_heads", None)
    cache["v"] = shard_hint(cache["v"], "batch", "cache_seq", "cache_heads", None)
    return y, cache


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    hd, nkv = cfg.head_dim, cfg.n_kv_heads
    shape = (batch, max_len, nkv, hd)
    zeros = jnp.zeros(shape, dtype)
    cache = {"k": zeros, "v": zeros}
    axes = {
        "k": ("batch", "cache_seq", "cache_heads", None),
        "v": ("batch", "cache_seq", "cache_heads", None),
    }
    return cache, axes


def _rope_decode(x: Array, pos: Array, head_dim: int, theta) -> Array:
    """Rotate one decode token per slot.  x: (B, 1, H, D).

    Scalar ``pos`` reproduces the original shared-position path bit-for-bit;
    a ``(B,)`` vector applies each slot's own angle (continuous batching).
    """
    if pos.ndim == 0:
        sin, cos = rope_table(pos[None], head_dim, theta)
        return apply_rope(x, sin, cos)
    sin, cos = rope_table(pos, head_dim, theta)  # (B, D/2)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[:, None, None, :].astype(x.dtype)
    cos = cos[:, None, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _slot_write(cache: Array, new: Array, slot: Array, active: Array | None):
    """Dense-adapter write: one token per slot at per-slot ring positions.

    cache: (B, L, ...); new: (B, 1, ...); slot: (B,) int32.  One-hot
    ``where`` rather than dynamic_update_slice because each batch row
    writes a *different* position, and inactive rows write nothing.
    """
    l = cache.shape[1]
    hit = jnp.arange(l)[None, :] == slot[:, None]  # (B, L)
    if active is not None:
        hit = hit & active[:, None]
    hit = hit.reshape(hit.shape + (1,) * (cache.ndim - 2))
    return jnp.where(hit, new.astype(cache.dtype), cache)


def _decode_mask(pos: Array, skv: int, ring: bool) -> Array:
    """Validity mask for a decode read, broadcastable to (B, 1, 1, Skv).

    ring=True caps at the buffer length (after wrap, every slot is live);
    ring=False is the plain prefix mask used by full-length/paged caches.
    """
    j = jnp.arange(skv)
    lim = jnp.minimum(pos, skv - 1) if ring else pos
    if pos.ndim == 0:
        return jnp.broadcast_to((j <= lim)[None, None, None], (1, 1, 1, skv))
    return (j[None, :] <= lim[:, None])[:, None, None, :]


def _rope_at(x: Array, posmat: Array, head_dim: int, theta) -> Array:
    """Rotate a chunk of tokens at absolute positions ``posmat`` (B|1, T).
    x: (B, T, H, D).

    Elementwise rotate-half with per-(slot, token) angle tables — for a
    single token this computes exactly what :func:`_rope_decode` computes,
    and for a shared scalar offset it matches :func:`apply_rope` over a
    ``(T,)`` table (the angles are elementwise equal, so the products
    are bitwise equal).
    """
    sin, cos = rope_table(posmat.reshape(-1), head_dim, theta)
    sin = sin.reshape(posmat.shape + (-1,))[:, :, None, :].astype(x.dtype)
    cos = cos.reshape(posmat.shape + (-1,))[:, :, None, :].astype(x.dtype)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _pos_matrix(pos: Array, t: int) -> Array:
    """Absolute positions of a T-token chunk: (B|1, T) from the per-slot
    (or shared scalar) position of the chunk's first token."""
    offs = jnp.arange(t, dtype=jnp.int32)
    if pos.ndim == 0:
        return (pos + offs)[None]
    return pos[:, None] + offs[None]


def _chunk_valid(
    b: int, t: int, active: Array | None, lengths: Array | None
) -> Array | None:
    """(B, T) bool — which chunk entries really carry a token (``lengths``
    right-pads a ragged final slice; ``active`` gates whole slots)."""
    if active is None and lengths is None:
        return None
    ok = jnp.ones((b, t), bool)
    if lengths is not None:
        ok = ok & (jnp.arange(t)[None, :] < lengths[:, None])
    if active is not None:
        ok = ok & active[:, None]
    return ok


def _span_write(cache: Array, new: Array, rows: Array, valid: Array | None):
    """Dense-adapter span write: T tokens per slot at per-(slot, token)
    rows.  cache: (B, L, ...); new: (B, T, ...); rows: (B, T) int32.
    Invalid entries are routed out of bounds and dropped (no arithmetic on
    resident values — writes are pure placements)."""
    b, t = rows.shape
    if valid is not None:
        rows = jnp.where(valid, rows, cache.shape[1])  # OOB -> mode="drop"
    bi = jnp.broadcast_to(jnp.arange(b)[:, None], (b, t))
    return cache.at[bi, rows].set(new.astype(cache.dtype), mode="drop")


def _span_mask(posmat: Array, skv: int) -> Array:
    """Causal validity of a chunk read, (B|1, 1, T, Skv): query at absolute
    position q attends cache columns j <= q.  Columns written by *later*
    chunk tokens sit at j > q, so one prefix rule masks both the resident
    garbage and the in-chunk future (the T=1 case is exactly
    :func:`_decode_mask` with ring=False)."""
    j = jnp.arange(skv)
    return (j[None, None, :] <= posmat[..., None])[:, None]


def _ring_chunk(q, k, v, cache: dict, posmat: Array, valid: Array | None):
    """Sequential per-token chunk over a RING cache (sliding-window layer).

    A parallel span write is wrong here: writing token ``p`` evicts the
    resident key at ``p - W``, which earlier queries in the same chunk
    still attend.  Scanning write->read per token reproduces the decode
    semantics exactly, token for token, so chunked prefill over a ring is
    bitwise the decode stream — while the projections around it stay
    chunk-parallel.  q/k/v: (B, T, H, D); posmat: (B|1, T).
    """
    b, t = q.shape[:2]
    l = cache["k"].shape[1]
    posmat = jnp.broadcast_to(posmat, (b, t))
    ok = jnp.broadcast_to(valid, (b, t)) if valid is not None else None

    def step(carry, inp):
        kc, vc = carry
        qt, kt, vt, pt, okt = inp  # (B, H, D) x3, (B,), (B,) | None
        kc = _slot_write(kc, kt[:, None], pt % l, okt)
        vc = _slot_write(vc, vt[:, None], pt % l, okt)
        mask = _decode_mask(pt, l, ring=True)
        out = _sdpa(qt[:, None], kc.astype(qt.dtype), vc.astype(qt.dtype), mask)
        return (kc, vc), out[:, 0]

    xs = (
        jnp.moveaxis(q, 1, 0),
        jnp.moveaxis(k, 1, 0),
        jnp.moveaxis(v, 1, 0),
        posmat.T,
        ok.T if ok is not None else jnp.ones((t, b), bool),
    )
    (kc, vc), outs = jax.lax.scan(step, (cache["k"], cache["v"]), xs)
    return jnp.moveaxis(outs, 0, 1), {"k": kc, "v": vc}


def _paged_scores(
    q: Array,  # (B, T, Hq, D) — rotated queries
    kpool: Array,
    vpool: Array,
    table: Array,
    posv: Array,  # (B,) — absolute position of q[:, 0]
    posmat: Array,  # (B|1, T) — per-(slot, token) absolute positions
    n_valid,  # (B,) lengths of a ragged slice, or the static T
    read_to: int | None,
) -> Array:
    """Score queries against the paged pool: Pallas block-table kernel
    when enabled (``kernels.paged_attention`` — walks each slot's pages
    in place, no dense gather), else the ``kv_pool.read`` gather +
    prefix-masked SDPA, which stays the parity oracle.  The fallback
    clamps its gather to the used-block prefix when the caller provides a
    static ``read_to`` bound; the kernel bounds its page walk per slot
    with the resident length ``posv + n_valid`` instead (no static bound
    needed).  Decode is the T=1 case: ``posmat = posv[:, None]`` makes
    ``_span_mask`` exactly the decode prefix mask."""
    from repro.kernels import ops  # deferred: kernels tier is optional here

    b, t = q.shape[:2]
    bs = kpool.shape[1]
    if ops.paged_attention_enabled() and ops.paged_attention_supported(
        bs, q.shape[-1], q.shape[2], kpool.shape[2]
    ):
        kv_lens = jnp.clip(posv + n_valid, 1, table.shape[1] * bs)
        return ops.paged_attention(
            q, kpool, vpool, table, posv, kv_lens
        ).astype(q.dtype)
    from repro.serve import kv_pool  # deferred: serve imports models

    mb = table.shape[1]
    nb = mb if read_to is None else max(1, min(mb, -(-read_to // bs)))
    keys = kv_pool.read(kpool, table, blocks=nb)
    vals = kv_pool.read(vpool, table, blocks=nb)
    mask = _span_mask(jnp.broadcast_to(posmat, (b, t)), keys.shape[1])
    return _sdpa(q, keys.astype(q.dtype), vals.astype(q.dtype), mask)


def attention_chunk(
    params,
    x: Array,
    cache: dict,
    pos: Array,
    cfg: ModelConfig,
    theta: float,
    window=0,
    active: Array | None = None,
    lengths: Array | None = None,
    ring: bool = False,
    read_to: int | None = None,
):
    """Cache-resident multi-token attention: process T tokens per slot.

    x: (B, T, D); pos: scalar or (B,) int32 — absolute position of
    x[:, 0].  K/V for tokens ``t < lengths[b]`` (default: all T) of active
    slots are written into the *existing* cache — dense ring, dense full,
    or paged — and each query attends the already-resident prefix plus the
    in-chunk causal keys.  Prefill is this from an empty cache; decode is
    T=1 (dispatched to :func:`attention_decode`, the preserved one-token
    fast path, so decode streams are bit-for-bit unchanged).

    ``ring`` (static) marks a sliding-window layer whose dense cache is
    shorter than the position range — those take the sequential in-chunk
    path (:func:`_ring_chunk`); everything else reads the updated cache in
    parallel under one prefix mask.  ``read_to`` (static) bounds that read
    when the caller knows no position >= read_to can be attended — prefill
    from an empty cache passes its prompt length, keeping scoring
    O(S*S) instead of O(S*cache_len); the masked-out columns it drops
    contribute exact zeros to the softmax either way.  The paged fallback
    gather honors the same bound (``kv_pool.read(blocks=ceil(read_to /
    block_size))``); the paged *kernel* path needs no static bound — it
    clamps each slot's page walk to its resident length.

    Returns (y (B, T, D), new_cache).
    """
    b, t = x.shape[:2]
    if t == 1 and lengths is None:
        return attention_decode(
            params, x, cache, pos, cfg, theta, window=window, active=active
        )
    del window  # window semantics are carried by the cache length (ring)
    q, k, v = _project_qkv(params, x, cfg)
    pos = jnp.asarray(pos, jnp.int32)
    posmat = _pos_matrix(pos, t)
    if cfg.pos_embedding == "rope":
        q = _rope_at(q, posmat, cfg.head_dim, theta)
        k = _rope_at(k, posmat, cfg.head_dim, theta)

    if "table" in cache:  # paged adapter: span-scatter straight into pages
        from repro.serve import kv_pool  # deferred: serve imports models

        posv = jnp.broadcast_to(pos, (b,))
        kp = kv_pool.write_span(
            cache["kpool"], cache["table"], posv, k, active, lengths
        )
        vp = kv_pool.write_span(
            cache["vpool"], cache["table"], posv, v, active, lengths
        )
        # pool layout (NB, BS, Hkv, D): shards over KV heads on `model`;
        # the per-slot table replicates with the rest of the slot state
        kp = shard_hint(kp, None, None, "cache_heads", None)
        vp = shard_hint(vp, None, None, "cache_heads", None)
        out = _paged_scores(
            q, kp, vp, cache["table"], posv, posmat,
            lengths if lengths is not None else t, read_to,
        )
        new_cache = {"kpool": kp, "vpool": vp, "table": cache["table"]}
        return _out_proj(params, out, cfg), new_cache

    valid = _chunk_valid(b, t, active, lengths)
    if ring:
        out, new_cache = _ring_chunk(q, k, v, cache, posmat, valid)
    else:
        skv = cache["k"].shape[1]
        lim = skv if read_to is None else min(read_to, skv)
        rows = jnp.broadcast_to(posmat, (b, t))
        new_k = _span_write(cache["k"], k, rows, valid)
        new_v = _span_write(cache["v"], v, rows, valid)
        new_k = shard_hint(new_k, "batch", "cache_seq", "cache_heads", None)
        new_v = shard_hint(new_v, "batch", "cache_seq", "cache_heads", None)
        mask = _span_mask(posmat, lim)
        out = _sdpa(
            q, new_k[:, :lim].astype(q.dtype), new_v[:, :lim].astype(q.dtype),
            mask,
        )
        new_cache = {"k": new_k, "v": new_v}
    return _out_proj(params, out, cfg), new_cache


def attention_decode(
    params,
    x: Array,
    cache: dict,
    pos: Array,
    cfg: ModelConfig,
    theta: float,
    window=0,
    active: Array | None = None,
):
    """One-token decode step. x: (B, 1, D); pos: scalar or (B,) int32.

    Dense caches may be shorter than the sequence (RING cache for
    sliding-window layers): the write slot is ``pos % cache_len`` and the
    validity mask covers min(pos+1, cache_len) slots — a cache of length W
    IS the W-token sliding window, so no extra window masking is needed.
    Paged caches (``"table"`` key) scatter into the shared block pool and
    score via :func:`_paged_scores` — the Pallas block-table kernel when
    enabled, else the dense-view gather (see ``repro.serve.kv_pool``).

    Returns (y, new_cache).
    """
    b = x.shape[0]
    del window  # window semantics are carried by the cache length (ring)
    q, k, v = _project_qkv(params, x, cfg)
    pos = jnp.asarray(pos, jnp.int32)
    if cfg.pos_embedding == "rope":
        q = _rope_decode(q, pos, cfg.head_dim, theta)
        k = _rope_decode(k, pos, cfg.head_dim, theta)

    if "table" in cache:  # paged adapter
        from repro.serve import kv_pool  # deferred: serve imports models

        posv = jnp.broadcast_to(pos, (b,))
        kp = kv_pool.write(cache["kpool"], cache["table"], posv, k[:, 0], active)
        vp = kv_pool.write(cache["vpool"], cache["table"], posv, v[:, 0], active)
        kp = shard_hint(kp, None, None, "cache_heads", None)
        vp = shard_hint(vp, None, None, "cache_heads", None)
        out = _paged_scores(
            q, kp, vp, cache["table"], posv, posv[:, None], 1, None
        )
        new_cache = {"kpool": kp, "vpool": vp, "table": cache["table"]}
        return _out_proj(params, out, cfg), new_cache

    skv = cache["k"].shape[1]
    if pos.ndim == 0 and active is None:
        # lockstep fast path: every slot writes the same ring position
        slot = pos % skv
        new_k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1
        )
        new_v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1
        )
    else:
        posv = jnp.broadcast_to(pos, (b,))
        new_k = _slot_write(cache["k"], k, posv % skv, active)
        new_v = _slot_write(cache["v"], v, posv % skv, active)
    new_k = shard_hint(new_k, "batch", "cache_seq", "cache_heads", None)
    new_v = shard_hint(new_v, "batch", "cache_seq", "cache_heads", None)
    mask = _decode_mask(pos, skv, ring=True)
    out = _sdpa(q, new_k.astype(q.dtype), new_v.astype(q.dtype), mask)
    return _out_proj(params, out, cfg), {"k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# Cross-attention (Whisper decoder)
# ---------------------------------------------------------------------------


def cross_attention(params, x: Array, k: Array, v: Array, cfg: ModelConfig) -> Array:
    """x: (B, Sq, D) queries; k/v precomputed from encoder memory."""
    b, sq, _ = x.shape
    hd, nq = cfg.head_dim, cfg.n_heads
    q = bitlinear(params["wq"], x, cfg.quant).reshape(b, sq, nq, hd)
    out = _sdpa(q, k, v, None)
    return _out_proj(params, out, cfg)


def cross_kv(params, memory: Array, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output (once per request)."""
    b, sm, _ = memory.shape
    hd, nkv = cfg.head_dim, cfg.n_kv_heads
    k = bitlinear(params["wk"], memory, cfg.quant).reshape(b, sm, nkv, hd)
    v = bitlinear(params["wv"], memory, cfg.quant).reshape(b, sm, nkv, hd)
    return k, v


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key: Array, cfg: ModelConfig):
    d = cfg.d_model
    nh = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 8)
    params, axes = {}, {}

    def add(name, k, di, do, ax):
        p, a = init_linear(k, di, do, ax)
        params[name], axes[name] = p, a

    if cfg.q_lora_rank > 0:
        add("wq_down", ks[0], d, cfg.q_lora_rank, ("embed", "lora"))
        add("wq_up", ks[1], cfg.q_lora_rank, nh * qk, ("lora", "heads"))
        p, a = init_rmsnorm(cfg.q_lora_rank, axis="lora")
        params["q_norm"], axes["q_norm"] = p, a
    else:
        add("wq", ks[0], d, nh * qk, ("embed", "heads"))
    # joint KV down-projection: [c_kv ; k_rope]
    add("wkv_down", ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim, ("embed", "lora"))
    add(
        "wkv_up",
        ks[3],
        cfg.kv_lora_rank,
        nh * (cfg.qk_nope_dim + cfg.v_head_dim),
        ("lora", "heads"),
    )
    p, a = init_rmsnorm(cfg.kv_lora_rank, axis="lora")
    params["kv_norm"], axes["kv_norm"] = p, a
    add("wo", ks[4], nh * cfg.v_head_dim, d, ("heads", "embed"))
    if cfg.quant.mode != "none":
        p, a = init_rmsnorm(nh * cfg.v_head_dim, axis="heads")
        params["subln"], axes["subln"] = p, a
    return params, axes


def _mla_q(params, x: Array, cfg: ModelConfig):
    b, s, _ = x.shape
    nh, qk = cfg.n_heads, cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank > 0:
        cq = bitlinear(params["wq_down"], x, cfg.quant)
        cq = rmsnorm(params["q_norm"], cq)
        q = bitlinear(params["wq_up"], cq, cfg.quant)
    else:
        q = bitlinear(params["wq"], x, cfg.quant)
    q = q.reshape(b, s, nh, qk)
    return q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]


def _mla_expand_kv(params, ckv: Array, cfg: ModelConfig):
    """Expand compressed latent into per-head K_nope and V."""
    b, s, _ = ckv.shape
    nh = cfg.n_heads
    kv = bitlinear(params["wkv_up"], ckv, cfg.quant)
    kv = kv.reshape(b, s, nh, cfg.qk_nope_dim + cfg.v_head_dim)
    return kv[..., : cfg.qk_nope_dim], kv[..., cfg.qk_nope_dim :]


def mla_attention(
    params,
    x: Array,
    cfg: ModelConfig,
    positions: Array,
):
    """Full-sequence MLA (train / eval; serving goes through
    :func:`mla_chunk`)."""
    b, s, _ = x.shape
    nh = cfg.n_heads
    q_nope, q_rope = _mla_q(params, x, cfg)

    down = bitlinear(params["wkv_down"], x, cfg.quant)
    ckv, k_rope = down[..., : cfg.kv_lora_rank], down[..., cfg.kv_lora_rank :]
    ckv = rmsnorm(params["kv_norm"], ckv)
    k_nope, v = _mla_expand_kv(params, ckv, cfg)

    sin, cos = rope_table(positions, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    k_rope = apply_rope(k_rope[:, :, None, :], sin, cos)  # single shared head

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, nh, cfg.qk_rope_dim))], axis=-1
    )
    mask = causal_mask(s, s, 0)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    out = _sdpa(q, k, v, mask, scale=scale)
    subln = params.get("subln")
    return bitlinear(
        params["wo"], out.reshape(b, s, -1), cfg.quant, sublayer_norm=subln
    )


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """MLA caches only the compressed latent + shared rope key — this is the
    architecture's memory win and must be preserved (not expanded K/V)."""
    cache = {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }
    axes = {
        "ckv": ("batch", "cache_seq", None),
        "krope": ("batch", "cache_seq", None),
    }
    return cache, axes


def mla_chunk(
    params,
    x: Array,
    cache: dict,
    pos: Array,
    cfg: ModelConfig,
    active: Array | None = None,
    lengths: Array | None = None,
    read_to: int | None = None,
):
    """Cache-resident multi-token MLA: span-write T compressed latents,
    expand the latent cache (up to the static ``read_to`` bound — see
    :func:`attention_chunk`), and score each query against its causal
    prefix.  T=1 dispatches to :func:`mla_decode` (bit-for-bit the decode
    stream); the latent cache stays dense in both layouts (caching only
    ``(B, L, kv_lora_rank)`` latents is already the memory win paging
    chases) — with no paged K/V to walk, the block-table attention
    kernel does not apply here and MLA keeps its dense latent expansion.
    Returns (y (B, T, D), new_cache)."""
    b, t = x.shape[:2]
    if t == 1 and lengths is None:
        return mla_decode(params, x, cache, pos, cfg, active=active)
    nh = cfg.n_heads
    q_nope, q_rope = _mla_q(params, x, cfg)
    down = bitlinear(params["wkv_down"], x, cfg.quant)
    ckv_new = rmsnorm(params["kv_norm"], down[..., : cfg.kv_lora_rank])
    krope_new = down[..., cfg.kv_lora_rank :]
    pos = jnp.asarray(pos, jnp.int32)
    posmat = _pos_matrix(pos, t)
    q_rope = _rope_at(q_rope, posmat, cfg.qk_rope_dim, cfg.rope_theta)
    krope_new = _rope_at(
        krope_new[:, :, None, :], posmat, cfg.qk_rope_dim, cfg.rope_theta
    )[:, :, 0]

    valid = _chunk_valid(b, t, active, lengths)
    rows = jnp.broadcast_to(posmat, (b, t))
    new_ckv = _span_write(cache["ckv"], ckv_new, rows, valid)
    new_krope = _span_write(cache["krope"], krope_new, rows, valid)
    skv = new_ckv.shape[1]
    lim = skv if read_to is None else min(read_to, skv)
    k_nope, v = _mla_expand_kv(params, new_ckv[:, :lim].astype(x.dtype), cfg)
    k = jnp.concatenate(
        [
            k_nope,
            jnp.broadcast_to(
                new_krope[:, :lim].astype(x.dtype)[:, :, None, :],
                (b, lim, nh, cfg.qk_rope_dim),
            ),
        ],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    mask = _span_mask(posmat, lim)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    out = _sdpa(q, k, v, mask, scale=scale)
    subln = params.get("subln")
    y = bitlinear(params["wo"], out.reshape(b, t, -1), cfg.quant, sublayer_norm=subln)
    return y, {"ckv": new_ckv, "krope": new_krope}


def mla_decode(
    params,
    x: Array,
    cache: dict,
    pos: Array,
    cfg: ModelConfig,
    active: Array | None = None,
):
    """MLA decode keeps the dense latent cache in both serving engines —
    caching only ``(B, L, kv_lora_rank)`` latents is already the memory
    win paging chases, so only the write/mask paths learn per-slot ``pos``
    and ``active``."""
    b = x.shape[0]
    nh = cfg.n_heads
    q_nope, q_rope = _mla_q(params, x, cfg)
    down = bitlinear(params["wkv_down"], x, cfg.quant)
    ckv_new = rmsnorm(params["kv_norm"], down[..., : cfg.kv_lora_rank])
    krope_new = down[..., cfg.kv_lora_rank :]
    pos = jnp.asarray(pos, jnp.int32)
    q_rope = _rope_decode(q_rope, pos, cfg.qk_rope_dim, cfg.rope_theta)
    krope_new = _rope_decode(
        krope_new[:, :, None, :], pos, cfg.qk_rope_dim, cfg.rope_theta
    )[:, :, 0]

    if pos.ndim == 0 and active is None:
        new_ckv = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos, axis=1
        )
        new_krope = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], krope_new.astype(cache["krope"].dtype), pos, axis=1
        )
    else:
        posv = jnp.broadcast_to(pos, (b,))
        new_ckv = _slot_write(cache["ckv"], ckv_new, posv, active)
        new_krope = _slot_write(cache["krope"], krope_new, posv, active)
    skv = new_ckv.shape[1]
    # expand the whole latent cache for scoring (weight-absorption variant is
    # a serving optimisation tracked in EXPERIMENTS.md §Perf)
    k_nope, v = _mla_expand_kv(params, new_ckv.astype(x.dtype), cfg)
    k = jnp.concatenate(
        [
            k_nope,
            jnp.broadcast_to(
                new_krope.astype(x.dtype)[:, :, None, :], (b, skv, nh, cfg.qk_rope_dim)
            ),
        ],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    mask = _decode_mask(pos, skv, ring=False)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    out = _sdpa(q, k, v, mask, scale=scale)
    subln = params.get("subln")
    y = bitlinear(params["wo"], out.reshape(b, 1, -1), cfg.quant, sublayer_norm=subln)
    return y, {"ckv": new_ckv, "krope": new_krope}
