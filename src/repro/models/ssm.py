"""Mamba-2 (SSD — state-space duality) block, attention-free architecture.

Chunked SSD algorithm following the Mamba-2 paper's minimal reference:
within-chunk terms are dense matmuls ("attention-like"), cross-chunk terms
a short recurrence over chunk states — a TPU-friendly decomposition (MXU
for the quadratic-in-chunk terms, small sequential scan across chunks).

pQuant adaptation (DESIGN.md §5): Mamba-2 has no FFN, so the paper's
decoupled layer applies to the in/out projections via
``core.decoupled.decoupled_proj`` (1-bit dominant + r-wide 8-bit bottleneck
branch).  Conv/SSD/gate parameters (<2% of the total) stay FP.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.decoupled import decoupled_proj, init_decoupled_proj
from repro.core.bitlinear import bitlinear, init_linear, init_rmsnorm, rmsnorm
from repro.core.routing import RouterConfig
from repro.distributed.sharding import shard_hint

Array = jax.Array


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_headdim
    conv_dim = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    proj_out = 2 * d_in + 2 * cfg.ssm_groups * cfg.ssm_state + nheads
    return d_in, nheads, conv_dim, proj_out


def init_mamba_block(key: Array, cfg: ModelConfig):
    d = cfg.d_model
    d_in, nheads, conv_dim, proj_out = _dims(cfg)
    ks = jax.random.split(key, 8)
    params, axes = {}, {}

    q = cfg.quant
    if q.mode == "pquant":
        p, a = init_decoupled_proj(
            ks[0], d, proj_out, q.r, axes_out="ffn",
            num_experts=q.num_experts,
            alpha_init=q.alpha_init, beta_init=q.beta_init,
        )
        params["in_proj"], axes["in_proj"] = p, a
        p, a = init_decoupled_proj(
            ks[1], d_in, d, q.r, axes_in="ffn", axes_out="embed",
            num_experts=q.num_experts,
            alpha_init=q.alpha_init, beta_init=q.beta_init,
        )
        params["out_proj"], axes["out_proj"] = p, a
    else:
        p, a = init_linear(ks[0], d, proj_out, ("embed", "ffn"))
        params["in_proj"], axes["in_proj"] = p, a
        p, a = init_linear(ks[1], d_in, d, ("ffn", "embed"))
        params["out_proj"], axes["out_proj"] = p, a

    # depthwise causal conv over [x, B, C]
    params["conv_w"] = (
        jax.random.normal(ks[2], (cfg.conv_kernel, conv_dim), jnp.float32) * 0.02
    )
    axes["conv_w"] = ("conv", "ffn")
    params["conv_b"] = jnp.zeros((conv_dim,), jnp.float32)
    axes["conv_b"] = ("ffn",)

    # SSD per-head parameters
    a_init = jax.random.uniform(ks[3], (nheads,), jnp.float32, 1.0, 16.0)
    params["A_log"] = jnp.log(a_init)
    axes["A_log"] = ("heads",)
    params["D"] = jnp.ones((nheads,), jnp.float32)
    axes["D"] = ("heads",)
    # dt bias: softplus^-1 of dt in [1e-3, 1e-1]
    dt = jnp.exp(
        jax.random.uniform(ks[4], (nheads,), jnp.float32)
        * (jnp.log(0.1) - jnp.log(1e-3))
        + jnp.log(1e-3)
    )
    params["dt_bias"] = dt + jnp.log(-jnp.expm1(-dt))
    axes["dt_bias"] = ("heads",)

    p, a = init_rmsnorm(d_in, axis="ffn")
    params["gate_norm"], axes["gate_norm"] = p, a
    return params, axes


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _segsum(a: Array) -> Array:
    """a: (..., cs). Returns (..., cs, cs) with S[i,j] = sum_{k=j+1..i} a_k
    on the lower triangle (i >= j), -inf above."""
    cs = a.shape[-1]
    ac = jnp.cumsum(a, axis=-1)
    diff = ac[..., :, None] - ac[..., None, :]
    i = jnp.arange(cs)[:, None]
    j = jnp.arange(cs)[None, :]
    return jnp.where(i >= j, diff, -jnp.inf)


def ssd_chunked(
    x: Array,  # (B, L, H, P)  — already multiplied by dt
    dta: Array,  # (B, L, H)     — dt * A (negative log-decays)
    b_mat: Array,  # (B, L, G, N)
    c_mat: Array,  # (B, L, G, N)
    chunk: int,
    initial_state: Array | None = None,  # (B, H, P, N)
):
    """Chunked SSD. Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    l_orig = l
    if l % chunk != 0:
        # pad with identity steps: x=0 contributes nothing, dta=0 -> decay 1
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dta = jnp.pad(dta, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = l + pad
    nc = l // chunk
    rep = h // g

    xc = x.reshape(bsz, nc, chunk, h, p)
    ac = dta.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2)  # (B,H,nc,cs)
    bc = b_mat.reshape(bsz, nc, chunk, g, n)
    cc = c_mat.reshape(bsz, nc, chunk, g, n)
    # broadcast groups to heads
    bh = jnp.repeat(bc, rep, axis=3)  # (B,nc,cs,H,N)
    ch = jnp.repeat(cc, rep, axis=3)

    a_cum = jnp.cumsum(ac, axis=-1)  # (B,H,nc,cs)

    # 1. within-chunk (diagonal blocks)
    l_mat = jnp.exp(_segsum(ac))  # (B,H,nc,cs,cs)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", ch, bh, l_mat, xc)

    # 2. chunk states (decayed contribution of each chunk to its last step)
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # (B,H,nc,cs)
    states = jnp.einsum("bcshn,bhcs,bcshp->bchpn", bh, decay_states, xc)

    # 3. inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(a_cum[..., -1])  # (B,H,nc)
    s0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((bsz, h, p, n), x.dtype)
    )

    def step(carry, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    xs = (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1))
    final, prev_states = jax.lax.scan(step, s0, xs)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # 4. cross-chunk output
    state_decay_out = jnp.exp(a_cum)  # (B,H,nc,cs)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", ch, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(bsz, l, h, p)[:, :l_orig]
    return y, final


def _causal_conv(x: Array, w: Array, b: Array, prev: Array | None = None) -> Array:
    """Depthwise causal conv: x (B, L, C), w (K, C).  ``prev`` (B, K-1, C)
    seeds the window with the cached tail of the preceding tokens (chunked
    serving); ``None`` zero-pads, which is bitwise the same as a zero
    tail — a fresh cache reproduces the from-scratch prefill exactly."""
    k = w.shape[0]
    if prev is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(k):  # K is 4 — unrolled adds, no gather
        out = out + xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def _split_proj(zxbcdt: Array, cfg: ModelConfig):
    d_in, nheads, conv_dim, _ = _dims(cfg)
    gn = cfg.ssm_groups * cfg.ssm_state
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : d_in + conv_dim]
    dt = zxbcdt[..., d_in + conv_dim :]
    return z, xbc, dt


def _apply_in_proj(params, x, cfg: ModelConfig):
    if cfg.quant.mode == "pquant":
        rcfg = (
            RouterConfig(num_experts=cfg.quant.num_experts, top_k=1)
            if cfg.quant.num_experts > 1
            else None
        )
        return decoupled_proj(params["in_proj"], x, cfg.quant, rcfg)
    return bitlinear(params["in_proj"], x, cfg.quant), jnp.zeros((), jnp.float32)


def _apply_out_proj(params, y, cfg: ModelConfig):
    if cfg.quant.mode == "pquant":
        rcfg = (
            RouterConfig(num_experts=cfg.quant.num_experts, top_k=1)
            if cfg.quant.num_experts > 1
            else None
        )
        return decoupled_proj(params["out_proj"], y, cfg.quant, rcfg)
    return bitlinear(params["out_proj"], y, cfg.quant), jnp.zeros((), jnp.float32)


def mamba_block(
    params,
    x: Array,
    cfg: ModelConfig,
    return_cache: bool = False,
    cache: dict | None = None,
):
    """Multi-token Mamba-2 mixing chunk. x: (B, S, D).

    ``cache`` (conv tail + SSD state from :func:`init_mamba_cache` /
    a previous chunk) resumes the recurrence mid-stream — the model
    stack's ``forward_chunk`` runs every chunk with T > 1 through this
    path.  ``cache=None`` is the from-scratch prefill (bitwise identical
    to a zero cache: the conv sees a zero tail either way and the SSD
    scan starts from a zero state).

    Returns (y, aux) or (y, aux, cache) with cache = {conv tail, final
    state} so decode can continue.
    """
    bsz, s, _ = x.shape
    d_in, nheads, conv_dim, _ = _dims(cfg)
    gn = cfg.ssm_groups * cfg.ssm_state

    zxbcdt, aux_in = _apply_in_proj(params, x, cfg)
    z, xbc_raw, dt = _split_proj(zxbcdt, cfg)
    xbc = jax.nn.silu(
        _causal_conv(
            xbc_raw, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype),
            prev=None if cache is None else cache["conv"],
        )
    )
    xs = xbc[..., :d_in]
    b_mat = xbc[..., d_in : d_in + gn].reshape(bsz, s, cfg.ssm_groups, cfg.ssm_state)
    c_mat = xbc[..., d_in + gn :].reshape(bsz, s, cfg.ssm_groups, cfg.ssm_state)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None])
    a = -jnp.exp(params["A_log"])[None, None]  # (1,1,H)
    xh = xs.reshape(bsz, s, nheads, cfg.ssm_headdim)
    xh = shard_hint(xh, "batch", "seq", "act_heads", None)

    y, final_state = ssd_chunked(
        (xh.astype(jnp.float32) * dt[..., None]),
        dt * a,
        b_mat.astype(jnp.float32),
        c_mat.astype(jnp.float32),
        cfg.ssm_chunk,
        initial_state=None if cache is None else cache["state"],
    )
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, d_in).astype(x.dtype)
    y = shard_hint(y, "batch", "seq", "act_ffn")
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z))
    out, aux_out = _apply_out_proj(params, y, cfg)
    if not return_cache:
        return out, aux_in + aux_out
    k = cfg.conv_kernel
    if cache is None:
        tail = xbc_raw[:, s - (k - 1) :, :]
    else:  # short chunks keep part of the previous tail
        tail = jnp.concatenate(
            [cache["conv"], xbc_raw.astype(cache["conv"].dtype)], axis=1
        )[:, -(k - 1) :, :]
    return out, aux_in + aux_out, {"conv": tail, "state": final_state}


# ---------------------------------------------------------------------------
# Decode (single step, constant-size state)
# ---------------------------------------------------------------------------


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    d_in, nheads, conv_dim, _ = _dims(cfg)
    cache = {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
        "state": jnp.zeros(
            (batch, nheads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32
        ),
    }
    axes = {
        "conv": ("batch", None, "act_ffn"),
        "state": ("batch", "act_heads", None, None),
    }
    return cache, axes


def mamba_decode(params, x: Array, cache: dict, cfg: ModelConfig):
    """x: (B, 1, D). Returns (y, new_cache)."""
    bsz = x.shape[0]
    d_in, nheads, conv_dim, _ = _dims(cfg)
    gn = cfg.ssm_groups * cfg.ssm_state

    zxbcdt, aux = _apply_in_proj(params, x, cfg)
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc = xbc[:, 0]  # (B, conv_dim)

    # conv with rolling window state
    win = jnp.concatenate([cache["conv"], xbc[:, None, :].astype(cache["conv"].dtype)], axis=1)
    w = params["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", win.astype(x.dtype), w) + params["conv_b"].astype(x.dtype)
    xbc_t = jax.nn.silu(conv_out)
    new_conv = win[:, 1:]

    xs = xbc_t[..., :d_in].reshape(bsz, nheads, cfg.ssm_headdim)
    b_vec = xbc_t[..., d_in : d_in + gn].reshape(bsz, cfg.ssm_groups, cfg.ssm_state)
    c_vec = xbc_t[..., d_in + gn :].reshape(bsz, cfg.ssm_groups, cfg.ssm_state)
    rep = nheads // cfg.ssm_groups
    b_h = jnp.repeat(b_vec, rep, axis=1)  # (B,H,N)
    c_h = jnp.repeat(c_vec, rep, axis=1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"][None])  # (B,H)
    a = -jnp.exp(params["A_log"])[None]  # (1,H)
    da = jnp.exp(dt * a)  # (B,H)

    xs32 = xs.astype(jnp.float32)
    new_state = cache["state"] * da[..., None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", xs32, b_h.astype(jnp.float32), dt
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c_h.astype(jnp.float32))
    y = y + params["D"][None, :, None] * xs32
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z))
    out, aux_out = _apply_out_proj(params, y, cfg)
    return out, {"conv": new_conv, "state": new_state}
