"""Family-dispatching model API + dry-run input specs.

Every architecture family exposes the same five entry points used by the
trainer / server / dry-run:

    init_model(key, cfg)                          -> (params, axes)
    loss_fn(params, batch, cfg)                   -> (loss, metrics)
    forward(params, batch, cfg)                   -> (logits, aux)
    forward_chunk(params, toks, caches, pos, cfg) -> (logits (B,T,V), caches)
    prefill(params, batch, cfg, cache_len)        -> (logits_last, caches)
    decode_step(params, tokens, caches, pos, cfg) -> (logits, caches)

Decoder families serve through ONE forward implementation: ``prefill`` is
``forward_chunk`` from an empty cache and ``decode_step`` is
``forward_chunk`` with T=1 (see ``models.transformer``).

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of a benchmark cell (weak-type-correct, shardable, zero
allocation) plus their logical axes — the dry-run lowers against these.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer
from repro.telemetry import probes

Array = jax.Array


def _mod(cfg: ModelConfig):
    return encdec if cfg.family == "encdec" else transformer


def init_model(key, cfg: ModelConfig):
    return _mod(cfg).init_model(key, cfg)


def forward(params, batch, cfg: ModelConfig):
    return _mod(cfg).forward(params, batch, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    loss, metrics = _mod(cfg).lm_loss(params, batch, cfg)
    if probes.active():
        # fold the QAT health probes recorded during the forward (clip
        # rates, branch norms, router entropy) into the aux metrics — the
        # one escape hatch through value_and_grad(has_aux=True)
        metrics = dict(metrics)
        metrics.update(probes.summaries())
    return loss, metrics


def prefill(params, batch, cfg: ModelConfig, cache_len: int, last_pos=None):
    """``last_pos`` (optional traced scalar) selects the logits position
    for bucket-padded prompts.  Both families share the signature — the
    serving tiers no longer special-case enc-dec configs."""
    if last_pos is None:
        return _mod(cfg).prefill(params, batch, cfg, cache_len)
    return _mod(cfg).prefill(params, batch, cfg, cache_len, last_pos)


def decode_step(params, tokens, caches, pos, cfg: ModelConfig, active=None):
    """``pos`` may be scalar (lockstep) or (B,) (per-slot, continuous
    batching); ``active`` optionally masks per-slot cache writes.  Both
    families accept both extensions."""
    if active is None and jnp.asarray(pos).ndim == 0:
        return _mod(cfg).decode_step(params, tokens, caches, pos, cfg)
    return _mod(cfg).decode_step(params, tokens, caches, pos, cfg, active)


def forward_chunk(
    params, tokens, caches, pos, cfg: ModelConfig,
    active=None, lengths=None, logits_at=None,
):
    """Cache-resident multi-token forward (decoder families): T tokens per
    slot against resident caches, at per-slot position offsets — the one
    serving forward behind ``prefill`` (empty cache) and ``decode_step``
    (T=1).  See ``models.transformer.forward_chunk`` for the contract."""
    if cfg.family == "encdec":
        raise NotImplementedError(
            "forward_chunk is decoder-family only; encdec prefill keeps "
            "its fused encode+decoder path"
        )
    return _mod(cfg).forward_chunk(
        params, tokens, caches, pos, cfg, active=active, lengths=lengths,
        logits_at=logits_at,
    )


def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    dtype=jnp.bfloat16,
    layout: str = "dense",
    block_size: int = 16,
    num_blocks: int | None = None,
):
    if layout == "dense":
        return _mod(cfg).init_cache(cfg, batch, max_len, dtype)
    if cfg.family == "encdec":
        raise NotImplementedError("paged KV caches are decoder-family only")
    return _mod(cfg).init_cache(
        cfg, batch, max_len, dtype, layout, block_size, num_blocks
    )


def params_shape_and_axes(cfg: ModelConfig):
    """ShapeDtypeStructs for params plus the logical-axes tree."""
    axes_box = {}

    def only_params(key):
        p, a = init_model(key, cfg)
        axes_box["axes"] = a
        return p

    shapes = jax.eval_shape(only_params, jax.random.PRNGKey(0))
    return shapes, axes_box["axes"]


# ---------------------------------------------------------------------------
# Dry-run input specs
# ---------------------------------------------------------------------------


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> tuple[dict, dict]:
    """(specs, logical_axes) for one benchmark cell.

    train:   full batch with labels.
    prefill: prompt batch (no labels).
    decode:  one new token + KV caches at seq_len + scalar position.
    """
    b, s = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    batch_ax = ("batch", None)

    if cfg.family == "encdec":
        f = cfg.n_frontend_tokens
        if shape.kind in ("train", "prefill"):
            specs: dict[str, Any] = {
                "frames": sds((b, f, cfg.d_model), bf16),
                "tokens": sds((b, s), i32),
            }
            axes: dict[str, Any] = {
                "frames": ("batch", None, "act_embed"),
                "tokens": batch_ax,
            }
            if shape.kind == "train":
                specs["labels"] = sds((b, s), i32)
                axes["labels"] = batch_ax
            return specs, axes
        # decode
        cache, cache_axes = jax.eval_shape(
            lambda: init_cache(cfg, b, s, bf16)[0]
        ), init_cache_axes(cfg, b, s)
        return (
            {"tokens": sds((b, 1), i32), "caches": cache,
             "pos": sds((), i32)},
            {"tokens": batch_ax, "caches": cache_axes, "pos": ()},
        )

    n_img = cfg.n_image_tokens
    if shape.kind in ("train", "prefill"):
        s_text = s - n_img if n_img else s
        specs = {"tokens": sds((b, s_text), i32)}
        axes = {"tokens": batch_ax}
        if n_img:
            specs["image_embeds"] = sds((b, n_img, cfg.d_model), bf16)
            axes["image_embeds"] = ("batch", None, "act_embed")
        if shape.kind == "train":
            specs["labels"] = sds((b, s_text), i32)
            axes["labels"] = batch_ax
        return specs, axes

    # decode: caches at length s
    cache_shapes = jax.eval_shape(lambda: init_cache(cfg, b, s, bf16)[0])
    cache_axes = init_cache_axes(cfg, b, s)
    return (
        {"tokens": sds((b, 1), i32), "caches": cache_shapes, "pos": sds((), i32)},
        {"tokens": batch_ax, "caches": cache_axes, "pos": ()},
    )


def init_cache_axes(cfg: ModelConfig, batch: int, max_len: int):
    """Logical axes of the cache pytree (no allocation; init_cache returns
    (cache, axes) and axes is plain python)."""
    box = {}

    def f():
        c, a = init_cache(cfg, batch, max_len, jnp.bfloat16)
        box["a"] = a
        return c

    jax.eval_shape(f)
    return box["a"]
