"""DeepSeekMoE-style mixture-of-experts FFN (shared + routed experts).

Composition with pQuant (DESIGN.md §5): in ``pquant`` mode the routed
experts' FFNs are 1-bit (they are the capacity pool) while the *shared*
experts — always active, analogous to pQuant's own shared 1-bit branch —
carry the decoupled 8-bit branch that preserves sensitive parameters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import routing
from repro.core.decoupled import ACTIVATIONS
from repro.core.quantization import (
    QuantConfig,
    fake_quant_stacked,
    is_packed_1bit,
    maybe_quant_acts,
)
from repro.core.routing import RouterConfig
from repro.distributed.sharding import shard_hint
from repro.models.layers import apply_ffn, init_ffn

Array = jax.Array


def init_moe_ffn(key: Array, cfg: ModelConfig):
    """Parameters for one MoE FFN layer."""
    d = cfg.d_model
    e = cfg.n_routed_experts
    de = cfg.d_ff_expert
    ks = jax.random.split(key, 6)
    params, axes = {}, {}

    s_in = d**-0.5
    shapes = [("we_up", (e, d, de), ("experts", "embed", "expert_ffn"))]
    if cfg.glu:
        shapes.append(("we_gate", (e, d, de), ("experts", "embed", "expert_ffn")))
    shapes.append(("we_down", (e, de, d), ("experts", "expert_ffn", "embed")))
    for i, (name, shp, ax) in enumerate(shapes):
        scale = s_in if shp[1] == d else de**-0.5
        params[name] = (
            jax.random.truncated_normal(ks[i], -3, 3, shp, jnp.float32) * scale
        )
        axes[name] = ax

    rp, ra = routing.init_router(
        ks[3], d, RouterConfig(num_experts=e, top_k=cfg.moe_top_k)
    )
    params["router"], axes["router"] = rp, ra

    if cfg.n_shared_experts > 0:
        # shared experts fused into one FFN of width s*d_ff_expert; in pquant
        # mode this FFN carries the decoupled 8-bit branch (see DESIGN.md §5)
        sp, sa = init_ffn(ks[4], cfg, d_ff=cfg.n_shared_experts * de)
        params["shared"], axes["shared"] = sp, sa
    return params, axes


def _expert_wq(qcfg: QuantConfig, dtype):
    """Per-expert weight quantizer; with qgather enabled the FSDP gather
    moves INT8 signs (EXPERIMENTS.md §Perf, Cell C follow-up)."""
    if qcfg.qgather and qcfg.mode in ("bitnet", "pquant"):
        from repro.distributed.qgather import binarize_gather_stacked

        def wq(w, axes=("experts", "embed", "expert_ffn")):
            if isinstance(w, dict):
                return fake_quant_stacked(w, qcfg).astype(dtype)
            return binarize_gather_stacked(w, axes).astype(dtype)

        return wq
    return lambda w, axes=None: fake_quant_stacked(w, qcfg).astype(dtype)


def _experts_packed(params, glu: bool) -> bool:
    """True when every expert weight is the bit-packed serving layout
    {"packed": (E, D//8, F) uint8, "scale": (E, 1, 1)} (per-slice packing,
    see train/quantized_serving)."""
    names = ("we_gate", "we_up", "we_down") if glu else ("we_up", "we_down")
    return all(is_packed_1bit(params[n]) for n in names)


def _experts_apply_packed(params, xe: Array, cfg: ModelConfig) -> Array:
    """Packed-serving expert FFN: one W1A8 kernel call per expert slice
    (E is static, so this unrolls; each expert keeps its own AbsMean scale).
    xe: (..., E, C, D) with the expert axis second-to-third-from-last."""
    from repro.kernels import ops

    act = ACTIVATIONS[cfg.activation]
    e_ax = xe.ndim - 3
    n_e = xe.shape[e_ax]

    def lin(name, h, e):
        w = params[name]
        return ops.bit_linear_infer(
            h, w["packed"][e], w["scale"][e], out_dtype=xe.dtype
        )

    outs = []
    for e in range(n_e):
        x_e = jnp.take(xe, e, axis=e_ax)
        up = lin("we_up", x_e, e)
        h = act(lin("we_gate", x_e, e)) * up if cfg.glu else act(up)
        outs.append(lin("we_down", h, e))
    return jnp.stack(outs, axis=e_ax)


def _experts_apply(params, xe: Array, cfg: ModelConfig, qcfg: QuantConfig) -> Array:
    """Batched expert FFN: xe (E, C, D) -> (E, C, D), per-expert quantized."""
    if _experts_packed(params, cfg.glu):
        return _experts_apply_packed(params, xe, cfg)
    act = ACTIVATIONS[cfg.activation]
    wq = _expert_wq(qcfg, xe.dtype)
    xq = maybe_quant_acts(xe, qcfg)
    up = jnp.einsum("ecd,edf->ecf", xq, wq(params["we_up"]))
    if cfg.glu:
        h = act(jnp.einsum("ecd,edf->ecf", xq, wq(params["we_gate"]))) * up
    else:
        h = act(up)
    hq = maybe_quant_acts(h, qcfg)
    return jnp.einsum(
        "ecf,efd->ecd", hq,
        wq(params["we_down"], ("experts", "expert_ffn", "embed")),
    )


def _experts_apply_grouped(params, xe: Array, cfg: ModelConfig, qcfg) -> Array:
    """Batched expert FFN for einsum dispatch: (G, E, C, D) -> (G, E, C, D)."""
    if _experts_packed(params, cfg.glu):
        # bit_linear_infer flattens the (G, C) token axes per expert slice
        return _experts_apply_packed(params, xe, cfg)
    act = ACTIVATIONS[cfg.activation]
    wq = _expert_wq(qcfg, xe.dtype)
    xq = maybe_quant_acts(xe, qcfg)
    up = jnp.einsum("gecd,edf->gecf", xq, wq(params["we_up"]))
    if cfg.glu:
        h = act(jnp.einsum("gecd,edf->gecf", xq, wq(params["we_gate"]))) * up
    else:
        h = act(up)
    hq = maybe_quant_acts(h, qcfg)
    return jnp.einsum(
        "gecf,efd->gecd", hq,
        wq(params["we_down"], ("experts", "expert_ffn", "embed")),
    )


def moe_ffn(params, x: Array, cfg: ModelConfig):
    """Apply MoE FFN over (..., D). Returns (y, aux_loss)."""
    lead, d = x.shape[:-1], x.shape[-1]
    xf = x.reshape(-1, d)
    rcfg = RouterConfig(
        num_experts=cfg.n_routed_experts,
        top_k=cfg.moe_top_k,
        capacity_factor=cfg.moe_capacity_factor,
    )
    probs, logits = routing.router_probs(params["router"], xf)

    if cfg.moe_dispatch == "einsum":
        gs = min(cfg.moe_group_size, xf.shape[0])
        combine, dispatch, aux = routing.einsum_dispatch_combine(probs, rcfg, gs)
        # DeepSeek-style top-k gate normalization
        denom = jnp.sum(combine, axis=(-1, -2), keepdims=True) + 1e-9
        combine = combine / denom
        g = xf.shape[0] // gs
        xg = xf.reshape(g, gs, d)
        combine = shard_hint(combine.astype(x.dtype), "batch", None, "experts", None)
        dispatch = shard_hint(dispatch.astype(x.dtype), "batch", None, "experts", None)
        xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
        xe = shard_hint(xe, "batch", "experts", None, "act_embed")
        ye = _experts_apply_grouped(params, xe, cfg, cfg.quant)
        y = jnp.einsum("gsec,gecd->gsd", combine, ye).reshape(-1, d)
    else:
        dispatch = routing.topk_dispatch(probs, rcfg)
        # DeepSeek normalizes the selected top-k gates to sum to 1
        cw = dispatch["combine_weight"]
        dispatch["combine_weight"] = cw / (jnp.sum(cw, axis=-1, keepdims=True) + 1e-9)
        xe = routing.dispatch_gather(xf, dispatch)
        xe = shard_hint(xe, "experts", None, "act_embed")
        ye = _experts_apply(params, xe, cfg, cfg.quant)
        y = routing.combine_scatter(ye, dispatch, xf.shape[0])
        aux = dispatch["aux_loss"]
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * rcfg.router_z_weight
    aux = aux + z.astype(aux.dtype)

    if "shared" in params:
        ys, aux_s = apply_ffn(params["shared"], xf, cfg)
        y = y + ys
        aux = aux + aux_s
    return y.reshape(*lead, d), aux
