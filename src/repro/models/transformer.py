"""Decoder LM assembly: dense / MoE / SSM / hybrid families behind one API.

The model is a list of *segments*; each segment is a group of heterogeneous
blocks repeated R times.  Repeated segments are executed with
``jax.lax.scan`` over stacked parameters so the compiled HLO is O(1) in
depth (production-scale compile times at 512 devices), with per-layer
metadata (sliding-window size, local/global RoPE selection) passed as
scanned arrays so architectures like Gemma-3 (5 local : 1 global) keep a
single uniform scan.

API:
  init_model(key, cfg)                      -> (params, axes)
  forward(params, batch, cfg)               -> (logits, aux_loss)
  init_cache(cfg, batch, max_len, dtype)    -> (cache, axes)
  forward_chunk(params, toks, cache, pos, cfg) -> (logits (B,T,V), cache)
  prefill(params, batch, cfg, cache_len)    -> (logits_last, cache)
  decode_step(params, token, cache, pos, cfg) -> (logits, cache)

Serving runs ONE forward implementation: ``forward_chunk`` processes T
tokens per slot against resident caches (dense ring or paged), ``prefill``
is forward_chunk from an empty cache, and ``decode_step`` is forward_chunk
with T=1.  ``forward`` keeps the cache-free full-sequence path for
train/eval.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_hint
from repro.telemetry import probes
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_ffn,
    cross_entropy_loss,
    embed,
    init_embedding,
    init_ffn,
    init_rmsnorm,
    rmsnorm,
    rope_table,
    unembed,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# Segment plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    mixer: str  # attn | mla | ssm | rec
    ffn: Optional[str]  # dense | moe | None
    # static sliding window of this block (0 = full attention).  Determines
    # the KV-cache length: windowed layers keep a RING cache of exactly
    # `window` positions (gemma3 long_500k: 52/62 layers cache 1024, not 512k)
    window: int = 0


@dataclasses.dataclass(frozen=True)
class Segment:
    repeats: int
    blocks: tuple[BlockSpec, ...]
    # absolute layer index of the first block (for window/theta metadata)
    first_layer: int


def build_segments(cfg: ModelConfig) -> list[Segment]:
    if cfg.family == "ssm":
        return [Segment(cfg.n_layers, (BlockSpec("ssm", None),), 0)]
    if cfg.family == "hybrid":
        pat = cfg.block_pattern
        g = len(pat)
        reps, rem = divmod(cfg.n_layers, g)
        def bs(m, layer):
            return BlockSpec(m, "dense", layer_window(cfg, layer))
        segs = [
            Segment(reps, tuple(bs(m, i) for i, m in enumerate(pat)), 0)
        ]
        if rem:
            segs.append(
                Segment(
                    1, tuple(bs(m, reps * g + i) for i, m in enumerate(pat[:rem])),
                    reps * g,
                )
            )
        return segs
    # decoder family (incl. MoE)
    mixer = "mla" if cfg.attn_type == "mla" else "attn"
    if cfg.moe:
        k = cfg.first_k_dense
        segs = []
        if k > 0:
            segs.append(Segment(1, tuple(BlockSpec(mixer, "dense") for _ in range(k)), 0))
        segs.append(Segment(cfg.n_layers - k, (BlockSpec(mixer, "moe"),), k))
        return segs
    if cfg.global_every > 0:
        # group by the local:global period so per-block cache lengths are
        # uniform across scan repeats (local blocks get ring caches)
        g = cfg.global_every
        reps, rem = divmod(cfg.n_layers, g)
        blocks = tuple(
            BlockSpec(mixer, "dense", layer_window(cfg, i)) for i in range(g)
        )
        segs = [Segment(reps, blocks, 0)]
        if rem:
            segs.append(
                Segment(
                    1,
                    tuple(BlockSpec(mixer, "dense", layer_window(cfg, reps * g + i))
                          for i in range(rem)),
                    reps * g,
                )
            )
        return segs
    win = cfg.window_size if cfg.attn_type == "swa" else 0
    return [Segment(cfg.n_layers, (BlockSpec(mixer, "dense", win),), 0)]


def layer_window(cfg: ModelConfig, layer: int) -> int:
    """Static per-layer sliding window (0 = global/full)."""
    if cfg.global_every > 0:
        return 0 if (layer + 1) % cfg.global_every == 0 else cfg.window_size
    if cfg.attn_type == "swa":
        return cfg.window_size
    return 0


def layer_uses_local_rope(cfg: ModelConfig, layer: int) -> bool:
    return cfg.global_every > 0 and (layer + 1) % cfg.global_every != 0


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------


def _init_block(key: Array, spec: BlockSpec, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}

    def add(name, pa):
        params[name], axes[name] = pa

    add("pre_norm", init_rmsnorm(cfg.d_model, axis="act_embed"))
    if spec.mixer == "attn":
        add("mixer", attn_mod.init_attention(ks[0], cfg))
    elif spec.mixer == "mla":
        add("mixer", attn_mod.init_mla(ks[0], cfg))
    elif spec.mixer == "ssm":
        add("mixer", ssm_mod.init_mamba_block(ks[0], cfg))
    elif spec.mixer == "rec":
        add("mixer", rglru_mod.init_rglru_block(ks[0], cfg))
    else:
        raise ValueError(spec.mixer)

    if spec.ffn is not None:
        add("ffn_norm", init_rmsnorm(cfg.d_model, axis="act_embed"))
        if spec.ffn == "dense":
            add("ffn", init_ffn(ks[1], cfg))
        elif spec.ffn == "moe":
            add("ffn", moe_mod.init_moe_ffn(ks[1], cfg))
        else:
            raise ValueError(spec.ffn)
    return params, axes


def _apply_mixer(
    bparams,
    spec: BlockSpec,
    x: Array,
    cfg: ModelConfig,
    rope_tabs,
    meta: dict,
):
    """Full-sequence mixer (train / eval — no cache).  Serving paths run
    :func:`forward_chunk` instead.  Returns (y, aux)."""
    zero = jnp.zeros((), jnp.float32)
    if spec.mixer == "attn":
        sin, cos = rope_tabs
        if cfg.global_every > 0:
            use_local = meta["use_local_rope"]
            sin_g, sin_l = sin
            cos_g, cos_l = cos
            sin = jnp.where(use_local, sin_l, sin_g)
            cos = jnp.where(use_local, cos_l, cos_g)
        else:
            sin, cos = sin[0], cos[0]
        out = attn_mod.attention(
            bparams["mixer"], x, cfg, sin, cos,
            window=meta["window"], causal=cfg.family != "encoder",
        )
        return out, zero
    if spec.mixer == "mla":
        pos = jnp.arange(x.shape[1])
        return attn_mod.mla_attention(bparams["mixer"], x, cfg, pos), zero
    if spec.mixer == "ssm":
        return ssm_mod.mamba_block(bparams["mixer"], x, cfg)
    if spec.mixer == "rec":
        return rglru_mod.rglru_block(bparams["mixer"], x, cfg), zero
    raise ValueError(spec.mixer)


def _apply_block(
    bparams,
    spec: BlockSpec,
    x: Array,
    cfg: ModelConfig,
    rope_tabs,
    meta,
):
    """Pre-norm residual block. Returns (x, aux)."""
    h = rmsnorm(bparams["pre_norm"], x)
    y, aux = _apply_mixer(bparams, spec, h, cfg, rope_tabs, meta)
    x = x + y
    if spec.ffn is not None:
        h = rmsnorm(bparams["ffn_norm"], x)
        if spec.ffn == "moe":
            y, aux2 = moe_mod.moe_ffn(bparams["ffn"], h, cfg)
        else:
            y, aux2 = apply_ffn(bparams["ffn"], h, cfg)
        x = x + y
        aux = aux + aux2
    # "resid_seq" (default unsharded) enables sequence parallelism via a
    # rule override: the residual stream shards over `model` between
    # blocks, turning TP all-reduces into reduce-scatter/all-gather pairs
    x = shard_hint(x, "batch", "resid_seq", "act_embed")
    return x, aux


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------


def _stack_trees(trees: list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _stack_axes(axes):
    """Prepend the (unsharded) layer-stack axis to every axes tuple."""
    return jax.tree.map(
        lambda a: ("layers",) + tuple(a), axes, is_leaf=lambda t: isinstance(t, tuple)
    )


def init_model(key: Array, cfg: ModelConfig):
    segs = build_segments(cfg)
    keys = jax.random.split(key, len(segs) + 2)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}

    p, a = init_embedding(keys[0], cfg.vocab_size, cfg.d_model)
    params["embed"], axes["embed"] = p, a

    seg_params, seg_axes = [], []
    for si, seg in enumerate(segs):
        skeys = jax.random.split(keys[si + 1], seg.repeats)
        blocks_p, blocks_a = {}, {}
        for bi, spec in enumerate(seg.blocks):
            if seg.repeats == 1:
                bp, ba = _init_block(
                    jax.random.fold_in(skeys[0], bi), spec, cfg
                )
            else:
                reps = [
                    _init_block(jax.random.fold_in(skeys[r], bi), spec, cfg)
                    for r in range(seg.repeats)
                ]
                bp = _stack_trees([r[0] for r in reps])
                ba = _stack_axes(reps[0][1])
            blocks_p[f"b{bi}"] = bp
            blocks_a[f"b{bi}"] = ba
        seg_params.append(blocks_p)
        seg_axes.append(blocks_a)
    params["segments"] = seg_params
    axes["segments"] = seg_axes

    p, a = init_rmsnorm(cfg.d_model, axis="act_embed")
    params["final_norm"], axes["final_norm"] = p, a
    if not cfg.tie_embeddings:
        p, a = init_embedding(keys[-1], cfg.vocab_size, cfg.d_model)
        params["lm_head"], axes["lm_head"] = p, a
    return params, axes


# ---------------------------------------------------------------------------
# Metadata (per-layer window / rope selection) for scans
# ---------------------------------------------------------------------------


def _segment_meta(cfg: ModelConfig, seg: Segment):
    """Stacked per-repeat metadata arrays for each block in the segment."""
    metas = []
    for bi in range(len(seg.blocks)):
        layers = [seg.first_layer + r * len(seg.blocks) + bi for r in range(seg.repeats)]
        metas.append(
            {
                "window": jnp.asarray([layer_window(cfg, l) for l in layers], jnp.int32),
                "use_local_rope": jnp.asarray(
                    [layer_uses_local_rope(cfg, l) for l in layers], bool
                ),
            }
        )
    return metas


def _rope_tabs(cfg: ModelConfig, positions: Array):
    if cfg.pos_embedding != "rope":
        return None
    sin_g, cos_g = rope_table(positions, cfg.head_dim, cfg.rope_theta)
    if cfg.global_every > 0:
        sin_l, cos_l = rope_table(positions, cfg.head_dim, cfg.rope_theta_local)
        return (sin_g, sin_l), (cos_g, cos_l)
    return (sin_g,), (cos_g,)


# ---------------------------------------------------------------------------
# Forward (train / eval, full sequence)
# ---------------------------------------------------------------------------


def _run_segments(params, x: Array, cfg: ModelConfig, rope_tabs):
    """Train/eval segment walk (no caches — serving walks the same
    segments through :func:`forward_chunk`).  Returns (x, aux_total)."""
    segs = build_segments(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    for si, seg in enumerate(segs):
        seg_p = params["segments"][si]
        metas = _segment_meta(cfg, seg)
        if seg.repeats == 1:
            for bi, spec in enumerate(seg.blocks):
                meta = {k: v[0] for k, v in metas[bi].items()}
                x, aux = _apply_block(
                    seg_p[f"b{bi}"], spec, x, cfg, rope_tabs, meta
                )
                aux_total = aux_total + aux
        elif not cfg.scan_layers:
            # unrolled execution (scan_layers=False): bigger HLO, exact
            # per-layer cost accounting; used by roofline calibration.
            # remat is applied per group so compute matches the scanned path.
            def one_group(x_aux, layer_p, metas_r, rr):
                x, aux_acc = x_aux
                for bi, spec in enumerate(seg.blocks):
                    x, aux = _apply_block(
                        layer_p[f"b{bi}"], spec, x, cfg, rope_tabs,
                        metas_r[f"b{bi}"],
                    )
                    aux_acc = aux_acc + aux
                # probe values recorded inside a remat-wrapped group must
                # leave as outputs (the rematerialized trace is a boundary
                # like a scan body); None when probes are off
                return (x, aux_acc), probes.scan_drain()

            if cfg.remat:
                one_group = jax.checkpoint(
                    one_group,
                    policy=jax.checkpoint_policies.nothing_saveable,
                    static_argnums=(3,),
                )
            for r in range(seg.repeats):
                layer_p = jax.tree.map(lambda t: t[r], seg_p)
                metas_r = {
                    f"b{bi}": {k: v[r] for k, v in metas[bi].items()}
                    for bi in range(len(seg.blocks))
                }
                (x, aux_total), drained = one_group(
                    (x, aux_total), layer_p, metas_r, r
                )
                probes.merge(drained)
        else:

            def body(carry, inp):
                x, aux_acc = carry
                bp_all, meta_all = inp
                aux_layer = jnp.zeros((), jnp.float32)
                for bi, spec in enumerate(seg.blocks):
                    x, aux = _apply_block(
                        bp_all[f"b{bi}"], spec, x, cfg, rope_tabs,
                        meta_all[f"b{bi}"],
                    )
                    aux_layer = aux_layer + aux
                # probe values recorded in the body are body-trace tracers:
                # they leave the scan as ys (None when probes are off) and
                # scan_merge sums them over the layer axis below
                return (x, aux_acc + aux_layer), probes.scan_drain()

            if cfg.remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable
                )
            xs = (seg_p, {f"b{bi}": metas[bi] for bi in range(len(seg.blocks))})
            with probes.scan_scope():
                (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), xs)
                probes.scan_merge(ys)
    return x, aux_total


def _embed_inputs(params, batch: dict, cfg: ModelConfig) -> Array:
    x = embed(params["embed"], batch["tokens"], cfg)
    if cfg.n_image_tokens > 0 and "image_embeds" in batch:
        img = batch["image_embeds"].astype(x.dtype)
        x = jnp.concatenate([img, x], axis=1)
    return x


def forward(params, batch: dict, cfg: ModelConfig):
    """batch: {"tokens": (B,S)} (+ "image_embeds" for VLM).
    Returns (logits (B,S_total,V), aux_loss)."""
    x = _embed_inputs(params, batch, cfg)
    x = shard_hint(x, "batch", "seq", "act_embed")
    positions = jnp.arange(x.shape[1])
    tabs = _rope_tabs(cfg, positions)
    x, aux = _run_segments(params, x, cfg, tabs)
    x = rmsnorm(params["final_norm"], x)
    head = params.get("lm_head", params["embed"])
    logits = unembed(head, x, cfg)
    logits = shard_hint(logits, "batch", "seq", "vocab")
    return logits, aux


def lm_loss(params, batch: dict, cfg: ModelConfig):
    """Next-token CE over the text positions. batch needs "tokens" and
    "labels" (both (B,S)); image positions (if any) are excluded."""
    logits, aux = forward(params, batch, cfg)
    n_img = cfg.n_image_tokens if "image_embeds" in batch else 0
    logits = logits[:, n_img:]
    loss, nll = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return loss + aux.astype(loss.dtype), {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# KV-cache init / prefill / decode
# ---------------------------------------------------------------------------


def _init_block_cache(
    spec: BlockSpec,
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    dtype,
    layout: str = "dense",
    block_size: int = 16,
    num_blocks: int | None = None,
):
    if spec.mixer == "attn":
        if spec.window > 0:
            # sliding-window layers keep the dense RING cache in both
            # layouts: a W-length ring IS the window, and W is small
            length = min(spec.window, max_len)
            return attn_mod.init_attention_cache(cfg, batch, length, dtype)
        if layout == "paged":
            from repro.serve import kv_pool  # deferred: serve imports models

            nb = num_blocks or batch * kv_pool.blocks_for(max_len, block_size)
            return kv_pool.init_paged_attention_cache(
                batch, max_len, cfg.n_kv_heads, cfg.head_dim, nb,
                block_size, dtype,
            )
        return attn_mod.init_attention_cache(cfg, batch, max_len, dtype)
    if spec.mixer == "mla":
        return attn_mod.init_mla_cache(cfg, batch, max_len, dtype)
    if spec.mixer == "ssm":
        return ssm_mod.init_mamba_cache(cfg, batch, dtype)
    if spec.mixer == "rec":
        return rglru_mod.init_rglru_cache(cfg, batch, dtype)
    raise ValueError(spec.mixer)


def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    dtype=jnp.bfloat16,
    layout: str = "dense",
    block_size: int = 16,
    num_blocks: int | None = None,
):
    """Cache pytree for decode.  ``layout="dense"`` is the per-slot buffer
    layout every caller gets by default; ``layout="paged"`` swaps global-
    attention layers to the shared block pool (``repro.serve.kv_pool``) —
    same tree structure, interchangeable at every decode call site."""
    if layout not in ("dense", "paged"):
        raise ValueError(f"unknown cache layout {layout!r}")
    segs = build_segments(cfg)
    caches, axes = [], []
    for seg in segs:
        seg_c, seg_a = {}, {}
        for bi, spec in enumerate(seg.blocks):
            c, a = _init_block_cache(
                spec, cfg, batch, max_len, dtype, layout, block_size,
                num_blocks,
            )
            if seg.repeats > 1:
                c = jax.tree.map(
                    lambda t: jnp.broadcast_to(t[None], (seg.repeats,) + t.shape), c
                )
                a = _stack_axes(a)
            seg_c[f"b{bi}"] = c
            seg_a[f"b{bi}"] = a
        caches.append(seg_c)
        axes.append(seg_a)
    return caches, axes


def _freeze_inactive(new_cache, old_cache, active):
    """Keep inactive slots' recurrent state untouched (ssm/rec mixers
    update state unconditionally; attention variants mask writes inline)."""

    def keep(n, o):
        a = active.reshape(active.shape + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o.astype(n.dtype))

    return jax.tree.map(keep, new_cache, old_cache)


def _mixer_chunk(
    bparams, spec: BlockSpec, x, cache, pos, cfg: ModelConfig, meta,
    active=None, lengths=None, read_to=None,
):
    """T-token cache-resident mixer.  Attention variants run their chunk
    entry points (which keep the one-token decode fast path at T=1);
    recurrent mixers run the block form from their cached state for T > 1
    and the preserved step form at T=1 — step and block are the same
    recurrence in different float associations, so one-token decode
    streams stay bit-for-bit what they were."""
    t = x.shape[1]
    if spec.mixer == "attn":
        if cfg.global_every > 0:
            theta = jnp.where(
                meta["use_local_rope"], cfg.rope_theta_local, cfg.rope_theta
            )
        else:
            theta = cfg.rope_theta
        return attn_mod.attention_chunk(
            bparams["mixer"], x, cache, pos, cfg, theta,
            window=meta["window"], active=active, lengths=lengths,
            ring=spec.window > 0, read_to=read_to,
        )
    if spec.mixer == "mla":
        return attn_mod.mla_chunk(
            bparams["mixer"], x, cache, pos, cfg, active=active,
            lengths=lengths, read_to=read_to,
        )
    if spec.mixer not in ("ssm", "rec"):
        raise ValueError(spec.mixer)
    if lengths is not None:
        raise NotImplementedError(
            "ragged chunk lengths are attention-family only (recurrent "
            "state would integrate the pad tail); chunked admission "
            "prefill gates on _chunked_prefill_safe accordingly"
        )
    if t == 1:
        if spec.mixer == "ssm":
            out = ssm_mod.mamba_decode(bparams["mixer"], x, cache, cfg)
        else:
            out = rglru_mod.rglru_decode(bparams["mixer"], x, cache, cfg)
    elif spec.mixer == "ssm":
        y, _, nc = ssm_mod.mamba_block(
            bparams["mixer"], x, cfg, return_cache=True, cache=cache
        )
        out = (y, nc)
    else:
        out = rglru_mod.rglru_block(
            bparams["mixer"], x, cfg, return_cache=True, cache=cache
        )
    if active is not None:
        out = (out[0], _freeze_inactive(out[1], cache, active))
    return out


def _chunk_block(
    bparams, spec, x, cache, pos, cfg, meta, active=None, lengths=None,
    read_to=None,
):
    h = rmsnorm(bparams["pre_norm"], x)
    y, new_cache = _mixer_chunk(
        bparams, spec, h, cache, pos, cfg, meta, active, lengths, read_to
    )
    x = x + y
    if spec.ffn is not None:
        h = rmsnorm(bparams["ffn_norm"], x)
        if spec.ffn == "moe":
            y, _ = moe_mod.moe_ffn(bparams["ffn"], h, cfg)
        else:
            y, _ = apply_ffn(bparams["ffn"], h, cfg)
        x = x + y
    return x, new_cache


def decode_step(
    params, tokens: Array, caches, pos: Array, cfg: ModelConfig,
    active: Array | None = None,
):
    """One decode step — :func:`forward_chunk` with T=1.  tokens: (B, 1)
    int32; pos: scalar int32 (lockstep: every slot at the same write
    index) or (B,) int32 (per-slot positions, continuous batching).
    ``active`` optionally masks cache writes per slot.  Returns
    (logits (B,1,V), new_caches)."""
    return forward_chunk(params, tokens, caches, pos, cfg, active=active)


def _forward_chunk_x(
    params, x: Array, caches, pos: Array, cfg: ModelConfig,
    active: Array | None = None, lengths: Array | None = None,
    read_to: int | None = None,
):
    """Segment walk of :func:`_chunk_block` over embedded inputs
    x (B, T, D).  Returns (hidden (B, T, D), new_caches) — the shared
    core under ``forward_chunk`` / ``prefill`` / ``decode_step``."""
    segs = build_segments(cfg)
    new_caches = []
    for si, seg in enumerate(segs):
        seg_p = params["segments"][si]
        seg_c = caches[si]
        metas = _segment_meta(cfg, seg)
        if seg.repeats == 1:
            new_seg = {}
            for bi, spec in enumerate(seg.blocks):
                meta = {k: v[0] for k, v in metas[bi].items()}
                x, nc = _chunk_block(
                    seg_p[f"b{bi}"], spec, x, seg_c[f"b{bi}"], pos, cfg, meta,
                    active, lengths, read_to,
                )
                new_seg[f"b{bi}"] = nc
            new_caches.append(new_seg)
        elif not cfg.scan_layers:
            reps = []
            for r in range(seg.repeats):
                layer_p = jax.tree.map(lambda t: t[r], seg_p)
                layer_c = jax.tree.map(lambda t: t[r], seg_c)
                new_c = {}
                for bi, spec in enumerate(seg.blocks):
                    meta = {k: v[r] for k, v in metas[bi].items()}
                    x, nc = _chunk_block(
                        layer_p[f"b{bi}"], spec, x, layer_c[f"b{bi}"], pos,
                        cfg, meta, active, lengths, read_to,
                    )
                    new_c[f"b{bi}"] = nc
                reps.append(new_c)
            new_caches.append(jax.tree.map(lambda *xs: jnp.stack(xs), *reps))
        else:

            def body(x, inp):
                bp_all, c_all, meta_all = inp
                new_c = {}
                for bi, spec in enumerate(seg.blocks):
                    x, nc = _chunk_block(
                        bp_all[f"b{bi}"], spec, x, c_all[f"b{bi}"], pos, cfg,
                        meta_all[f"b{bi}"], active, lengths, read_to,
                    )
                    new_c[f"b{bi}"] = nc
                return x, new_c

            xs = (
                seg_p,
                seg_c,
                {f"b{bi}": metas[bi] for bi in range(len(seg.blocks))},
            )
            x, new_seg = jax.lax.scan(body, x, xs)
            new_caches.append(new_seg)
    return x, new_caches


def forward_chunk(
    params, tokens: Array, caches, pos: Array, cfg: ModelConfig,
    active: Array | None = None, lengths: Array | None = None,
    logits_at: Array | None = None,
):
    """Cache-resident multi-token forward: the single serving code path.

    tokens: (B, T) int32; pos: scalar or (B,) int32 — absolute position
    of ``tokens[:, 0]`` per slot.  K/V (or recurrent state) for tokens
    ``t < lengths[b]`` (default: all T) of ``active`` slots extend the
    *existing* caches — dense ring or paged — and each token attends the
    already-resident prefix plus its in-chunk causal predecessors.

    * ``prefill``  == forward_chunk from an empty cache (T = prompt len);
    * ``decode_step`` == forward_chunk with T = 1;
    * chunked admission prefill == a sequence of forward_chunk slices
      (``serve.scheduler``), each landing straight in the shared caches.

    ``logits_at``: ``None`` returns logits for every chunk position
    (B, T, V); a per-slot (B,) chunk-relative index returns only that
    position's logits (B, V) — what admission prefill reads (the last
    real prompt token) without unembedding the whole chunk.

    Returns (logits, new_caches).
    """
    x = embed(params["embed"], tokens, cfg)
    x, new_caches = _forward_chunk_x(
        params, x, caches, pos, cfg, active, lengths
    )
    head = params.get("lm_head", params["embed"])
    if logits_at is not None:
        idx = jnp.asarray(logits_at, jnp.int32)[:, None, None]
        xl = jnp.take_along_axis(
            x, jnp.broadcast_to(idx, (x.shape[0], 1, x.shape[2])), axis=1
        )
        xl = rmsnorm(params["final_norm"], xl)
        return unembed(head, xl, cfg)[:, 0], new_caches
    x = rmsnorm(params["final_norm"], x)
    return unembed(head, x, cfg), new_caches


def prefill(params, batch: dict, cfg: ModelConfig, cache_len: int,
            last_pos: Optional[Array] = None):
    """Run the full prompt as ONE :func:`forward_chunk` from an empty
    dense-layout cache: last-position logits plus filled caches of length
    ``cache_len`` (>= prompt length).

    ``last_pos`` (traced scalar) reads the logits at position
    ``last_pos - 1`` instead of the final row — the hook for bucketed
    admission prefill (serve/scheduler): the prompt is right-padded to a
    shared bucket length so one trace serves many prompt lengths, and
    causal masking keeps every position < last_pos bit-identical to an
    exact-length prefill (pad positions only write cache slots that decode
    masks until it overwrites them).

    Returns (logits_last (B,V), caches).  Cache structure matches
    :func:`init_cache` / :func:`decode_step`.
    """
    x = _embed_inputs(params, batch, cfg)
    x = shard_hint(x, "batch", "seq", "act_embed")
    caches, _ = init_cache(cfg, x.shape[0], cache_len, dtype=x.dtype)
    x, caches = _forward_chunk_x(
        params, x, caches, jnp.asarray(0, jnp.int32), cfg,
        read_to=x.shape[1],
    )
    if last_pos is None:
        xl = x[:, -1:]
    else:
        xl = jax.lax.dynamic_slice_in_dim(
            x, jnp.asarray(last_pos, jnp.int32) - 1, 1, axis=1
        )
    x = rmsnorm(params["final_norm"], xl)
    head = params.get("lm_head", params["embed"])
    logits = unembed(head, x, cfg)
    return logits[:, 0], caches
