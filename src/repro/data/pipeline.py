"""Data pipeline: deterministic host-sharded token streams with background
prefetch.

Production posture: every host computes its own disjoint shard of the
global batch from (step, host_index) alone — no data server, no
coordination — so a restarted or replaced host resumes mid-run
deterministically (straggler/fault story, DESIGN.md §4).

Sources:
  * SyntheticSource — seeded Zipf-ish token stream (benchmarks, tests)
  * TextFileSource  — tokenized text file(s), memory-mapped token buffer
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.data.tokenizer import ByteTokenizer


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    host_index: int = 0
    host_count: int = 1
    seed: int = 0
    prefetch: int = 2

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count


class SyntheticSource:
    """Deterministic pseudo-text: Zipf-distributed tokens with local
    structure (bigram coupling) so models have something learnable."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        # fixed bigram transition "grammar"
        self.trans = rng.integers(0, vocab_size, size=(vocab_size, 4))

    def tokens_for(self, step: int, row: int, length: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng((seed * 1_000_003 + step) * 65_537 + row)
        out = np.empty(length + 1, np.int32)
        out[0] = rng.integers(0, self.vocab)
        zipf_jump = rng.random(length) < 0.3
        choices = rng.integers(0, 4, size=length)
        jumps = (rng.zipf(1.5, size=length) - 1) % self.vocab
        for i in range(length):
            out[i + 1] = (
                jumps[i] if zipf_jump[i] else self.trans[out[i], choices[i]]
            )
        return out


class TextFileSource:
    """Pre-tokenizes file(s) once into a flat int32 buffer."""

    def __init__(self, paths: list[str], tokenizer=None):
        tok = tokenizer or ByteTokenizer()
        bufs = []
        for p in paths:
            with open(p, "r", errors="replace") as f:
                bufs.append(np.asarray(tok.encode(f.read()), np.int32))
        self.buf = np.concatenate(bufs)
        self.vocab = tok.vocab_size

    def tokens_for(self, step: int, row: int, length: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng((seed * 1_000_003 + step) * 65_537 + row)
        start = rng.integers(0, max(1, len(self.buf) - length - 1))
        return self.buf[start : start + length + 1]


def host_batch(source, cfg: DataConfig, step: int) -> dict:
    """Build this host's slice of global batch ``step``: next-token pairs."""
    rows = []
    base = cfg.host_index * cfg.host_batch
    for r in range(cfg.host_batch):
        rows.append(source.tokens_for(step, base + r, cfg.seq_len, cfg.seed))
    arr = np.stack(rows)  # (B, S+1)
    return {"tokens": arr[:, :-1].astype(np.int32),
            "labels": arr[:, 1:].astype(np.int32)}


class PrefetchIterator:
    """Background-thread prefetch of host batches."""

    def __init__(self, source, cfg: DataConfig, start_step: int = 0):
        self.source, self.cfg = source, cfg
        self.step = start_step
        self.q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            batch = host_batch(self.source, self.cfg, s)
            while not self._stop.is_set():
                try:
                    self.q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
