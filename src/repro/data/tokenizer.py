"""Tokenization substrate: a byte-level tokenizer (always available) and a
small trainable BPE (paper: "BPE tokenizer with a vocabulary size of 32K").

The BPE here is a faithful, self-contained implementation — greedy pair
merges learned from a corpus sample — adequate for the CPU-scale training
runs in examples/ and benchmarks/.  Vocabulary layout:
  [0] pad  [1] bos  [2] eos  [3..258] bytes  [259..] merges
"""

from __future__ import annotations

import collections
import json
import os
from typing import Iterable, Sequence

import numpy as np

PAD, BOS, EOS = 0, 1, 2
BYTE_OFFSET = 3


class ByteTokenizer:
    """Raw bytes + specials; vocab 259."""

    vocab_size = BYTE_OFFSET + 256

    def encode(self, text: str, add_bos: bool = True) -> list[int]:
        ids = [b + BYTE_OFFSET for b in text.encode("utf-8")]
        return ([BOS] if add_bos else []) + ids

    def decode(self, ids: Sequence[int]) -> str:
        bs = bytes(i - BYTE_OFFSET for i in ids if i >= BYTE_OFFSET)
        return bs.decode("utf-8", errors="replace")


class BPETokenizer:
    """Byte-level BPE with learned merges."""

    def __init__(self, merges: list[tuple[int, int]] | None = None):
        self.merges: list[tuple[int, int]] = merges or []
        self._ranks = {tuple(m): i for i, m in enumerate(self.merges)}

    @property
    def vocab_size(self) -> int:
        return BYTE_OFFSET + 256 + len(self.merges)

    # -- training ----------------------------------------------------------
    @classmethod
    def train(cls, corpus: Iterable[str], vocab_size: int, max_bytes: int = 1 << 22):
        """Greedy BPE merge learning over a corpus sample."""
        data: list[int] = []
        for text in corpus:
            data.extend(b + BYTE_OFFSET for b in text.encode("utf-8"))
            if len(data) >= max_bytes:
                break
        seq = np.asarray(data, np.int32)
        merges: list[tuple[int, int]] = []
        next_id = BYTE_OFFSET + 256
        while next_id < vocab_size and len(seq) > 1:
            pairs = collections.Counter(zip(seq[:-1].tolist(), seq[1:].tolist()))
            if not pairs:
                break
            (a, b), cnt = pairs.most_common(1)[0]
            if cnt < 2:
                break
            merges.append((a, b))
            # apply merge
            out = []
            i = 0
            n = len(seq)
            sl = seq.tolist()
            while i < n:
                if i < n - 1 and sl[i] == a and sl[i + 1] == b:
                    out.append(next_id)
                    i += 2
                else:
                    out.append(sl[i])
                    i += 1
            seq = np.asarray(out, np.int32)
            next_id += 1
        return cls(merges)

    # -- encode/decode -----------------------------------------------------
    def encode(self, text: str, add_bos: bool = True) -> list[int]:
        ids = [b + BYTE_OFFSET for b in text.encode("utf-8")]
        if self._ranks:
            while len(ids) > 1:
                best_rank, best_i = None, None
                for i in range(len(ids) - 1):
                    r = self._ranks.get((ids[i], ids[i + 1]))
                    if r is not None and (best_rank is None or r < best_rank):
                        best_rank, best_i = r, i
                if best_i is None:
                    break
                ids[best_i : best_i + 2] = [BYTE_OFFSET + 256 + best_rank]
        return ([BOS] if add_bos else []) + ids

    def _expand(self, tok: int, out: list[int]):
        if tok < BYTE_OFFSET + 256:
            out.append(tok)
            return
        a, b = self.merges[tok - BYTE_OFFSET - 256]
        self._expand(a, out)
        self._expand(b, out)

    def decode(self, ids: Sequence[int]) -> str:
        flat: list[int] = []
        for t in ids:
            if t >= BYTE_OFFSET:
                self._expand(int(t), flat)
        return bytes(i - BYTE_OFFSET for i in flat).decode("utf-8", errors="replace")

    # -- persistence -------------------------------------------------------
    def save(self, path: str):
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"merges": self.merges}, f)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str):
        with open(path) as f:
            d = json.load(f)
        return cls([tuple(m) for m in d["merges"]])
