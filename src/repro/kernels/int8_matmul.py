"""W8A8 matmul Pallas kernel — the decoupled layer's high-precision branch.

Straight int8 x int8 -> int32 MXU matmul with the per-token activation
scale (gamma) and per-tensor weight scale folded into the epilogue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BM, DEFAULT_BK, DEFAULT_BN = 128, 256, 256


def _int8_kernel(x_ref, w_ref, gamma_ref, wscale_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        inv = 1.0 / (gamma_ref[...] * wscale_ref[0])  # (bm,)
        y = acc_ref[...].astype(jnp.float32) * inv[:, None]
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bn", "out_dtype", "interpret")
)
def int8_matmul(
    x_i8: Array,
    w_i8: Array,
    gamma: Array,
    wscale: Array,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> Array:
    m, k = x_i8.shape
    k2, n = w_i8.shape
    assert k == k2
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm_ == 0 and k % bk_ == 0 and n % bn_ == 0

    return pl.pallas_call(
        _int8_kernel,
        grid=(m // bm_, n // bn_, k // bk_),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm_,), lambda i, j, kk: (i,)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        interpret=interpret,
    )(
        x_i8,
        w_i8,
        gamma.astype(jnp.float32),
        wscale.reshape(1).astype(jnp.float32),
    )
