"""Fused decoupled-FFN first GEMM — both branches in one activation pass.

Paper §A (third optimization): "the same input must be multiplied with both
the 8-bit and 1-bit branches of the up projection ... distributed across
multiple thread groups, enabling parallel execution without redundant data
reads."  TPU adaptation: one Pallas kernel whose grid walks the 1-bit
branch's N tiles; the (much narrower, r << d_ff) 8-bit branch weight tile
rides along pinned in VMEM, and both accumulators advance per K step — the
INT8 activation tile is read from HBM exactly once for the two GEMMs.

Outputs are pre-scaled by the feature-scaling factors beta (1-bit) and
alpha (8-bit), folding paper Eq. 11 into the epilogue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.w1a8_matmul import _unpack_tile

Array = jax.Array

DEFAULT_BM, DEFAULT_BK, DEFAULT_BN = 128, 256, 256


def _decoupled_kernel(
    x_ref, wp_ref, w8_ref, gamma_ref, lam_ref, w8s_ref, ab_ref,
    o1_ref, o8_ref, acc1_ref, acc8_ref
):
    j = pl.program_id(1)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc8_ref[...] = jnp.zeros_like(acc8_ref)

    x = x_ref[...]
    w1 = _unpack_tile(wp_ref[...])
    acc1_ref[...] += jax.lax.dot_general(
        x, w1, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    # 8-bit branch: only the j == 0 pass accumulates (r fits in one N tile;
    # other j tiles would redundantly recompute it)
    @pl.when(j == 0)
    def _acc8():
        acc8_ref[...] += jax.lax.dot_general(
            x, w8_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        lam = lam_ref[0]
        alpha, beta = ab_ref[0], ab_ref[1]
        inv_gamma = 1.0 / gamma_ref[...]
        y1 = acc1_ref[...].astype(jnp.float32) * (beta * lam * inv_gamma)[:, None]
        o1_ref[...] = y1.astype(o1_ref.dtype)

        @pl.when(j == 0)
        def _write8():
            inv8 = alpha / (gamma_ref[...] * w8s_ref[0])
            y8 = acc8_ref[...].astype(jnp.float32) * inv8[:, None]
            o8_ref[...] = y8.astype(o8_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bn", "out_dtype", "interpret")
)
def decoupled_matmul(
    x_i8: Array,
    w1_packed: Array,
    w8_i8: Array,
    gamma: Array,
    lam: Array,
    w8scale: Array,
    alpha: Array,
    beta: Array,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    out_dtype=jnp.float32,
    interpret: bool = False,
):
    """Returns (y1 (M, N), y8 (M, R)): both branch outputs, scale-folded.

    R (the 8-bit width) must fit a single N tile (r <= bn) — true for the
    paper's r in [128, 768] with bn = 256+ (pad in ops.py otherwise).
    """
    m, k = x_i8.shape
    kb, n = w1_packed.shape
    _, r = w8_i8.shape
    assert kb * 8 == k
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm_ == 0 and k % bk_ == 0 and n % bn_ == 0
    assert r <= bn_, f"8-bit width {r} must fit one tile (bn={bn_})"

    ab = jnp.stack([alpha.astype(jnp.float32), beta.astype(jnp.float32)]).reshape(2)
    nk = k // bk_
    # w8 is only consumed on j == 0 passes; pin its block index at the last
    # K tile for j > 0 so the pipeline re-streams it per i, not per (i, j).
    w8_index = lambda i, j, kk: (jnp.where(j == 0, kk, nk - 1), 0)
    return pl.pallas_call(
        _decoupled_kernel,
        grid=(m // bm_, n // bn_, nk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_ // 8, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk_, r), w8_index),
            pl.BlockSpec((bm_,), lambda i, j, kk: (i,)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
            pl.BlockSpec((2,), lambda i, j, kk: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm_, r), lambda i, j, kk: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), out_dtype),
            jax.ShapeDtypeStruct((m, r), out_dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm_, bn_), jnp.int32),
            pltpu.VMEM((bm_, r), jnp.int32),
        ],
        interpret=interpret,
    )(
        x_i8,
        w1_packed,
        w8_i8,
        gamma.astype(jnp.float32),
        lam.reshape(1).astype(jnp.float32),
        w8scale.reshape(1).astype(jnp.float32),
        ab,
    )
