"""Decode-shaped W1A8 GEMV Pallas kernels with fused activation quantization.

Autoregressive decode multiplies a handful of token rows (M <= ~32) against
the full packed weight matrix — the op is bandwidth-bound on the 1-bit
weight stream, so the prefill-shaped ``w1a8_matmul`` tiling (M padded to
128-row tiles, a separate XLA activation-quantize pass that round-trips the
activations through HBM) leaves throughput on the table.  This tier is
specialized for that regime:

* **Fused act-quant prologue.**  The float activations (all M rows x full K)
  fit in VMEM at decode shapes, so the kernel's first grid step computes the
  per-token AbsMax INT8 quantization in-kernel (gamma + int8 rows land in
  VMEM scratch) and every later step reads the quantized rows from scratch.
  No ``quantize_act_int8`` XLA pass, no extra HBM round-trip.
* **No 128-row padding.**  M is a single block (padded only to the 8-row
  f32 sublane minimum in ops.py), not a grid dimension.
* **(N, K)-major grid with wide bn tiles.**  The grid walks output tiles
  j over N with K innermost, streaming wide packed-weight tiles HBM->VMEM —
  the weight stream, the bandwidth term that matters, is maximized while
  the tiny activation block stays resident.

``decoupled_gemv`` is the dual-branch variant (paper §A third point): the
8-bit branch tile rides along and both accumulators advance per K step, so
the quantized activations are read once for the two GEMVs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.w1a8_matmul import _unpack_tile

Array = jax.Array

# Wider-than-prefill defaults: weight streaming dominates, so bn leans wide;
# bk stays a multiple of 8 (packing) and of 128 (MXU lane) where shapes allow.
DEFAULT_BK, DEFAULT_BN = 512, 512


def _quant_prologue(x_ref, xq_ref, gamma_ref):
    """Per-token AbsMax INT8 quantize of the full (bm, K) activation block
    into VMEM scratch.  gamma = 127 / (amax + 1e-5) is never zero, so pad
    rows (all-zero activations) stay finite through the epilogue.

    This is the in-kernel mirror of ``core.quantization.act_scale_int8``
    (f32 amax, 127 / (amax + 1e-5)) — the single act-quant formula shared
    with the fake-quant path; keep them in lockstep or packed-vs-fake-quant
    parity drifts."""
    xf = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    gamma = 127.0 / (amax + 1e-5)
    xq_ref[...] = jnp.clip(jnp.round(xf * gamma[:, None]), -127, 127).astype(
        jnp.int8
    )
    gamma_ref[...] = gamma


def _w1a8_gemv_kernel(
    x_ref, wp_ref, lam_ref, o_ref, xq_ref, gamma_ref, acc_ref, *, bk: int
):
    """One (j, kk) grid step: j walks N tiles, kk walks K tiles (innermost)."""
    j, kk = pl.program_id(0), pl.program_id(1)

    @pl.when((j == 0) & (kk == 0))
    def _prologue():
        _quant_prologue(x_ref, xq_ref, gamma_ref)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x_tile = xq_ref[:, pl.dslice(kk * bk, bk)]
    w_tile = _unpack_tile(wp_ref[...])
    acc_ref[...] += jax.lax.dot_general(
        x_tile,
        w_tile,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(kk == pl.num_programs(1) - 1)
    def _epilogue():
        lam = lam_ref[0]
        y = acc_ref[...].astype(jnp.float32) * (lam / gamma_ref[...])[:, None]
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bk", "bn", "out_dtype", "interpret")
)
def w1a8_gemv(
    x: Array,
    w_packed: Array,
    lam: Array,
    *,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> Array:
    """Y (M, N) = dequant(quantize(X) @ unpack(W_packed)), act-quant fused.

    x: (M, K) float activations, M small (decode rows; pad to 8 in ops.py);
    w_packed: (K//8, N) uint8 sign bits; lam: scalar AbsMean weight scale.
    K % bk == 0 and N % bn == 0 (pick tiles via ops.decode_tiles).
    """
    m, k = x.shape
    kb, n = w_packed.shape
    assert kb * 8 == k, f"packed K mismatch: {kb}*8 != {k}"
    bk_, bn_ = min(bk, k), min(bn, n)
    assert bk_ % 8 == 0, f"bk={bk_} must be a multiple of 8 (packing)"
    assert k % bk_ == 0 and n % bn_ == 0, (k, n, bk_, bn_)

    return pl.pallas_call(
        functools.partial(_w1a8_gemv_kernel, bk=bk_),
        grid=(n // bn_, k // bk_),
        in_specs=[
            pl.BlockSpec((m, k), lambda j, kk: (0, 0)),  # resident in VMEM
            pl.BlockSpec((bk_ // 8, bn_), lambda j, kk: (kk, j)),
            pl.BlockSpec((1,), lambda j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((m, bn_), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((m, k), jnp.int8),  # quantized rows
            pltpu.VMEM((m,), jnp.float32),  # gamma
            pltpu.VMEM((m, bn_), jnp.int32),  # accumulator
        ],
        interpret=interpret,
    )(x, w_packed, lam.reshape(1).astype(jnp.float32))


def _decoupled_gemv_kernel(
    x_ref, wp_ref, w8_ref, lam_ref, w8s_ref, ab_ref,
    o1_ref, o8_ref, xq_ref, gamma_ref, acc1_ref, acc8_ref, *, bk: int
):
    j, kk = pl.program_id(0), pl.program_id(1)

    @pl.when((j == 0) & (kk == 0))
    def _prologue():
        _quant_prologue(x_ref, xq_ref, gamma_ref)
        acc8_ref[...] = jnp.zeros_like(acc8_ref)

    @pl.when(kk == 0)
    def _init():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)

    x_tile = xq_ref[:, pl.dslice(kk * bk, bk)]
    w1 = _unpack_tile(wp_ref[...])
    acc1_ref[...] += jax.lax.dot_general(
        x_tile, w1, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )

    # 8-bit branch: only the j == 0 pass accumulates (r fits one N tile)
    @pl.when(j == 0)
    def _acc8():
        acc8_ref[...] += jax.lax.dot_general(
            x_tile, w8_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )

    @pl.when(kk == pl.num_programs(1) - 1)
    def _epilogue():
        lam = lam_ref[0]
        alpha, beta = ab_ref[0], ab_ref[1]
        y1 = acc1_ref[...].astype(jnp.float32) * (
            beta * lam / gamma_ref[...]
        )[:, None]
        o1_ref[...] = y1.astype(o1_ref.dtype)

        @pl.when(j == 0)
        def _write8():
            inv8 = alpha / (gamma_ref[...] * w8s_ref[0])
            y8 = acc8_ref[...].astype(jnp.float32) * inv8[:, None]
            o8_ref[...] = y8.astype(o8_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bk", "bn", "out_dtype", "interpret")
)
def decoupled_gemv(
    x: Array,
    w1_packed: Array,
    w8_i8: Array,
    lam: Array,
    w8scale: Array,
    alpha: Array,
    beta: Array,
    *,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    out_dtype=jnp.float32,
    interpret: bool = False,
):
    """Dual-branch decode GEMV: (y1 (M, N), y8 (M, R)), act-quant fused.

    Same semantics as ``decoupled_matmul`` (outputs pre-scaled by beta /
    alpha) with the activation quantization done in the kernel prologue.
    R must fit one N tile (r <= bn).
    """
    m, k = x.shape
    kb, n = w1_packed.shape
    _, r = w8_i8.shape
    assert kb * 8 == k, f"packed K mismatch: {kb}*8 != {k}"
    bk_, bn_ = min(bk, k), min(bn, n)
    assert bk_ % 8 == 0 and k % bk_ == 0 and n % bn_ == 0, (k, n, bk_, bn_)
    assert r <= bn_, f"8-bit width {r} must fit one tile (bn={bn_})"

    ab = jnp.stack(
        [alpha.astype(jnp.float32), beta.astype(jnp.float32)]
    ).reshape(2)
    nk = k // bk_
    # w8 is only consumed on the j == 0 pass; pinning its block index at the
    # last K tile for j > 0 means the mapped block never changes after that
    # pass, so the pipeline's revisiting logic streams w8 exactly once
    # instead of n/bn times.
    w8_index = lambda j, kk: (jnp.where(j == 0, kk, nk - 1), 0)
    return pl.pallas_call(
        functools.partial(_decoupled_gemv_kernel, bk=bk_),
        grid=(n // bn_, nk),
        in_specs=[
            pl.BlockSpec((m, k), lambda j, kk: (0, 0)),
            pl.BlockSpec((bk_ // 8, bn_), lambda j, kk: (kk, j)),
            pl.BlockSpec((bk_, r), w8_index),
            pl.BlockSpec((1,), lambda j, kk: (0,)),
            pl.BlockSpec((1,), lambda j, kk: (0,)),
            pl.BlockSpec((2,), lambda j, kk: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((m, bn_), lambda j, kk: (0, j)),
            pl.BlockSpec((m, r), lambda j, kk: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), out_dtype),
            jax.ShapeDtypeStruct((m, r), out_dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((m, k), jnp.int8),
            pltpu.VMEM((m,), jnp.float32),
            pltpu.VMEM((m, bn_), jnp.int32),
            pltpu.VMEM((m, r), jnp.int32),
        ],
        interpret=interpret,
    )(
        x,
        w1_packed,
        w8_i8,
        lam.reshape(1).astype(jnp.float32),
        w8scale.reshape(1).astype(jnp.float32),
        ab,
    )
