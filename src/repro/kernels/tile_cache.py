"""On-disk persistence for the kernel-tier autotune dispatch tables.

``ops.sweep_decode_tiles`` times candidate (bk, bn) tiles per
(op, m, k, n[, r]) GEMV signature, and ``ops.sweep_paged_tiles`` times
pages-per-step per ``(paged_attn, T, Hq, Hkv, head_dim, block_size,
max_blocks)`` paged-attention signature — but only in-process, so every
server restart re-pays the sweep.  This module mirrors those tables to a
per-backend JSON file:

    $REPRO_TILE_CACHE_DIR/decode_tiles_{backend}.json
    (default: ~/.cache/repro/)

``ops`` loads the file lazily on the first tile lookup and appends every
newly swept winner, so autotuning survives process restarts.  Tile
winners are backend-specific (a TPU sweep means nothing on CPU interpret
mode), hence the per-backend file.  Set ``REPRO_TILE_CACHE=0`` to disable
both load and store (hermetic CI runs).

File format: ``{"op|int|int|...": [int, ...], ...}`` — flat, mergeable,
and stable under concurrent writers (atomic replace; last writer wins on
a per-key basis after merging with the on-disk content).  Values are
kernel-family-shaped: ``[bk, bn]`` for the GEMV ops, ``[pages]`` for
paged attention — both keys and values are variable-arity int tuples, so
new kernel families extend the same file without a format bump.
"""

from __future__ import annotations

import json
import logging
import os
import pathlib
import tempfile

_KEY_SEP = "|"

_log = logging.getLogger(__name__)

# process-wide autotune-cache stats, answering "did this run pay sweep
# cost or reuse the cache": dispatch-table lookups (hits/misses), sweeps
# actually run, and milliseconds spent sweeping.  They live here (with the
# cache) rather than on an engine; the serving metrics registry pulls them
# in at snapshot time via a collector (``scheduler._tile_cache_stats``).
_STATS = {"hits": 0, "misses": 0, "sweeps": 0, "sweep_ms": 0.0}


def record_hit() -> None:
    _STATS["hits"] += 1


def record_miss() -> None:
    _STATS["misses"] += 1


def record_sweep_ms(ms: float) -> None:
    _STATS["sweeps"] += 1
    _STATS["sweep_ms"] += float(ms)


def stats() -> dict:
    """Copy of the process-wide autotune-cache stats."""
    return dict(_STATS)


def reset_stats() -> None:
    for k in _STATS:
        _STATS[k] = type(_STATS[k])()

# cache paths whose corruption has already been reported — warn once per
# path per process, not once per load
_CORRUPT_WARNED: set[str] = set()


def _quarantine_corrupt(path: pathlib.Path, err: Exception) -> None:
    """A cache file that does not parse is renamed to ``*.corrupt`` (so
    the next sweep starts a fresh file instead of silently re-hitting the
    same corruption forever) and reported once.  Best-effort: quarantine
    must never break inference either."""
    try:
        path.replace(path.with_suffix(path.suffix + ".corrupt"))
    except OSError:
        pass
    key = str(path)
    if key not in _CORRUPT_WARNED:
        _CORRUPT_WARNED.add(key)
        _log.warning(
            "tile cache %s is corrupt (%s); quarantined to %s.corrupt and "
            "starting fresh", path, err, path,
        )


def enabled() -> bool:
    return os.environ.get("REPRO_TILE_CACHE", "1") != "0"


def cache_path(backend: str) -> pathlib.Path:
    root = os.environ.get("REPRO_TILE_CACHE_DIR")
    base = pathlib.Path(root) if root else pathlib.Path.home() / ".cache" / "repro"
    return base / f"decode_tiles_{backend}.json"


def _encode_key(key: tuple) -> str:
    return _KEY_SEP.join(str(p) for p in key)


def _decode_key(s: str) -> tuple:
    parts = s.split(_KEY_SEP)
    return (parts[0],) + tuple(int(p) for p in parts[1:])


def _valid_entry(key: tuple, val: tuple) -> bool:
    """Family-shaped EXACT arity check: paged_attn winners are (pages,),
    the GEMV families are (bk, bn).  A wrong-arity value — short or long —
    must be dropped at load time: dispatch tuple-unpacks these, and a
    broken cache file must never break inference."""
    return len(val) == (1 if key[0] == "paged_attn" else 2)


def load(backend: str) -> dict[tuple, tuple[int, ...]]:
    """Persisted winners for ``backend`` ({} on any miss/corruption,
    per-entry validation drops malformed keys/values —
    a broken cache file must never break inference).  A file that fails
    to parse at all is quarantined to ``*.corrupt`` (with one warning per
    path) so the corruption is visible and the next store starts clean."""
    if not enabled():
        return {}
    path = cache_path(backend)
    try:
        text = path.read_text()
    except OSError:
        return {}
    try:
        raw = json.loads(text)
        if not isinstance(raw, dict):
            raise ValueError(f"expected a JSON object, got {type(raw).__name__}")
    except ValueError as e:  # json.JSONDecodeError is a ValueError
        _quarantine_corrupt(path, e)
        return {}
    out = {}
    for k, v in raw.items():
        try:
            key = _decode_key(k)
            val = tuple(int(x) for x in v)
        except (ValueError, TypeError, IndexError):
            continue  # one bad entry must not poison the rest
        if key and _valid_entry(key, val):
            out[key] = val
    return out


def store(backend: str, table: dict[tuple, tuple[int, ...]]) -> None:
    """Merge ``table`` into the on-disk cache (best-effort: serving never
    fails because a cache dir is read-only).  Atomic replace so concurrent
    sweeps can't interleave partial JSON."""
    if not enabled() or not table:
        return
    path = cache_path(backend)
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        merged = load(backend)
        merged.update(table)
        payload = json.dumps(
            {_encode_key(k): list(v) for k, v in sorted(merged.items())},
            indent=0,
        )
        # crash/concurrency safety: write a temp file IN THE SAME
        # DIRECTORY, fsync it, then atomically os.replace it into place —
        # a reader (or a crash at any point) sees either the old complete
        # file or the new complete file, never a partial write.
        fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
        ok = False
        try:
            with os.fdopen(fd, "w") as f:
                f.write(payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            ok = True
        finally:
            # remove the temp file on any failure without catching the
            # in-flight exception: KeyboardInterrupt/SystemExit (and real
            # write errors) propagate, and a failed unlink can never mask
            # the original error
            if not ok:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
    except OSError:
        pass
