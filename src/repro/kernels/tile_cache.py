"""On-disk persistence for the decode-tier tile dispatch table.

``ops.sweep_decode_tiles`` times candidate (bk, bn) tiles and caches the
winner per (op, m, k, n[, r]) signature — but only in-process, so every
server restart re-pays the sweep.  This module mirrors that table to a
per-backend JSON file:

    $REPRO_TILE_CACHE_DIR/decode_tiles_{backend}.json
    (default: ~/.cache/repro/)

``ops`` loads the file lazily on the first decode-tile lookup and appends
every newly swept winner, so autotuning survives process restarts.  Tile
winners are backend-specific (a TPU sweep means nothing on CPU interpret
mode), hence the per-backend file.  Set ``REPRO_TILE_CACHE=0`` to disable
both load and store (hermetic CI runs).

File format: ``{"op|m|k|n[|r]": [bk, bn], ...}`` — flat, mergeable, and
stable under concurrent writers (atomic replace; last writer wins on a
per-key basis after merging with the on-disk content).
"""

from __future__ import annotations

import json
import os
import pathlib
import tempfile

_KEY_SEP = "|"


def enabled() -> bool:
    return os.environ.get("REPRO_TILE_CACHE", "1") != "0"


def cache_path(backend: str) -> pathlib.Path:
    root = os.environ.get("REPRO_TILE_CACHE_DIR")
    base = pathlib.Path(root) if root else pathlib.Path.home() / ".cache" / "repro"
    return base / f"decode_tiles_{backend}.json"


def _encode_key(key: tuple) -> str:
    return _KEY_SEP.join(str(p) for p in key)


def _decode_key(s: str) -> tuple:
    parts = s.split(_KEY_SEP)
    return (parts[0],) + tuple(int(p) for p in parts[1:])


def load(backend: str) -> dict[tuple, tuple[int, int]]:
    """Persisted winners for ``backend`` ({} on any miss/corruption —
    a broken cache file must never break inference)."""
    if not enabled():
        return {}
    try:
        raw = json.loads(cache_path(backend).read_text())
        return {
            _decode_key(k): (int(v[0]), int(v[1])) for k, v in raw.items()
        }
    except (OSError, ValueError, KeyError, IndexError, TypeError):
        return {}


def store(backend: str, table: dict[tuple, tuple[int, int]]) -> None:
    """Merge ``table`` into the on-disk cache (best-effort: serving never
    fails because a cache dir is read-only).  Atomic replace so concurrent
    sweeps can't interleave partial JSON."""
    if not enabled() or not table:
        return
    path = cache_path(backend)
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        merged = load(backend)
        merged.update(table)
        payload = json.dumps(
            {_encode_key(k): list(v) for k, v in sorted(merged.items())},
            indent=0,
        )
        # crash/concurrency safety: write a temp file IN THE SAME
        # DIRECTORY, fsync it, then atomically os.replace it into place —
        # a reader (or a crash at any point) sees either the old complete
        # file or the new complete file, never a partial write.
        fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            os.unlink(tmp)
            raise
    except OSError:
        pass
