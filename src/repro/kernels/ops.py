"""Jit'd public wrappers around the Pallas kernels.

Handles: CPU fallback (interpret=True so the kernel *body* is executed and
validated on CPU), ragged-shape padding to tile multiples, and the
quantize -> kernel -> output plumbing used by the serving path
(``repro.train.serve`` W1A8 inference).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decoupled_matmul import decoupled_matmul
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.rmsnorm_quant import rmsnorm_quant
from repro.kernels.w1a8_matmul import w1a8_matmul

Array = jax.Array


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(x: Array, mult: int):
    m = x.shape[0]
    pad = (-m) % mult
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x, m


def quantize_act_int8(x: Array):
    """Per-token AbsMax INT8 (runtime, true-integer path)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    gamma = 127.0 / (amax + 1e-5)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) * gamma[:, None]), -127, 127)
    return q.astype(jnp.int8), gamma


def bit_linear_infer(
    x: Array, w_packed: Array, lam: Array, out_dtype=jnp.bfloat16
) -> Array:
    """Full W1A8 inference linear: quantize acts -> packed 1-bit matmul.

    x: (..., K) float; w_packed: (K//8, N) uint8; lam: scalar.
    """
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    xq, gamma = quantize_act_int8(xf)
    bm = 8 if xq.shape[0] <= 128 else 128
    xq, m = _pad_rows(xq, bm)
    gamma_p, _ = _pad_rows(gamma + (gamma == 0), bm)  # avoid 1/0 on pad rows
    y = w1a8_matmul(
        xq, w_packed, gamma_p, lam,
        bm=bm, out_dtype=out_dtype, interpret=not on_tpu(),
    )
    return y[:m].reshape(*lead, -1)


def int8_linear_infer(
    x: Array, w_q: Array, wscale: Array, out_dtype=jnp.bfloat16
) -> Array:
    """Full W8A8 inference linear (8-bit branch)."""
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    xq, gamma = quantize_act_int8(xf)
    bm = 8 if xq.shape[0] <= 128 else 128
    xq, m = _pad_rows(xq, bm)
    gamma_p, _ = _pad_rows(gamma + (gamma == 0), bm)
    y = int8_matmul(
        xq, w_q, gamma_p, wscale, bm=bm, out_dtype=out_dtype,
        interpret=not on_tpu(),
    )
    return y[:m].reshape(*lead, -1)


def fused_rmsnorm_quant(x: Array, scale: Array):
    """(..., D) -> (int8 (..., D), gamma (...,))."""
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    bm = 8 if xf.shape[0] <= 256 else 256
    xp, m = _pad_rows(xf, bm)
    q, gamma = rmsnorm_quant(xp, scale, bm=bm, interpret=not on_tpu())
    return q[:m].reshape(*lead, -1), gamma[:m].reshape(lead)


def decoupled_first_gemm(
    x: Array,
    w1_packed: Array,
    w8_q: Array,
    lam: Array,
    w8scale: Array,
    alpha: Array,
    beta: Array,
    out_dtype=jnp.bfloat16,
):
    """Fused dual-branch up-projection for serving: reads activations once.

    Returns (y1 (..., N), y8 (..., R)), each pre-scaled by beta / alpha.
    """
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    xq, gamma = quantize_act_int8(xf)
    bm = 8 if xq.shape[0] <= 128 else 128
    xq, m = _pad_rows(xq, bm)
    gamma_p, _ = _pad_rows(gamma + (gamma == 0), bm)
    r = w8_q.shape[1]
    bn = max(256, r)
    y1, y8 = decoupled_matmul(
        xq, w1_packed, w8_q, gamma_p, lam, w8scale, alpha, beta,
        bm=bm, bn=bn, out_dtype=out_dtype, interpret=not on_tpu(),
    )
    return y1[:m].reshape(*lead, -1), y8[:m].reshape(*lead, -1)
