"""Jit'd public wrappers around the Pallas kernels.

Handles: CPU fallback (interpret=True so the kernel *body* is executed and
validated on CPU), ragged-shape padding to tile multiples, the
quantize -> kernel -> output plumbing used by the serving path
(``repro.train.serve`` W1A8 inference), and shape-keyed dispatch between
the prefill-tiled kernels and the decode GEMV tier:

* M <= DECODE_M_MAX (decode/GEMV regime): route to ``w1a8_gemv`` /
  ``decoupled_gemv`` — activation quantization fused into the kernel
  prologue, M padded only to the 8-row sublane minimum, wide-bn (N, K)
  grid for maximum packed-weight streaming.
* M > DECODE_M_MAX (prefill/train regime): the existing M-tiled kernels
  behind a separate ``quantize_act_int8`` pass.

Tile sizes for the decode tier come from a per-(M, K, N) dispatch table:
``decode_tiles`` answers from divisor heuristics, and ``sweep_decode_tiles``
runs a timed sweep on the current backend and caches the winner under the
same signature so later calls (and jit retraces) pick it up.  Swept
winners are also mirrored to a per-backend JSON file
(``repro.kernels.tile_cache``) loaded on the first lookup, so autotuning
survives process restarts.

The paged-attention family (``paged_attention`` + ``paged_tiles`` /
``sweep_paged_tiles`` + the ``paged_attention_enabled`` /
``paged_attention_supported`` dispatch gates) lives at the bottom of this
module: ``models.attention._paged_scores`` routes the serving stack's
paged-KV branches here, keeping the ``kv_pool.read`` gather + SDPA path
as fallback and parity oracle.
"""

from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.quantization import quantize_act_int8  # noqa: F401  (re-export:
# the single act-quant source of truth lives in core.quantization)
from repro.distributed import sharding as _sharding
from repro.kernels import ref, tile_cache
from repro.kernels.decoupled_matmul import decoupled_matmul
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.paged_attention import paged_attention as _paged_attention
from repro.kernels.rmsnorm_quant import rmsnorm_quant
from repro.kernels.w1a8_gemv import decoupled_gemv, w1a8_gemv
from repro.kernels.w1a8_matmul import w1a8_matmul

Array = jax.Array

# Largest flattened row count routed to the decode GEMV tier.  Decode serves
# one token per request, so M = batch; 32 covers the batched-decode regime
# while anything larger amortizes like prefill.
DECODE_M_MAX = 32

# (op, m, k, n) -> (bk, bn): filled by sweep_decode_tiles (and, lazily, by
# the on-disk per-backend cache); consulted before the divisor heuristic so
# an autotuned signature sticks for the process.
_DECODE_TILE_CACHE: dict[tuple, tuple[int, int]] = {}
_TILE_CACHE_LOADED = False


def _ensure_tile_cache_loaded() -> None:
    """Merge persisted winners on first use (in-process entries win).
    Lazy so importing ops never forces jax backend initialisation."""
    global _TILE_CACHE_LOADED
    if _TILE_CACHE_LOADED:
        return
    _TILE_CACHE_LOADED = True
    for key, tiles in tile_cache.load(jax.default_backend()).items():
        _DECODE_TILE_CACHE.setdefault(key, tiles)

_BK_CANDIDATES = (1024, 512, 256, 128, 64, 32, 16, 8)
_BN_CANDIDATES = (1024, 512, 256, 128, 64, 32, 16, 8)


def _annotate(name: str):
    """Profiler span (``repro.serve.tracing.annotate``) around a kernel
    dispatch site — host-timeline TraceAnnotation + named_scope so kernel
    time is attributable by name in a profiler trace.  Imported lazily:
    the kernel tier stays importable without the serving layer, and the
    context manager runs at trace time, never per decode step."""
    from repro.serve.tracing import annotate

    return annotate(name)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(x: Array, mult: int):
    m = x.shape[0]
    pad = (-m) % mult
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x, m


def _pad_gamma(gamma: Array, mult: int) -> Array:
    """Pad per-token scales with ONES, not zeros: kernel epilogues divide by
    gamma, and a zero-padded row would compute 1/0 * 0 = NaN before the
    [:m] slice drops it."""
    pad = (-gamma.shape[0]) % mult
    if pad:
        gamma = jnp.pad(gamma, ((0, pad),), constant_values=1.0)
    return gamma


# ---------------------------------------------------------------------------
# Decode-tier tile dispatch / autotune
# ---------------------------------------------------------------------------


def _largest_divisor(total: int, candidates) -> int:
    for c in candidates:
        if c <= total and total % c == 0:
            return c
    return total


def _tile_key(op: str, m: int, k: int, n: int, r: int | None):
    # r is part of the decoupled signature: the same (m, k, n) with a
    # different 8-bit branch width is a different kernel launch.
    return (op, m, k, n) if r is None else (op, m, k, n, r)


def decode_tiles(m: int, k: int, n: int, op: str = "w1a8_gemv",
                 r: int | None = None):
    """(bk, bn) for a decode-shaped call: autotuned entry if one was swept
    (this process or a persisted earlier one), otherwise the widest
    candidate tiles that divide (K, N).  For the decoupled op, bn always
    fits the 8-bit branch (bn >= r)."""
    _ensure_tile_cache_loaded()
    cached = _DECODE_TILE_CACHE.get(_tile_key(op, m, k, n, r))
    if cached is not None:
        tile_cache.record_hit()
        return cached
    tile_cache.record_miss()
    bk = _largest_divisor(k, _BK_CANDIDATES)
    bn = _largest_divisor(n, _BN_CANDIDATES)
    if r is not None and bn < r:
        wide = [c for c in _BN_CANDIDATES if c >= r and n % c == 0]
        bn = min(wide) if wide else n
    return bk, bn


def sweep_decode_tiles(
    m: int,
    k: int,
    n: int,
    *,
    op: str = "w1a8_gemv",
    r: int | None = None,
    bk_candidates=None,
    bn_candidates=None,
    warmup: int = 1,
    iters: int = 3,
    seed: int = 0,
):
    """Time the decode kernel over candidate (bk, bn) tiles on the current
    backend, cache the winner per (m, k, n[, r]) signature, and return it.

    M is normalized to the 8-row padded shape the dispatcher actually
    launches, so a sweep for batch 4 is found by the batch-4 inference call.
    op selects the kernel: "w1a8_gemv" or "decoupled_gemv" (r = the 8-bit
    branch width to sweep with).  The sweep runs whatever backend is active
    (interpret on CPU, compiled on TPU) — call it once per decode signature
    at server start-up; subsequent calls with that signature use the cache.
    Winners are mirrored to the per-backend on-disk cache
    (``repro.kernels.tile_cache``), so later processes skip the sweep.
    """
    import numpy as np

    if op == "decoupled_gemv" and r is None:
        raise ValueError("decoupled_gemv sweeps need r (8-bit branch width)")
    sweep_t0 = time.perf_counter()
    m_p = m + (-m) % 8  # the shape _bit_linear_decode pads to and looks up
    key = _tile_key(op, m_p, k, n, r if op == "decoupled_gemv" else None)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m_p, k)).astype(np.float32))
    wp = jnp.asarray(rng.integers(0, 256, (k // 8, n)).astype(np.uint8))
    lam = jnp.asarray(np.float32(0.05))
    interp = not on_tpu()
    if op == "decoupled_gemv":
        w8 = jnp.asarray(rng.integers(-127, 128, (k, r)).astype(np.int8))
        scales = [jnp.asarray(np.float32(v)) for v in (2.0, 1.0, 1.0)]

        def call(bk, bn):
            return decoupled_gemv(
                x, wp, w8, lam, *scales, bk=bk, bn=bn, interpret=interp
            )[0]
    else:
        def call(bk, bn):
            return w1a8_gemv(x, wp, lam, bk=bk, bn=bn, interpret=interp)

    best, best_t = None, float("inf")
    bks = [c for c in (bk_candidates or _BK_CANDIDATES)
           if c % 8 == 0 and c <= k and k % c == 0]
    bns = [c for c in (bn_candidates or _BN_CANDIDATES)
           if c <= n and n % c == 0
           and (op != "decoupled_gemv" or c >= r)]
    for bk in bks:
        for bn in bns:
            try:
                for _ in range(warmup):
                    jax.block_until_ready(call(bk, bn))
                ts = []
                for _ in range(iters):
                    t0 = time.perf_counter()
                    jax.block_until_ready(call(bk, bn))
                    ts.append(time.perf_counter() - t0)
                t = min(ts)
            except Exception:  # noqa: BLE001 — an invalid tile combo just loses
                continue
            if t < best_t:
                best, best_t = (bk, bn), t
    if best is None:
        best = decode_tiles(m_p, k, n, op=op, r=r)
    _DECODE_TILE_CACHE[key] = best
    tile_cache.store(jax.default_backend(), {key: best})
    tile_cache.record_sweep_ms((time.perf_counter() - sweep_t0) * 1e3)
    return best


# ---------------------------------------------------------------------------
# Inference linears (shape-dispatched)
# ---------------------------------------------------------------------------


def _prefill_tiles(k: int, n: int, r: int | None = None):
    """(bk, bn): the widest candidate tiles that divide (K, N) — the
    prefill-tier kernels assert even tiling, and model-stack shapes (e.g.
    Mamba's d_inner = 384) aren't always multiples of the 256 defaults.
    Ragged dims fall back to the whole dim (a single tile).  With ``r``
    set, bn also fits the 8-bit branch (bn >= r)."""
    bk = _largest_divisor(k, _BK_CANDIDATES)
    bn = _largest_divisor(n, _BN_CANDIDATES)
    if r is not None and bn < r:
        wide = [c for c in _BN_CANDIDATES if c >= r and n % c == 0]
        bn = min(wide) if wide else n
    return bk, bn


def _bit_linear_prefill(xf: Array, w_packed: Array, lam: Array, out_dtype):
    """Prefill-tiled path: XLA act-quant pass + M-tiled w1a8_matmul."""
    xq, gamma = quantize_act_int8(xf)
    bm = 8 if xq.shape[0] <= 128 else 128
    xq, m = _pad_rows(xq, bm)
    gamma_p = _pad_gamma(gamma, bm)
    bk, bn = _prefill_tiles(xf.shape[1], w_packed.shape[1])
    with _annotate("kernels/w1a8_matmul"):
        y = w1a8_matmul(
            xq, w_packed, gamma_p, lam,
            bm=bm, bk=bk, bn=bn, out_dtype=out_dtype, interpret=not on_tpu(),
        )
    return y[:m]


def _bit_linear_decode(xf: Array, w_packed: Array, lam: Array, out_dtype):
    """Decode GEMV path: act-quant fused into the kernel prologue."""
    xp, m = _pad_rows(xf, 8)
    bk, bn = decode_tiles(xp.shape[0], xf.shape[1], w_packed.shape[1])
    with _annotate("kernels/w1a8_gemv"):
        y = w1a8_gemv(
            xp, w_packed, lam,
            bk=bk, bn=bn, out_dtype=out_dtype, interpret=not on_tpu(),
        )
    return y[:m]


def bit_linear_infer(
    x: Array, w_packed: Array, lam: Array, out_dtype=jnp.bfloat16
) -> Array:
    """Full W1A8 inference linear: quantize acts -> packed 1-bit matmul.

    x: (..., K) float; w_packed: (K//8, N) uint8; lam: scalar.
    Decode shapes (M <= DECODE_M_MAX flattened rows) take the fused GEMV
    tier; larger M takes the prefill-tiled kernel.
    """
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    if xf.shape[0] <= DECODE_M_MAX:
        y = _bit_linear_decode(xf, w_packed, lam, out_dtype)
    else:
        y = _bit_linear_prefill(xf, w_packed, lam, out_dtype)
    return y.reshape(*lead, -1)


# ---------------------------------------------------------------------------
# Tensor-parallel (N-major) kernel islands
# ---------------------------------------------------------------------------
#
# GSPMD treats a pallas_call as opaque, so an N-sharded packed weight fed to
# the plain dispatchers would be all-gathered around the kernel.  The
# ``*_nshard`` wrappers instead open a ``shard_map`` island over the active
# mesh: x / scales come in replicated, the weight comes in N-major-sharded,
# and each device runs the SAME kernel on its local (K, N/ws) shard — no
# collective inside the island, the dot-product reduction is never split,
# so per-shard outputs are bitwise slices of the unsharded result.  Because
# the kernel body sees the LOCAL shapes, the tile-dispatch keys
# (``_tile_key(op, m, k, n_local)``) become per-shard automatically — a
# swept winner on one shard width never collides with the full-width entry.


def _rep(ndim: int) -> P:
    return P(*([None] * ndim))


def _nshard(ndim: int, axis: str) -> P:
    return P(*([None] * (ndim - 1) + [axis]))


def bit_linear_infer_nshard(
    x: Array, w_packed: Array, lam: Array, axis: str, out_dtype=jnp.bfloat16
) -> Array:
    """:func:`bit_linear_infer` with ``w_packed`` sharded N-major over mesh
    axis ``axis`` (callers decide via ``sharding.nmajor_axis``).  ``lam`` is
    the per-weight AbsMean scalar — replicated, so every shard dequantizes
    with the same scale (per-shard scales == the full scale)."""
    mesh = _sharding.active_mesh()
    fn = functools.partial(bit_linear_infer, out_dtype=out_dtype)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(_rep(x.ndim), _nshard(2, axis), _rep(lam.ndim)),
        out_specs=_nshard(x.ndim, axis), check_rep=False,
    )(x, w_packed, lam)


def int8_linear_infer_nshard(
    x: Array, w_q: Array, wscale: Array, axis: str, out_dtype=jnp.bfloat16
) -> Array:
    """:func:`int8_linear_infer` with ``w_q`` sharded N-major; the AbsMax
    weight scale is a replicated scalar, shared by every shard."""
    mesh = _sharding.active_mesh()
    fn = functools.partial(int8_linear_infer, out_dtype=out_dtype)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(_rep(x.ndim), _nshard(2, axis), _rep(wscale.ndim)),
        out_specs=_nshard(x.ndim, axis), check_rep=False,
    )(x, w_q, wscale)


def decoupled_first_gemm_nshard(
    x: Array,
    w1_packed: Array,
    w8_q: Array,
    lam: Array,
    w8scale: Array,
    alpha: Array,
    beta: Array,
    axis: str,
    out_dtype=jnp.bfloat16,
):
    """:func:`decoupled_first_gemm` with the 1-bit trunk sharded N-major.
    The r-narrow 8-bit branch stays replicated (``ffn8`` maps to no mesh
    axis under the serving rules), so y1 comes out sharded and y8 comes out
    replicated."""
    mesh = _sharding.active_mesh()
    fn = functools.partial(decoupled_first_gemm, out_dtype=out_dtype)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(
            _rep(x.ndim), _nshard(2, axis), _rep(2), _rep(lam.ndim),
            _rep(w8scale.ndim), _rep(alpha.ndim), _rep(beta.ndim),
        ),
        out_specs=(_nshard(x.ndim, axis), _rep(x.ndim)),
        check_rep=False,
    )(x, w1_packed, w8_q, lam, w8scale, alpha, beta)


def int8_linear_infer(
    x: Array, w_q: Array, wscale: Array, out_dtype=jnp.bfloat16
) -> Array:
    """Full W8A8 inference linear (8-bit branch)."""
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    xq, gamma = quantize_act_int8(xf)
    bm = 8 if xq.shape[0] <= 128 else 128
    xq, m = _pad_rows(xq, bm)
    gamma_p = _pad_gamma(gamma, bm)
    bk, bn = _prefill_tiles(xf.shape[1], w_q.shape[1])
    with _annotate("kernels/int8_matmul"):
        y = int8_matmul(
            xq, w_q, gamma_p, wscale, bm=bm, bk=bk, bn=bn,
            out_dtype=out_dtype, interpret=not on_tpu(),
        )
    return y[:m].reshape(*lead, -1)


def fused_rmsnorm_quant(x: Array, scale: Array):
    """(..., D) -> (int8 (..., D), gamma (...,))."""
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    bm = 8 if xf.shape[0] <= 256 else 256
    xp, m = _pad_rows(xf, bm)
    q, gamma = rmsnorm_quant(xp, scale, bm=bm, interpret=not on_tpu())
    return q[:m].reshape(*lead, -1), gamma[:m].reshape(lead)


def _decoupled_prefill(
    xf, w1_packed, w8_q, lam, w8scale, alpha, beta, out_dtype
):
    xq, gamma = quantize_act_int8(xf)
    bm = 8 if xq.shape[0] <= 128 else 128
    xq, m = _pad_rows(xq, bm)
    gamma_p = _pad_gamma(gamma, bm)
    r = w8_q.shape[1]
    bk, bn = _prefill_tiles(xf.shape[1], w1_packed.shape[1], r=r)
    with _annotate("kernels/decoupled_matmul"):
        y1, y8 = decoupled_matmul(
            xq, w1_packed, w8_q, gamma_p, lam, w8scale, alpha, beta,
            bm=bm, bk=bk, bn=bn, out_dtype=out_dtype, interpret=not on_tpu(),
        )
    return y1[:m], y8[:m]


def _decoupled_decode(
    xf, w1_packed, w8_q, lam, w8scale, alpha, beta, out_dtype
):
    xp, m = _pad_rows(xf, 8)
    k, n, r = xf.shape[1], w1_packed.shape[1], w8_q.shape[1]
    bk, bn = decode_tiles(xp.shape[0], k, n, op="decoupled_gemv", r=r)
    with _annotate("kernels/decoupled_gemv"):
        y1, y8 = decoupled_gemv(
            xp, w1_packed, w8_q, lam, w8scale, alpha, beta,
            bk=bk, bn=bn, out_dtype=out_dtype, interpret=not on_tpu(),
        )
    return y1[:m], y8[:m]


def decoupled_first_gemm(
    x: Array,
    w1_packed: Array,
    w8_q: Array,
    lam: Array,
    w8scale: Array,
    alpha: Array,
    beta: Array,
    out_dtype=jnp.bfloat16,
):
    """Fused dual-branch up-projection for serving: reads activations once.

    Returns (y1 (..., N), y8 (..., R)), each pre-scaled by beta / alpha.
    Decode shapes route to the fused-act-quant ``decoupled_gemv``.
    """
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    if xf.shape[0] <= DECODE_M_MAX:
        y1, y8 = _decoupled_decode(
            xf, w1_packed, w8_q, lam, w8scale, alpha, beta, out_dtype
        )
    else:
        y1, y8 = _decoupled_prefill(
            xf, w1_packed, w8_q, lam, w8scale, alpha, beta, out_dtype
        )
    return y1.reshape(*lead, -1), y8.reshape(*lead, -1)


# ---------------------------------------------------------------------------
# Paged attention (block-table attention over the serving KV pool)
# ---------------------------------------------------------------------------

# pages-per-step candidates for the paged-attention autotune: how many pool
# pages one grid step scores (the per-step KV tile is pages * block_size
# columns wide)
_PAGES_CANDIDATES = (8, 4, 2, 1)


def paged_attention_enabled() -> bool:
    """Whether the model stack's paged branches dispatch the Pallas kernel.

    ``REPRO_PAGED_ATTN=1`` forces it on (interpret mode off-TPU — the
    parity/bench configuration), ``=0`` forces the gather+SDPA fallback,
    and the default (``auto``) enables it on TPU only: off-TPU the
    interpreted kernel is a correctness tool, not a fast path, and the
    serving parity suites rely on the fallback's bitwise-dense numerics.
    """
    v = os.environ.get("REPRO_PAGED_ATTN", "auto")
    if v == "0":
        return False
    if v == "1":
        return True
    return on_tpu()


def paged_attention_supported(
    block_size: int, head_dim: int, n_q_heads: int, n_kv_heads: int
) -> bool:
    """Static shape gate for the kernel (callers fall back on False):
    GQA grouping must divide evenly and page/head tiles must respect the
    8-row packing/sublane alignment the kernel assumes."""
    return (
        n_q_heads % n_kv_heads == 0
        and block_size % 8 == 0
        and head_dim % 8 == 0
    )


def paged_tiles(
    t: int, hq: int, hkv: int, d: int, bs: int, mb: int
) -> int:
    """pages-per-step for a paged-attention call: the autotuned winner if
    one was swept (this process or a persisted earlier one), otherwise the
    widest candidate that divides the table width (no wasted tail step)."""
    _ensure_tile_cache_loaded()
    cached = _DECODE_TILE_CACHE.get(("paged_attn", t, hq, hkv, d, bs, mb))
    if cached is not None:
        tile_cache.record_hit()
        return int(cached[0])
    tile_cache.record_miss()
    for c in _PAGES_CANDIDATES:
        if c <= mb and mb % c == 0:
            return c
    return 1


def sweep_paged_tiles(
    t: int,
    hq: int,
    hkv: int,
    d: int,
    bs: int,
    mb: int,
    *,
    candidates=None,
    warmup: int = 1,
    iters: int = 3,
    seed: int = 0,
) -> int:
    """Time the paged-attention kernel over pages-per-step candidates on
    the current backend, persist the winner under the
    ``(paged_attn, T, Hq, Hkv, D, block, max_blocks)`` signature (same
    per-backend JSON the GEMV tables use), and return it."""
    import numpy as np

    sweep_t0 = time.perf_counter()
    key = ("paged_attn", t, hq, hkv, d, bs, mb)
    rng = np.random.default_rng(seed)
    nb = 2 * mb
    q = jnp.asarray(rng.standard_normal((2, t, hq, d)).astype(np.float32))
    kp = jnp.asarray(
        rng.standard_normal((nb, bs, hkv, d)).astype(np.float32)
    )
    vp = jnp.asarray(
        rng.standard_normal((nb, bs, hkv, d)).astype(np.float32)
    )
    table = jnp.asarray(
        rng.permutation(nb)[: 2 * mb].reshape(2, mb).astype(np.int32)
    )
    # one full-context slot and one short one (both within capacity)
    s0 = max(mb * bs - t, 0)
    start = jnp.asarray([s0, min(bs, s0)], np.int32)
    lens = start + t
    interp = not on_tpu()
    best, best_t = None, float("inf")
    for pages in candidates or _PAGES_CANDIDATES:
        if pages > mb:
            continue
        try:
            call = functools.partial(
                _paged_attention, q, kp, vp, table, start, lens,
                pages=pages, interpret=interp,
            )
            for _ in range(warmup):
                jax.block_until_ready(call())
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(call())
                ts.append(time.perf_counter() - t0)
            dt = min(ts)
        except Exception:  # noqa: BLE001 — an invalid candidate just loses
            continue
        if dt < best_t:
            best, best_t = pages, dt
    if best is None:
        best = paged_tiles(t, hq, hkv, d, bs, mb)
    _DECODE_TILE_CACHE[key] = (best,)
    tile_cache.store(jax.default_backend(), {key: (best,)})
    tile_cache.record_sweep_ms((time.perf_counter() - sweep_t0) * 1e3)
    return best


def paged_attention(
    q: Array,  # (B, T, Hq, D)
    kpool: Array,  # (NB, BS, Hkv, D)
    vpool: Array,  # (NB, BS, Hkv, D)
    table: Array,  # (B, MB) int32
    start: Array,  # (B,) int32 — absolute position of q[:, 0]
    kv_lens: Array,  # (B,) int32 — resident tokens per slot
    scale: float | None = None,
) -> Array:
    """Block-table attention over the paged KV pool (flash-decoding-style
    online softmax, GQA/MQA grouping; T=1 decode, T>1 chunk/prefill).

    The jit'd public wrapper: picks pages-per-step from the autotuned
    table (``paged_tiles`` / ``sweep_paged_tiles``) and runs interpreted
    off-TPU.  Callers gate on :func:`paged_attention_enabled` /
    :func:`paged_attention_supported` and keep the ``kv_pool.read``
    gather + SDPA path as fallback and parity oracle
    (``ref.paged_attention_ref``).
    """
    t, hq, d = q.shape[1:]
    bs, hkv = kpool.shape[1], kpool.shape[2]
    mb = table.shape[1]
    pages = paged_tiles(t, hq, hkv, d, bs, mb)
    with _annotate("kernels/paged_attention"):
        return _paged_attention(
            q, kpool, vpool, table, start, kv_lens,
            pages=pages, scale=scale, interpret=not on_tpu(),
        )
