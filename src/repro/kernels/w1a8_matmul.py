"""W1A8 matmul Pallas kernel — the paper's inference hot spot, TPU-native.

GPU/CPU papers (T-MAC, LUT-GEMM) turn 1-bit GEMV into table lookups; TPUs
have no scalar LUT unit, so we adapt the *insight* (1-bit weights make the
op bandwidth-bound -> shrink bytes moved): weights live in HBM bit-packed
8-per-uint8 (16x smaller than bf16), each grid step streams a packed tile
HBM->VMEM, unpacks to +-1 INT8 on the VPU (shift/mask), and feeds the MXU's
int8 x int8 -> int32 path (2x the bf16 MACs/cycle on v5e).

Epilogue folds the dequant scales lam (weight AbsMean) and gamma (per-token
activation AbsMax) into the final tile write — no separate dequant pass
touches HBM (paper §A scale folding).

Grid: (M/bm, N/bn, K/bk) with a VMEM int32 accumulator; K is innermost so
the accumulator stays resident until the (i, j) tile finishes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

# Default tile sizes: bm x bk int8 acts (32 KiB), bk//8 x bn packed weights
# (8 KiB), bm x bn int32 accumulator (128 KiB) -> comfortably in 16 MiB VMEM
# with double buffering.  bk is a multiple of 8 (packing) and 128 (MXU).
DEFAULT_BM, DEFAULT_BK, DEFAULT_BN = 128, 256, 256


def _unpack_tile(packed: Array) -> Array:
    """(bk//8, bn) uint8 -> (bk, bn) int8 {-1, +1} (little-endian bits)."""
    kb, bn = packed.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)[None, :, None]
    bits = (packed[:, None, :] >> shifts) & jnp.uint8(1)
    return (bits.astype(jnp.int8) * 2 - 1).reshape(kb * 8, bn)


def _w1a8_kernel(x_ref, wp_ref, gamma_ref, lam_ref, o_ref, acc_ref):
    """One (i, j, k) grid step."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_tile = _unpack_tile(wp_ref[...])  # VPU unpack in VMEM
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_tile,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,  # MXU int8 path
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        lam = lam_ref[0]
        inv_gamma = 1.0 / gamma_ref[...]  # (bm,)
        y = acc_ref[...].astype(jnp.float32) * (lam * inv_gamma)[:, None]
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "bn", "out_dtype", "interpret"),
)
def w1a8_matmul(
    x_i8: Array,
    w_packed: Array,
    gamma: Array,
    lam: Array,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> Array:
    """Y (M, N) = dequant(X_int8 (M, K) @ unpack(W_packed (K//8, N))).

    Shapes must tile evenly (pad in ops.py for ragged cases).
    """
    m, k = x_i8.shape
    kb, n = w_packed.shape
    assert kb * 8 == k, f"packed K mismatch: {kb}*8 != {k}"
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm_ == 0 and k % bk_ == 0 and n % bn_ == 0, (m, k, n, bm_, bk_, bn_)

    return pl.pallas_call(
        _w1a8_kernel,
        grid=(m // bm_, n // bn_, k // bk_),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_ // 8, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm_,), lambda i, j, kk: (i,)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        interpret=interpret,
    )(x_i8, w_packed, gamma.astype(jnp.float32), lam.reshape(1).astype(jnp.float32))
