"""Pallas kernel tier for the pQuant integer serving path.

Every kernel has a pure-jnp oracle in ``ref.py``; ``ops.py`` owns padding,
CPU interpret fallback, and shape-keyed dispatch between the tiers.

Kernel            | File                | Shape regime                  | How ops.py selects it
------------------+---------------------+-------------------------------+----------------------------------------------
w1a8_matmul       | w1a8_matmul.py      | prefill/train, M > 32         | bit_linear_infer, M > DECODE_M_MAX: M-tiled
                  |                     |                               | (bm up to 128) grid, separate act-quant pass
w1a8_gemv         | w1a8_gemv.py        | decode, M <= 32               | bit_linear_infer, M <= DECODE_M_MAX: act-quant
                  |                     |                               | fused in prologue, (N, K)-major grid, wide bn;
                  |                     |                               | tiles from decode_tiles / sweep_decode_tiles
int8_matmul       | int8_matmul.py      | 8-bit branch, any M           | int8_linear_infer (W8A8 branch)
decoupled_matmul  | decoupled_matmul.py | prefill/train dual-branch     | decoupled_first_gemm, M > DECODE_M_MAX
decoupled_gemv    | w1a8_gemv.py        | decode dual-branch, M <= 32   | decoupled_first_gemm, M <= DECODE_M_MAX
rmsnorm_quant     | rmsnorm_quant.py    | norm + act-quant, any M       | fused_rmsnorm_quant
paged_attention   | paged_attention.py  | paged-KV attention: decode    | models.attention._paged_scores whenever the
                  |                     | (T=1), chunked prefill and    | cache is the paged {"kpool","vpool","table"}
                  |                     | one-shot prefill (any T),     | layout AND ops.paged_attention_enabled()
                  |                     | GQA/MQA                       | (REPRO_PAGED_ATTN=1 forces on / =0 forces the
                  |                     |                               | gather+SDPA fallback / default: TPU only) AND
                  |                     |                               | ops.paged_attention_supported (GQA divides,
                  |                     |                               | block_size & head_dim 8-aligned); MLA keeps
                  |                     |                               | its dense latent cache (nothing paged to walk)

Decode-tier tile sizes are answered per (M, K, N) signature by
``ops.decode_tiles`` (divisor heuristic) and can be autotuned on the
current backend with ``ops.sweep_decode_tiles`` — the swept winner is
cached and picked up by later calls with the same signature.  The paged-
attention pages-per-step knob is answered per (T, Hq, Hkv, head_dim,
block_size, max_blocks) by ``ops.paged_tiles`` and autotuned with
``ops.sweep_paged_tiles``; winners for both families persist in the same
per-backend JSON (``repro.kernels.tile_cache``).

Model-stack call sites (since the packed-forward wiring): ``bitlinear``
(attention / MLA projections), ``core.decoupled`` (FFN trunk, fused
dual-branch first GEMMs, 8-bit branch, decoupled projections) and
``models.moe`` (per-expert slices) all dispatch here whenever their
weights are in the ``quantize_params_for_serving(packed=True)`` layout —
``DecodeEngine`` / ``ContinuousBatchingEngine`` decode steps (M = batch
<= DECODE_M_MAX) land on the GEMV row.
"""
