"""Fused RMSNorm + per-token AbsMax INT8 quantize Pallas kernel.

Paper §A: "the RMSNorm operation can be merged with activation
quantization, as both are element-wise transformations."  Fusing them means
the normalized fp tensor never round-trips HBM between the norm and the
quantized GEMM — on a bandwidth-bound decode step this halves activation
traffic for the norm+quant stage.

Row-tiled: each grid step owns (bm, D) rows, computes rsqrt(mean(x^2)),
scales by the norm weight, takes the row AbsMax, and writes INT8 + gamma.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_BM = 256


def _rmsnorm_quant_kernel(x_ref, scale_ref, q_ref, gamma_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)[None, :]
    amax = jnp.max(jnp.abs(normed), axis=-1)
    gamma = 127.0 / (amax + 1e-5)
    q = jnp.clip(jnp.round(normed * gamma[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    gamma_ref[...] = gamma


@functools.partial(jax.jit, static_argnames=("bm", "eps", "interpret"))
def rmsnorm_quant(
    x: Array,
    scale: Array,
    *,
    bm: int = DEFAULT_BM,
    eps: float = 1e-6,
    interpret: bool = False,
):
    """x (M, D), scale (D,) -> (q (M, D) int8, gamma (M,) f32)."""
    m, d = x.shape
    bm_ = min(bm, m)
    assert m % bm_ == 0

    return pl.pallas_call(
        functools.partial(_rmsnorm_quant_kernel, eps=eps),
        grid=(m // bm_,),
        in_specs=[
            pl.BlockSpec((bm_, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm_, d), lambda i: (i, 0)),
            pl.BlockSpec((bm_,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, d), jnp.int8),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=interpret,
    )(x, scale)
