"""Pallas paged-attention kernel: block-table attention over the serving
KV pool.

Since the one-serving-forward refactor, every engine tier reads context
through a single path — ``kv_pool.read``'s dense gather followed by a
prefix-masked SDPA (``models.attention``'s paged branches).  At long
context that gather is the serving-path memory amplifier: it materializes
a ``(B, max_blocks * block, H, D)`` copy of the pool *per layer per step*
just so XLA's SDPA can read it.  This kernel consumes the paged layout
directly:

    kpool / vpool : (num_blocks, block, n_kv_heads, head_dim)
    table         : (B, max_blocks) int32  — per-slot block ids
    start         : (B,) int32 — absolute position of the first query token
    kv_lens       : (B,) int32 — resident tokens per slot (after the write)

and computes flash-decoding-style online-softmax attention block-by-block,
walking each slot's table in place — no dense gather ever exists.

Design (one kernel serves all three engine tiers):

* **Grid (B, n_kv_heads, steps)** with the block table and per-slot
  start/length vectors as *scalar prefetch* operands: the K/V page for a
  grid step is selected by indexing the table inside the BlockSpec index
  map (``PrefetchScalarGridSpec``), so the pipeline DMAs pool pages
  HBM->VMEM directly — the classic TPU paged-attention trick.
* **GQA/MQA head grouping.**  Queries are laid out (B, Hkv, T*G, D)
  (G = Hq // Hkv query heads per KV head), so one grid step scores every
  query row of one KV head against one K/V page tile: decode (T=1, rows =
  G), chunked prefill (T>1) and one-shot prefill are the same kernel at
  different T.
* **Causal prefix mask in-kernel.**  Query row r (= t * G + g) sits at
  absolute position ``start[b] + t`` and attends columns ``j <= pos`` —
  exactly ``models.attention._span_mask`` (T=1 degenerates to the decode
  mask), so the kernel is interchangeable with the gather+SDPA fallback.
* **Used-prefix skip.**  Steps whose pages lie entirely beyond
  ``kv_lens[b]`` skip their compute, and their index map clamps to the
  slot's last used page — the mapped block doesn't change, so the
  pipeline issues no new DMA: per-slot work scales with the *live*
  context, not the table capacity.
* **pages_per_step** (the autotuned knob, ``ops.paged_tiles`` /
  ``ops.sweep_paged_tiles``): each grid step fetches P pages via P
  parallel input specs (pages are non-contiguous in the pool, so one
  BlockSpec cannot cover them), widening the per-step score tile to
  ``P * block`` columns.

Numerics: scores, online-softmax state and the output accumulator are
f32 regardless of pool dtype; the result matches the gather+SDPA
reference to float rounding (online softmax re-associates the reduction,
so parity is allclose-at-f32, not bitwise — which is why ``ops``
dispatches the kernel only where the serving tests run it explicitly or
the backend is TPU; see ``ops.paged_attention_enabled``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30

# Queries are padded to the f32 sublane minimum so (T*G, D) tiles are legal.
_ROW_ALIGN = 8


def _paged_attention_kernel(
    # scalar prefetch
    table_ref,  # (B, MB) int32
    start_ref,  # (B,) int32
    lens_ref,  # (B,) int32
    # tensor inputs: q then P K pages then P V pages
    q_ref,  # (1, 1, TGp, D)
    *refs,
    bs: int,
    pages: int,
    g: int,
    scale: float,
    steps: int,
):
    """One (b, h, s) grid step: online-softmax update of every query row of
    KV head ``h`` against the ``pages`` pool pages covering columns
    ``[s * pages * bs, (s + 1) * pages * bs)`` of slot ``b``."""
    k_refs = refs[:pages]
    v_refs = refs[pages : 2 * pages]
    o_ref, m_ref, l_ref, acc_ref = refs[2 * pages :]
    b, s = pl.program_id(0), pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # steps whose first column is past the slot's resident length carry no
    # valid key — compute is skipped (their pages weren't re-fetched either:
    # the index map clamps to the last used page)
    @pl.when(s * pages * bs < lens_ref[b])
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)  # (TGp, D)
        k = jnp.concatenate([r[0, :, 0, :] for r in k_refs], axis=0)
        v = jnp.concatenate([r[0, :, 0, :] for r in v_refs], axis=0)
        tg, w = q.shape[0], pages * bs
        # causal prefix: query row r = t*g + gq sits at start[b] + t and
        # attends absolute columns j <= that position (== _span_mask)
        cols = s * w + jax.lax.broadcasted_iota(jnp.int32, (tg, w), 1)
        rows = jax.lax.broadcasted_iota(jnp.int32, (tg, w), 0) // g
        mask = cols <= start_ref[b] + rows
        sc = (
            jax.lax.dot_general(
                q,
                k.astype(jnp.float32),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            * scale
        )
        sc = jnp.where(mask, sc, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=1))
        # p is explicitly re-masked: when a whole tile is masked m_new can
        # stay at NEG_INF and exp(sc - m_new) would be 1, not 0
        p = jnp.where(mask, jnp.exp(sc - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p,
            v.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(s == steps - 1)
    def _epilogue():
        # l > 0 for every row: column 0 satisfies j <= start + t (start,
        # t >= 0) and page 0 is always processed, so no 0/0 lane exists
        o_ref[0, 0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("pages", "scale", "interpret")
)
def paged_attention(
    q: Array,  # (B, T, Hq, D)
    kpool: Array,  # (NB, BS, Hkv, D)
    vpool: Array,  # (NB, BS, Hkv, D)
    table: Array,  # (B, MB) int32
    start: Array,  # (B,) int32 — absolute position of q[:, 0]
    kv_lens: Array,  # (B,) int32 — resident tokens per slot (>= 1)
    *,
    pages: int = 1,
    scale: float | None = None,
    interpret: bool = False,
) -> Array:
    """Block-table attention over the paged KV pool: (B, T, Hq, D) out.

    Query token t of slot b attends pool positions ``j <= start[b] + t``
    (the resident prefix plus its in-chunk causal predecessors — the
    ``forward_chunk`` contract); T=1 is the decode shape.  ``kv_lens``
    bounds the per-slot page walk (normally ``start + T``, or
    ``start + lengths`` for a ragged final slice).  Requires
    ``Hq % Hkv == 0`` (GQA/MQA grouping) and ``pages >= 1`` (autotuned
    via ``ops.paged_tiles``).
    """
    b, t, hq, d = q.shape
    nb, bs, hkv, dk = kpool.shape
    mb = table.shape[1]
    assert d == dk and vpool.shape == kpool.shape, (q.shape, kpool.shape)
    assert hq % hkv == 0, f"GQA grouping needs Hq % Hkv == 0, got {hq}/{hkv}"
    g = hq // hkv
    tg = t * g
    scale = float(d**-0.5) if scale is None else float(scale)
    pages = max(1, min(int(pages), mb))
    steps = -(-mb // pages)

    # (B, T, Hq, D) -> (B, Hkv, T*G, D): one grid step owns every query row
    # of one KV head; rows padded to the sublane minimum (pad rows attend
    # column 0 so their softmax mass is finite — they are sliced off below)
    q5 = (
        q.reshape(b, t, hkv, g, d)
        .transpose(0, 2, 1, 3, 4)
        .reshape(b, hkv, tg, d)
    )
    pad = (-tg) % _ROW_ALIGN
    if pad:
        q5 = jnp.pad(q5, ((0, 0), (0, 0), (0, pad), (0, 0)))
    tgp = tg + pad

    def page_index_map(p):
        def index(bi, h, s, table, start, lens):
            i = s * pages + p
            # beyond the used prefix, re-map to the last used page: the
            # mapped block is unchanged from the previous step, so the
            # pipeline skips the DMA instead of streaming dead pages
            last = jnp.maximum((lens[bi] - 1) // bs, 0)
            i = jnp.minimum(jnp.minimum(i, last), mb - 1)
            return (table[bi, i], 0, h, 0)

        return index

    page_spec = [
        pl.BlockSpec((1, bs, 1, d), page_index_map(p)) for p in range(pages)
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, steps),
        in_specs=[
            pl.BlockSpec((1, 1, tgp, d), lambda bi, h, s, *_: (bi, h, 0, 0))
        ]
        + page_spec
        + page_spec,
        out_specs=pl.BlockSpec(
            (1, 1, tgp, d), lambda bi, h, s, *_: (bi, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((tgp,), jnp.float32),  # running max
            pltpu.VMEM((tgp,), jnp.float32),  # running denominator
            pltpu.VMEM((tgp, d), jnp.float32),  # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _paged_attention_kernel,
            bs=bs,
            pages=pages,
            g=g,
            scale=scale,
            steps=steps,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, tgp, d), q.dtype),
        interpret=interpret,
    )(
        table.astype(jnp.int32),
        start.astype(jnp.int32),
        kv_lens.astype(jnp.int32),
        q5,
        *([kpool] * pages),
        *([vpool] * pages),
    )
    out = out[:, :, :tg]
    return (
        out.reshape(b, hkv, t, g, d)
        .transpose(0, 2, 1, 3, 4)
        .reshape(b, t, hq, d)
    )
