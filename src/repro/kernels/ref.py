"""Pure-jnp oracles for every Pallas kernel.  Tests assert_allclose the
kernels (interpret=True on CPU) against these across shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def unpack_ref(packed: Array) -> Array:
    """(K//8, N) uint8 -> (K, N) int8 in {-1, +1} (little-endian bits)."""
    kb, n = packed.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)[None, :, None]
    bits = (packed[:, None, :] >> shifts) & jnp.uint8(1)
    return (bits.astype(jnp.int8) * 2 - 1).reshape(kb * 8, n)


def w1a8_matmul_ref(
    x_i8: Array, w_packed: Array, gamma: Array, lam: Array, out_dtype=jnp.float32
) -> Array:
    """Y = (X_int8 @ unpack(W)) * lam / gamma   (paper Eq. 10).

    x_i8: (M, K) int8 quantized activations; gamma: (M,) per-token scales;
    w_packed: (K//8, N) uint8 sign bits; lam: scalar AbsMean.
    """
    w = unpack_ref(w_packed)
    acc = jax.lax.dot_general(
        x_i8, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    y = acc.astype(jnp.float32) * lam.astype(jnp.float32) / gamma[:, None].astype(
        jnp.float32
    )
    return y.astype(out_dtype)


def int8_matmul_ref(
    x_i8: Array, w_i8: Array, gamma: Array, wscale: Array, out_dtype=jnp.float32
) -> Array:
    """Y = (X_int8 @ W_int8) / (gamma * wscale)   (W8A8 branch).

    wscale: scalar AbsMax weight scale (q = w * wscale).
    """
    acc = jax.lax.dot_general(
        x_i8, w_i8, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    y = acc.astype(jnp.float32) / (
        gamma[:, None].astype(jnp.float32) * wscale.astype(jnp.float32)
    )
    return y.astype(out_dtype)


def rmsnorm_quant_ref(x: Array, scale: Array, eps: float = 1e-6):
    """Fused RMSNorm + per-token AbsMax INT8 quantize (paper §A: 'RMSNorm
    merged with activation quantization').

    Returns (q (M, D) int8, gamma (M,) f32) with
    q = RoundClip(rmsnorm(x) * gamma), gamma = 127 / max|rmsnorm(x)|.
    """
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)[None, :]
    amax = jnp.max(jnp.abs(normed), axis=-1)
    gamma = 127.0 / (amax + 1e-5)
    q = jnp.clip(jnp.round(normed * gamma[:, None]), -127, 127).astype(jnp.int8)
    return q, gamma


def quantize_act_ref(x: Array):
    """Per-token AbsMax INT8 quantize (the XLA pass the GEMV tier fuses).

    x: (M, K) float -> (q (M, K) int8, gamma (M,) f32)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    gamma = 127.0 / (amax + 1e-5)
    q = jnp.clip(jnp.round(xf * gamma[:, None]), -127, 127).astype(jnp.int8)
    return q, gamma


def w1a8_gemv_ref(
    x: Array, w_packed: Array, lam: Array, out_dtype=jnp.float32
) -> Array:
    """Decode GEMV with fused act-quant: quantize_act_ref + w1a8_matmul_ref."""
    xq, gamma = quantize_act_ref(x)
    return w1a8_matmul_ref(xq, w_packed, gamma, lam, out_dtype=out_dtype)


def decoupled_gemv_ref(
    x: Array,
    w1_packed: Array,
    w8_i8: Array,
    lam: Array,
    w8scale: Array,
    alpha: Array,
    beta: Array,
    out_dtype=jnp.float32,
):
    """Dual-branch decode GEMV reference (act-quant + decoupled_matmul_ref)."""
    xq, gamma = quantize_act_ref(x)
    return decoupled_matmul_ref(
        xq, w1_packed, w8_i8, gamma, lam, w8scale, alpha, beta,
        out_dtype=out_dtype,
    )


def paged_attention_ref(
    q: Array,  # (B, T, Hq, D)
    kpool: Array,  # (NB, BS, Hkv, D)
    vpool: Array,  # (NB, BS, Hkv, D)
    table: Array,  # (B, MB) int32
    start: Array,  # (B,) int32
    kv_lens: Array,  # (B,) int32 (unused: the causal mask already bounds
    # every valid row's columns — kept so ref and kernel share a signature)
    scale=None,
    out_dtype=None,
):
    """Gather + prefix-masked SDPA at f32 — the dense read path the paged
    kernel replaces (``kv_pool.read`` followed by
    ``models.attention._sdpa`` under ``_span_mask``), with query token t
    of slot b attending absolute columns ``j <= start[b] + t``.
    """
    del kv_lens
    b, t, hq, d = q.shape
    bs, hkv = kpool.shape[1], kpool.shape[2]
    g = hq // hkv
    scale = d**-0.5 if scale is None else scale
    keys = jnp.take(kpool, table, axis=0).reshape(b, -1, hkv, d)
    vals = jnp.take(vpool, table, axis=0).reshape(b, -1, hkv, d)
    skv = keys.shape[1]
    qg = q.reshape(b, t, hkv, g, d).astype(jnp.float32)
    logits = (
        jnp.einsum("bqhgd,bkhd->bhgqk", qg, keys.astype(jnp.float32)) * scale
    )
    rowpos = start[:, None] + jnp.arange(t, dtype=start.dtype)[None]
    mask = jnp.arange(skv)[None, None, :] <= rowpos[:, :, None]  # (B,T,Skv)
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, vals.astype(jnp.float32))
    out = out.reshape(b, t, hq, d)
    return out.astype(out_dtype if out_dtype is not None else q.dtype)


def decoupled_matmul_ref(
    x_i8: Array,
    w1_packed: Array,
    w8_i8: Array,
    gamma: Array,
    lam: Array,
    w8scale: Array,
    alpha: Array,
    beta: Array,
    out_dtype=jnp.float32,
):
    """Fused first GEMM of the decoupled FFN (paper §A third point): the
    same INT8 activations multiply both branches in one pass.

    Returns (y1 (M, N) = beta * W1A8 result, y8 (M, R) = alpha * W8A8 result).
    """
    y1 = w1a8_matmul_ref(x_i8, w1_packed, gamma, lam) * beta
    y8 = int8_matmul_ref(x_i8, w8_i8, gamma, w8scale) * alpha
    return y1.astype(out_dtype), y8.astype(out_dtype)
