"""Block-paged KV storage for the serving subsystem.

Dense decode caches are ``(B, max_len, n_kv_heads, head_dim)`` buffers:
every slot owns ``max_len`` positions whether it uses them or not, so a
short request admitted next to a long one pays the long one's memory.  The
paged layout replaces the per-slot buffer with a shared pool

    kpool / vpool : (num_blocks, block_size, n_kv_heads, head_dim)
    table         : (B, max_blocks) int32  — per-slot block ids

where position ``p`` of slot ``b`` lives at ``(table[b, p // bs], p % bs)``.
Blocks are handed out by the host-side :class:`BlockAllocator` at admission
and chunk boundaries and reclaimed on eviction, so KV memory scales with
the *live* token count, not ``B * max_len``.

This module is deliberately model-agnostic (pure jax + shape arguments, no
``repro.models`` imports): ``repro.models.attention`` calls :func:`write` /
:func:`read` from its decode path, and ``repro.models.transformer`` builds
the per-layer cache dict via :func:`init_paged_attention_cache`.  A cache
dict containing a ``"table"`` key *is* the paged layout — that key is the
cache-adapter discriminator the model stack dispatches on.

Numerics contract: :func:`read` gathers a slot's blocks in table order, so
the gathered ``(B, max_blocks * block_size, H, D)`` view is element-for-
element the dense cache (up to trailing padding that the position mask
excludes).  Decode attention over a paged cache is therefore bit-for-bit
the dense computation — the parity tests in ``tests/test_continuous_
batching.py`` assert exactly that.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def blocks_for(length: int, block_size: int) -> int:
    """Number of blocks needed to hold ``length`` positions."""
    return -(-int(length) // int(block_size))


def init_paged_attention_cache(
    batch: int,
    max_len: int,
    n_kv_heads: int,
    head_dim: int,
    num_blocks: int,
    block_size: int,
    dtype,
):
    """(cache, axes) for one paged attention layer.

    ``max_len`` bounds a single slot's sequence (it sizes the table), while
    ``num_blocks`` sizes the shared pool — the whole point is that
    ``num_blocks`` can be far less than ``batch * max_blocks``.
    """
    if max_len % block_size:
        raise ValueError(
            f"max_len ({max_len}) must be a multiple of block_size "
            f"({block_size}) so prefill pages tile exactly"
        )
    max_blocks = blocks_for(max_len, block_size)
    pool_shape = (num_blocks, block_size, n_kv_heads, head_dim)
    cache = {
        "kpool": jnp.zeros(pool_shape, dtype),
        "vpool": jnp.zeros(pool_shape, dtype),
        "table": jnp.zeros((batch, max_blocks), jnp.int32),
    }
    axes = {
        # pools carry no batch axis — they are the shared resource
        "kpool": (None, None, "cache_heads", None),
        "vpool": (None, None, "cache_heads", None),
        "table": ("batch", None),
    }
    return cache, axes


# Trailing-dim-aligned logical axes per cache-dict key, for placing a whole
# engine cache tree on a mesh (stacked ring layers carry a leading layer
# dim — pad with None).  Pools shard over KV heads on `model`; per-slot
# tables and dense ring caches follow the `batch` rule, which the serving
# overrides map to None (replicated with the rest of the slot state).
CACHE_KEY_AXES: dict[str, tuple] = {
    "kpool": (None, None, "cache_heads", None),
    "vpool": (None, None, "cache_heads", None),
    "table": ("batch", None),
    "k": ("batch", "cache_seq", "cache_heads", None),
    "v": ("batch", "cache_seq", "cache_heads", None),
    "ckv": ("batch", "cache_seq", None),   # MLA latent caches stay dense
    "kpe": ("batch", "cache_seq", None),
}


def cache_sharding(cache_tree, mesh):
    """NamedShardings for an engine cache tree (per-layer dicts, possibly
    stacked), keyed on the cache-dict key via :data:`CACHE_KEY_AXES`.
    Unknown keys and indivisible dims replicate.  Must run inside
    ``sharding.sharding_rules`` so the serving rule overrides apply."""
    from jax.sharding import NamedSharding, PartitionSpec
    from jax.tree_util import tree_flatten_with_path, tree_unflatten

    from repro.distributed import sharding as sh

    leaves, treedef = tree_flatten_with_path(cache_tree)
    out = []
    for path, leaf in leaves:
        key = None
        for entry in reversed(path):
            k = getattr(entry, "key", None)
            if isinstance(k, str):
                key = k
                break
        axes = CACHE_KEY_AXES.get(key)
        if axes is None or len(axes) > leaf.ndim:
            spec = PartitionSpec()
        else:
            padded = (None,) * (leaf.ndim - len(axes)) + tuple(axes)
            spec = sh.relaxed_spec(leaf.shape, padded, mesh)
        out.append(NamedSharding(mesh, spec))
    return tree_unflatten(treedef, out)


def write(
    pool: Array,  # (NB, BS, H, D)
    table: Array,  # (B, MB) int32
    pos: Array,  # (B,) int32 — write position per slot
    val: Array,  # (B, H, D) — one token's K or V per slot
    active: Array | None = None,  # (B,) bool; inactive slots write nothing
) -> Array:
    """Scatter one token per slot into its block.  Inactive slots are
    routed out of bounds and dropped, so a finished request can never
    scribble into a block that has been reclaimed and reassigned."""
    bs = pool.shape[1]
    blk = jnp.take_along_axis(table, (pos // bs)[:, None], axis=1)[:, 0]
    off = pos % bs
    if active is not None:
        blk = jnp.where(active, blk, pool.shape[0])  # OOB -> mode="drop"
    return pool.at[blk, off].set(val.astype(pool.dtype), mode="drop")


def write_span(
    pool: Array,  # (NB, BS, H, D)
    table: Array,  # (B, MB) int32
    pos: Array,  # (B,) int32 — first write position per slot
    val: Array,  # (B, T, H, D) — T consecutive tokens per slot
    active: Array | None = None,  # (B,) bool; inactive slots write nothing
    lengths: Array | None = None,  # (B,) int32; tokens t >= lengths[b] dropped
) -> Array:
    """Scatter a span of T tokens per slot into its pages: position
    ``pos[b] + t`` lands at ``(table[b, (pos[b]+t) // BS], (pos[b]+t) % BS)``.

    This is the multi-token generalisation of :func:`write` that both
    chunked prefill (prompt slices land directly in pool pages) and
    one-shot admission install (a batch-1 prefilled dense cache scattered
    into the slot's pages in one span) run — the single pool write path.
    Masked entries (inactive slot, or ``t >= lengths[b]`` on a ragged
    final slice) are routed out of bounds and dropped, exactly like
    :func:`write`'s inactive slots.
    """
    bs = pool.shape[1]
    t = val.shape[1]
    p = pos[:, None] + jnp.arange(t, dtype=pos.dtype)[None, :]  # (B, T)
    mb = table.shape[1]
    blk = jnp.take_along_axis(table, jnp.clip(p // bs, 0, mb - 1), axis=1)
    ok = p < mb * bs  # masked rows may run past the table; clip + drop
    if lengths is not None:
        ok = ok & (jnp.arange(t)[None, :] < lengths[:, None])
    if active is not None:
        ok = ok & active[:, None]
    blk = jnp.where(ok, blk, pool.shape[0])  # OOB -> mode="drop"
    return pool.at[blk, p % bs].set(val.astype(pool.dtype), mode="drop")


def read(pool: Array, table: Array, blocks: int | None = None) -> Array:
    """Gather a dense per-slot view: (B, nb * BS, H, D) in position order,
    where ``nb`` is ``blocks`` (a static used-prefix bound) or the full
    table width.

    Callers that know no position ``>= blocks * BS`` can be attended (the
    prefill path's static ``read_to`` bound, or a pool sized for far more
    blocks than any live slot holds) pass ``blocks`` so the gather stops
    at the used-block prefix instead of materializing the whole table —
    at short contexts that is most of the fallback's memory traffic.
    Unallocated table entries point at block 0; the positions they cover
    sit beyond the slot's ``pos`` and are excluded by the attention mask,
    so the garbage is never read into a softmax lane.
    """
    if blocks is not None:
        table = table[:, : max(1, min(int(blocks), table.shape[1]))]
    g = jnp.take(pool, table, axis=0)  # (B, nb, BS, H, D)
    b, nb, bs = g.shape[:3]
    return g.reshape(b, nb * bs, *g.shape[3:])


def hash_block_tokens(parent: int | None, tokens) -> int:
    """Content identity of one FULL block: a chain hash of
    ``(parent_hash, block_tokens)``.

    The hash is computed over the HOST token stream (never over pool
    bytes), so two prompts share a block id exactly when they share the
    token prefix up to and including this block — and the identity is
    independent of dtype, mesh shape, or how the pool happens to be
    sharded.  ``parent`` is ``None`` for the first block of a prompt.
    """
    return hash((parent, tuple(int(t) for t in tokens)))


def prompt_block_hashes(tokens, block_size: int) -> list[int]:
    """Chain hashes for every *full* block of a token stream (the trailing
    partial block has no content identity — it is still being written)."""
    bs = int(block_size)
    out: list[int] = []
    parent: int | None = None
    for i in range(len(tokens) // bs):
        parent = hash_block_tokens(parent, tokens[i * bs : (i + 1) * bs])
        out.append(parent)
    return out


def copy_block(pool: Array, src, dst) -> Array:
    """``pool[dst] = pool[src]`` — one page copied inside the pool.  This
    is the copy-on-write primitive: a slot that must write inside a shared
    block first duplicates the page into a private block, so the shared
    page (and every other slot reading it) is never mutated."""
    return pool.at[dst].set(pool[src])


class BlockAllocator:
    """Host-side ref-counted free list over the pool's block ids, with
    content-hash identity and an LRU of reusable (cached) blocks.

    The allocator is the single source of truth for block ownership: the
    scheduler allocates at admission / chunk boundaries and *unrefs* on
    eviction.  Each block carries a refcount (shared prefix blocks are
    held by several slots at once) and, optionally, a content hash
    registered by the scheduler once the block's pages are fully written.
    A block whose refcount drops to zero is not forgotten: if it has a
    registered hash it parks on an LRU list, still indexed by
    ``lookup``, until :meth:`alloc` reclaims it (never-hashed blocks go
    straight back to the blank free list).  So "free" really means
    "unreferenced", and ``free_count`` counts *allocatable* blocks —
    blank + cached — which keeps the drain invariant
    ``free_count == num_blocks`` (and ``pool_blocks_used == 0``) intact
    even with a warm cache.

    Invariants (pinned by the property suite in ``tests/test_kv_pool.py``):

    * conservation — ``free_count + used_count == num_blocks`` at every
      step, where ``used_count`` counts blocks with refcount > 0;
    * eviction only ever reclaims refcount-0 blocks (live blocks are
      never on the LRU);
    * every hash-map entry points at a live-or-cached block (eviction
      drops the hash entries of the block it reclaims);
    * double-unref detection is O(1) (the refcount is the check — no
      membership scan of a free list).

    ``fail_hook`` is the fault-injection seam (see
    :mod:`repro.serve.faults`): a callable consulted once per ``alloc``
    whose ``True`` forces that call to fail with exhaustion semantics —
    ``None`` returned, no state change.  ``None`` (the default) costs one
    ``is not None`` check per alloc and nothing else.

    ``metrics`` is an optional :class:`repro.serve.metrics.MetricsRegistry`
    (duck-typed — this module stays dependency-free): when set, the
    allocator keeps the ``pool_blocks_used`` gauge exact at every
    alloc/unref (utilization is maintained at the source of truth, so it
    provably returns to zero after a drain) and counts
    ``block_allocs_total`` (blocks handed out),
    ``block_alloc_failures_total`` (exhaustion + injected failures) and
    ``prefix_cache_evictions_total`` (cached blocks reclaimed by alloc).
    Each metric is guarded independently — a registry that hands back
    only some instruments still gets the ones it asked for.
    """

    def __init__(self, num_blocks: int, fail_hook=None, metrics=None):
        self.num_blocks = num_blocks
        self.fail_hook = fail_hook
        self._ref = [0] * num_blocks  # refcount per block id
        self._blank = list(range(num_blocks - 1, -1, -1))  # pop() -> low ids
        # refcount-0 blocks that still hold registered content, in release
        # order (dict preserves insertion order): front = least recently
        # released = first evicted.
        self._lru: dict[int, None] = {}
        self._hash_of: dict[int, int] = {}  # block id -> content hash
        self._block_of: dict[int, int] = {}  # content hash -> block id
        self._g_used = metrics.gauge("pool_blocks_used") if metrics else None
        self._c_allocs = (
            metrics.counter("block_allocs_total") if metrics else None
        )
        self._c_fail = (
            metrics.counter("block_alloc_failures_total") if metrics else None
        )
        self._c_evict = (
            metrics.counter("prefix_cache_evictions_total") if metrics else None
        )

    @property
    def free_count(self) -> int:
        """Allocatable blocks: blank + cached (refcount-0, evictable)."""
        return len(self._blank) + len(self._lru)

    @property
    def used_count(self) -> int:
        """Blocks with refcount > 0 (owned by at least one slot)."""
        return self.num_blocks - self.free_count

    @property
    def cached_count(self) -> int:
        """Blocks with a registered content hash (live or parked)."""
        return len(self._block_of)

    def refcount(self, i: int) -> int:
        return self._ref[i]

    def _mark_fail(self) -> None:
        if self._c_fail is not None:
            self._c_fail.inc()

    def _set_used_gauge(self) -> None:
        if self._g_used is not None:
            self._g_used.set(self.used_count)

    def alloc(self, n: int) -> list[int] | None:
        """n block ids at refcount 1, or None (and no ownership change) if
        the pool is exhausted (or a fault-injection hook says to pretend
        it is).  Blank blocks are handed out first; when they run out the
        least-recently-released cached block is evicted — its hash-map
        entries die with it, so the index never points at a reclaimed
        block.  Refcount>0 blocks are never candidates."""
        if self.fail_hook is not None and self.fail_hook():
            self._mark_fail()
            return None
        if n > self.free_count:
            self._mark_fail()
            return None
        got = []
        for _ in range(n):
            if self._blank:
                i = self._blank.pop()
            else:
                i = next(iter(self._lru))  # least recently released
                del self._lru[i]
                del self._block_of[self._hash_of.pop(i)]
                if self._c_evict is not None:
                    self._c_evict.inc()
            self._ref[i] = 1
            got.append(i)
        self._set_used_gauge()
        if self._c_allocs is not None:
            self._c_allocs.inc(n)
        return got

    def unref(self, ids) -> None:
        """Drop one reference per id.  A block reaching refcount 0 parks
        on the LRU if its content is registered (a future admission can
        still hit it), else returns to the blank list.  Double-unref is an
        error, detected in O(1) from the refcount — no free-list scan."""
        pending: dict[int, int] = {}
        for i in ids:  # validate everything before mutating anything
            if not 0 <= i < self.num_blocks:
                raise ValueError(f"block id {i} out of range")
            pending[i] = pending.get(i, 0) + 1
            if pending[i] > self._ref[i]:
                raise ValueError(f"double free of block {i}")
        for i in ids:
            self._ref[i] -= 1
            if self._ref[i] == 0:
                if i in self._hash_of:
                    self._lru[i] = None  # most recently released -> back
                else:
                    self._blank.append(i)
        self._set_used_gauge()

    # "free" predates the refcounts; release paths still call it, and for
    # never-shared blocks it behaves exactly as before (ref 1 -> blank).
    free = unref

    def ref(self, i: int) -> None:
        """Take one reference on a live or cached block (an admission hit
        calls this for every reused block).  Reviving a cached block pulls
        it off the LRU so it can no longer be evicted."""
        if not 0 <= i < self.num_blocks:
            raise ValueError(f"block id {i} out of range")
        if self._ref[i] == 0:
            if i not in self._lru:
                raise ValueError(f"block {i} is blank — nothing to share")
            del self._lru[i]
        self._ref[i] += 1
        self._set_used_gauge()

    def lookup(self, h: int) -> int | None:
        """Block id currently holding content ``h``, or None.  Does not
        take a reference — callers :meth:`ref` each hit before any
        further alloc so their own tail allocation cannot evict it."""
        return self._block_of.get(h)

    def register(self, i: int, h: int) -> bool:
        """Record that live block ``i`` now holds content ``h`` (its pages
        are fully written).  First writer wins: if ``h`` is already mapped
        to another block, this one simply stays private (returns False)
        and will recycle as blank.  Re-registering the same (block, hash)
        is a no-op; re-registering a block under a *different* hash is a
        bug — block content never changes while registered."""
        if not 0 <= i < self.num_blocks:
            raise ValueError(f"block id {i} out of range")
        if self._ref[i] <= 0:
            raise ValueError(f"register of unreferenced block {i}")
        cur = self._hash_of.get(i)
        if cur is not None:
            if cur != h:
                raise ValueError(
                    f"block {i} re-registered under a different hash"
                )
            return True
        if h in self._block_of:
            return False
        self._hash_of[i] = h
        self._block_of[h] = i
        return True
