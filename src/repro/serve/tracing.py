"""Compatibility re-export: tracing/profiling moved to
``repro.telemetry.tracing`` so the training loop shares one tracer core
with the serving stack.  Serving-side imports keep working unchanged."""

from repro.telemetry.tracing import (  # noqa: F401
    PROFILE_DIR_ENV,
    JsonlSink,
    ListSink,
    RequestTracer,
    TrainTracer,
    annotate,
    fault_hook,
    maybe_profile,
)
