"""Deterministic fault injection for the serving stack.

The continuous-batching engine has to degrade gracefully under the
failures a production pool actually sees — allocator exhaustion, forced
preemption, non-finite logits out of an unstable sub-2-bit checkpoint,
requests arriving late — and "gracefully" is a *testable* property only
if the failures themselves are reproducible.  :class:`FaultInjector`
holds a typed, seeded schedule of faults and exposes the small hook
protocol the scheduler threads through its hot path:

========================  ==================================================
injection point           hook
========================  ==================================================
block-allocator failure   ``on_alloc()`` — consulted by
                          :class:`repro.serve.kv_pool.BlockAllocator` via
                          its ``fail_hook``; ``True`` forces that ``alloc``
                          call to return ``None`` (exhaustion semantics:
                          no state change)
forced preemption         ``preempt_uids(step)`` — requests to preempt at
                          the start of engine step ``step`` (chunk
                          boundary), by uid or youngest-live
poisoned logits           ``poison_rel_step(uid, ngen, length)`` — the
                          relative scan step inside the coming decode
                          chunk whose logits should be made non-finite
                          for that request, or ``None``
delayed arrival           ``arrival_delay(uid)`` — added to the request's
                          arrival time at ``submit``
========================  ==================================================

Every hook is a pure lookup into the schedule plus a fired-fault counter
(``injected``), so the same schedule replays identically.  With no
injector the scheduler skips the hooks entirely and — crucially for the
chaos suite's bitwise-parity oracle — compiles exactly the same XLA
programs as before this module existed: logit poisoning lives in a
*separate* lazily-compiled chunk variant, never in the fault-free one.

Faults target requests by ``uid`` and streams by *generation index*, not
by slot or wall time: slots are a scheduling artifact, while (uid, gen)
names the same point in a request's deterministic stream under any
admission order — which is what makes a fault schedule meaningful across
scheduling perturbations caused by the *other* faults in the schedule.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class AllocFailure:
    """Force the ``index``-th ``BlockAllocator.alloc`` call (0-based over
    the engine's lifetime, warm-up included) to fail as if the pool were
    exhausted.  The scheduler's wait/preempt recovery path must absorb it
    with no stream change."""

    index: int


@dataclasses.dataclass(frozen=True)
class ForcePreempt:
    """Preempt a live request at the start of engine step ``step`` (a
    chunk boundary — the only place real preemption happens).  ``uid``
    picks the victim; ``None`` preempts the youngest live request, the
    same victim policy the pool-pressure path uses.  A no-op if nothing
    matching is live at that step."""

    step: int
    uid: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class PoisonLogits:
    """Make every logit non-finite at the decode step that would sample
    request ``uid``'s ``gen_index``-th generated token (0-based; index 0
    is the prefill-sampled token, so the smallest injectable index is 1).
    The quarantine contract: the request finishes with
    ``finish_reason="error"`` carrying its first ``gen_index`` tokens,
    and no other stream moves by a bit."""

    uid: int
    gen_index: int


@dataclasses.dataclass(frozen=True)
class DelayArrival:
    """Add ``delay`` clock units to request ``uid``'s arrival time at
    ``submit`` — late arrivals reshuffle admission order without touching
    any stream's content."""

    uid: int
    delay: float


Fault = Union[AllocFailure, ForcePreempt, PoisonLogits, DelayArrival]


class FaultInjector:
    """A replayable schedule of typed faults (see module docstring).

    ``injected`` counts faults that actually fired, per kind — a chaos
    trace that schedules a poison past the stream's natural end simply
    never fires it, and the counter lets tests tell the difference.
    """

    def __init__(self, faults: tuple[Fault, ...] | list[Fault] = ()):
        self.faults = tuple(faults)
        self.injected: collections.Counter = collections.Counter()
        #: Optional ``(kind, info_dict)`` callback invoked for every fired
        #: fault — the tracing seam (see ``repro.serve.tracing.fault_hook``)
        #: that lands injected faults on the request timeline.
        self.on_fire = None
        self._alloc_calls = 0
        self._alloc_fail_at = {
            f.index for f in self.faults if isinstance(f, AllocFailure)
        }
        self._preempts: dict[int, list[ForcePreempt]] = {}
        self._delays: dict[int, float] = {}
        # uid -> ascending pending gen indices (consumed as they fire)
        self._poisons: dict[int, list[int]] = {}
        for f in self.faults:
            if isinstance(f, ForcePreempt):
                self._preempts.setdefault(f.step, []).append(f)
            elif isinstance(f, DelayArrival):
                self._delays[f.uid] = self._delays.get(f.uid, 0.0) + f.delay
            elif isinstance(f, PoisonLogits):
                if f.gen_index < 1:
                    raise ValueError(
                        "gen_index 0 is the prefill-sampled token; logit "
                        "poisoning targets decode steps (gen_index >= 1)"
                    )
                self._poisons.setdefault(f.uid, []).append(f.gen_index)
        for g in self._poisons.values():
            g.sort()

    def fire(self, kind: str, **info) -> None:
        """Record that a fault of ``kind`` actually fired: bump the replay
        counter and notify ``on_fire`` (if set) with the fault's context.
        Every fired-fault site — here and in the scheduler's forced-preempt
        path — funnels through this one chokepoint."""
        self.injected[kind] += 1
        if self.on_fire is not None:
            self.on_fire(kind, info)

    # -- hook protocol ------------------------------------------------------

    def on_alloc(self) -> bool:
        """Consulted once per ``BlockAllocator.alloc`` call; ``True``
        forces that call to fail."""
        i = self._alloc_calls
        self._alloc_calls += 1
        if i in self._alloc_fail_at:
            self.fire("alloc_failure", index=i)
            return True
        return False

    def preempt_uids(self, step: int) -> list[Optional[int]]:
        """Victim uids to preempt at engine step ``step`` (``None`` =
        youngest live)."""
        return [f.uid for f in self._preempts.get(step, [])]

    def arrival_delay(self, uid: int) -> float:
        d = self._delays.get(uid, 0.0)
        if d:
            self.fire("delay_arrival", uid=uid, delay=d)
        return d

    @property
    def has_poison(self) -> bool:
        """Whether any logit-poison fault is (still) scheduled — gates the
        lazily-compiled poisoning chunk variant."""
        return any(self._poisons.values())

    def poison_rel_step(
        self, uid: int, ngen: int, length: int
    ) -> Optional[int]:
        """If request ``uid`` (currently at ``ngen`` generated tokens) has
        a poison scheduled inside the coming ``length``-step decode chunk,
        consume it and return its relative scan step; else ``None``.

        A preempted request restarts from scratch, so an unfired poison
        stays scheduled and fires on the re-run — (uid, gen) identity."""
        pend = self._poisons.get(uid)
        if not pend:
            return None
        g = pend[0]
        if ngen <= g < ngen + length:
            pend.pop(0)
            self.fire("poison_logits", uid=uid, gen_index=g)
            return g - ngen
        return None

    # -- schedule generation ------------------------------------------------

    #: Fault kinds :meth:`random` can draw, in draw order (the default
    #: tuple reproduces the historical 0..3 integer mapping bit for bit).
    KINDS = ("alloc", "preempt", "poison", "delay")

    @classmethod
    def random(
        cls,
        seed: int,
        uids,
        *,
        n_faults: int = 6,
        max_step: int = 24,
        max_alloc: int = 48,
        max_gen: int = 8,
        max_delay: float = 4.0,
        kinds=KINDS,
    ) -> "FaultInjector":
        """A seeded random schedule over ``uids`` — the chaos suite's
        entry point.  Same (seed, uids, knobs) -> same schedule, bit for
        bit, with every requested fault kind represented in expectation.

        ``kinds`` restricts (and weights, by repetition) which fault
        kinds are drawn — e.g. ``kinds=("alloc", "preempt")`` produces
        the allocation-failure + forced-preemption schedules the
        prefix-sharing chaos suite hammers shared blocks with: every
        admission walk can be denied blocks and every live request can be
        preempted *while other slots still hold references to its
        blocks*, without poison/delay faults diluting the schedule."""
        bad = set(kinds) - set(cls.KINDS)
        if bad:
            raise ValueError(f"unknown fault kinds {sorted(bad)!r}")
        if not kinds:
            raise ValueError("kinds must name at least one fault kind")
        rng = np.random.default_rng(seed)
        uids = list(uids)
        faults: list[Fault] = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(0, len(kinds)))]
            if kind == "alloc":
                faults.append(AllocFailure(int(rng.integers(0, max_alloc))))
            elif kind == "preempt":
                uid = (
                    int(rng.choice(uids)) if uids and rng.integers(0, 2)
                    else None
                )
                faults.append(
                    ForcePreempt(int(rng.integers(0, max_step)), uid)
                )
            elif kind == "poison" and uids:
                faults.append(
                    PoisonLogits(
                        int(rng.choice(uids)), int(rng.integers(1, max_gen))
                    )
                )
            elif kind == "delay" and uids:
                faults.append(
                    DelayArrival(
                        int(rng.choice(uids)),
                        float(rng.uniform(0.0, max_delay)),
                    )
                )
        return cls(faults)
