"""Continuous-batching scheduler: per-request lifecycle over a shared slot
batch and paged KV pool.

``DecodeEngine`` (the lockstep tier) decodes a fixed batch in lockstep:
every request burns the full token budget, and a new request waits for the
whole batch to drain.  ``ContinuousBatchingEngine`` keeps the same compiled
decode program (fixed ``num_slots``-wide batch, ``lax.scan`` chunks,
on-device sampling) but gives every slot its own lifecycle:

* **admission** — a queued request is prefilled batch-1, its KV prefix
  installed into a free slot (scattered into pool blocks under the paged
  layout), and its per-slot state (position, PRNG key, budget) written
  device-side.  Where parity allows (:func:`_bucketed_prefill_safe`) the
  prompt is right-padded to a power-of-two bucket so one compiled trace
  serves every length in the bucket; pad positions are causally invisible
  and their cache slots stay masked until decode overwrites them, so each
  request's stream is unchanged.  Ring-cache / recurrent / MoE configs
  fall back to exact-length prefill (one retrace per distinct length).
* **decode** — one compiled chunk advances all slots together; per-slot
  positions, EOS/stop-token hits and ``max_new_tokens`` budgets are
  tracked as on-device masks, and finished slots produce **no cache
  writes** (that is what makes reclaiming their blocks safe).
* **eviction** — at the chunk boundary finished requests leave their slot,
  their blocks return to the allocator's free list, and the next queued
  request is admitted into the hole.

Determinism contract: each request carries its own seed, and admission
prefill + per-slot key-splitting reproduce ``DecodeEngine``'s exact
key-split order for a batch-1 call.  A request's token stream is therefore
identical to ``DecodeEngine.generate(prompt[None], scfg, seed=seed)`` up
to stop-token truncation — the parity tests assert this bit-for-bit, for
both the dense and paged cache layouts.

Host-transfer hygiene: one fetch of the packed ``(B, chunk+1)`` token
matrix per decode chunk (the last column is the device's post-chunk active
mask, cross-checked against the host mirror), plus one scalar fetch per
admission (the prefill-sampled first token).  ``host_transfers`` counts
them.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.models.transformer import build_segments
from repro.serve import kv_pool
from repro.serve.engine import (
    SamplerConfig,
    _hit_stop,
    _make_bucketed_prefill_fn,
    _make_prefill_fn,
    sample_token,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# Request lifecycle records
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request.  ``seed`` makes the stream reproducible and
    independent of scheduling; ``arrival`` is in the engine's clock units
    (chunk ticks under the default virtual clock, seconds with a real
    one)."""

    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    seed: int = 0
    arrival: float = 0.0


@dataclasses.dataclass
class RequestState:
    """Host mirror of an admitted request (the device holds the arrays)."""

    request: Request
    slot: int
    blocks: list[int]
    tokens: list[int]
    n_generated: int
    admitted_at: float
    done: bool = False
    finish_reason: str = ""

    @property
    def pos(self) -> int:
        """Next write position = prompt_len + generated so far."""
        return len(self.request.prompt) + self.n_generated


@dataclasses.dataclass(frozen=True)
class FinishedRequest:
    uid: int
    tokens: np.ndarray  # (n,) int32, n <= max_new_tokens
    finish_reason: str  # "stop" | "length"
    prompt_len: int
    arrival: float
    admitted_at: float
    finished_at: float


# ---------------------------------------------------------------------------
# Compiled pieces
# ---------------------------------------------------------------------------


def _walk_blocks(cfg: ModelConfig):
    """(segment index, block key, spec, stacked) for every cache dict in
    the tree that :func:`repro.models.api.init_cache` builds."""
    for si, seg in enumerate(build_segments(cfg)):
        for bi, spec in enumerate(seg.blocks):
            yield si, f"b{bi}", spec, seg.repeats > 1


def _map_blocks(cfg: ModelConfig, fn, *trees):
    """Apply ``fn(spec, stacked, *block_dicts)`` over parallel cache trees."""
    out = []
    for si, key, spec, stacked in _walk_blocks(cfg):
        while len(out) <= si:
            out.append({})
        out[si][key] = fn(spec, stacked, *(t[si][key] for t in trees))
    return out


def _row_set(big: Array, small: Array, slot: Array, stacked: bool) -> Array:
    """big[..., slot, ...] = small[..., 0, ...] along the batch axis (index
    1 on layer-stacked leaves, 0 otherwise)."""
    ax = 1 if stacked else 0
    idx = (slice(None),) * ax + (slot,)
    return big.at[idx].set(jnp.take(small, 0, axis=ax).astype(big.dtype))


def _make_install_fn(cfg: ModelConfig, nb: int):
    """Install a batch-1 prefill cache into slot ``slot`` of the big cache
    tree.  ``nb`` (static) is the number of prompt-covering pages scattered
    into the pool for paged layers; dense leaves copy the whole row."""

    def install(big, small, slot, table_row):
        def blockfn(spec, stacked, bigc, smallc):
            if "table" in bigc:
                bids = table_row[:nb]

                def scatter(pool, dense):
                    return kv_pool.scatter_prefill(pool, dense[0], bids)

                if stacked:
                    scatter = jax.vmap(scatter)
                ax = 1 if stacked else 0
                idx = (slice(None),) * ax + (slot,)
                return {
                    "kpool": scatter(bigc["kpool"], smallc["k"]),
                    "vpool": scatter(bigc["vpool"], smallc["v"]),
                    "table": bigc["table"].at[idx].set(table_row),
                }
            return jax.tree.map(
                lambda b, s: _row_set(b, s, slot, stacked), bigc, smallc
            )

        return _map_blocks(cfg, blockfn, big, small)

    return install


def _make_set_tables_fn(cfg: ModelConfig):
    """Rewrite one slot's block-table row in every paged layer (block
    extension at a chunk boundary)."""

    def set_tables(big, slot, table_row):
        def blockfn(spec, stacked, bigc):
            if "table" not in bigc:
                return bigc
            ax = 1 if stacked else 0
            idx = (slice(None),) * ax + (slot,)
            return dict(bigc, table=bigc["table"].at[idx].set(table_row))

        return _map_blocks(cfg, blockfn, big)

    return set_tables


def _make_cb_chunk_fn(cfg: ModelConfig, scfg: SamplerConfig, length: int):
    """``length`` decode steps over the slot batch with per-slot positions,
    keys, budgets and stop masks.  Returns (packed (B, length+1), caches,
    state) — the packed matrix's last column is the post-chunk active mask,
    riding the chunk's single device->host transfer.

    Per-slot sampling vmaps the batch-1 sampler over (key, logits-row)
    pairs, which is bit-for-bit what ``DecodeEngine`` computes for a
    batch-1 call with that key — the determinism contract of the module
    docstring."""

    def chunk(params, caches, state):
        def step(carry, _):
            caches, st = carry
            split = jax.vmap(jax.random.split)(st["key"])  # (B, 2, 2)
            new_key, sub = split[:, 0], split[:, 1]
            logits, caches = api.decode_step(
                params, st["tok"][:, None], caches, st["pos"], cfg,
                active=st["active"],
            )
            logits = logits[:, -1]  # (B, V)
            nxt = jax.vmap(
                lambda s, l: sample_token(s, l[None], scfg)[0]
            )(sub, logits)
            nxt = jnp.where(st["active"], nxt, st["tok"])
            act = st["active"].astype(jnp.int32)
            ngen = st["ngen"] + act
            alive = (
                st["active"]
                & ~_hit_stop(nxt, scfg)
                & (ngen < st["budget"])
            )
            st = {
                "tok": nxt,
                "pos": st["pos"] + act,
                "key": new_key,
                "active": alive,
                "ngen": ngen,
                "budget": st["budget"],
            }
            return (caches, st), nxt

        (caches, st), toks = jax.lax.scan(
            step, (caches, state), None, length=length
        )
        toks = jnp.moveaxis(toks, 0, 1)  # (B, length)
        packed = jnp.concatenate(
            [toks, st["active"][:, None].astype(toks.dtype)], axis=1
        )
        return packed, caches, st

    return chunk


def _bucketed_prefill_safe(cfg: ModelConfig, max_len: int) -> bool:
    """Whether admission prefill may right-pad prompts to a shared bucket
    length without changing any request's stream.

    Safe exactly when pad tokens cannot leak into real positions: causal
    attention confines them to cache slots the decode mask gates until the
    real stream overwrites them.  Unsafe cases fall back to exact-length
    prefill (one retrace per distinct length, the pre-bucketing behavior):

    * ring caches (``window < max_len``): prefill keeps the last W
      positions of the *padded* sequence, evicting real tokens;
    * ssm / rec mixers: the recurrent state integrates the pad suffix;
    * MoE / routed 8-bit branches: Switch-style capacity couples tokens,
      so the pad tokens change real tokens' routing;
    * VLM image prefixes (position offsets are caller-managed).
    """
    if cfg.moe or cfg.quant.num_experts > 1 or cfg.n_image_tokens > 0:
        return False
    for seg in build_segments(cfg):
        for spec in seg.blocks:
            if spec.mixer not in ("attn", "mla"):
                return False
            if 0 < spec.window < max_len:
                return False
    return True


def _admit_state(state, slot, tok0, key, pos0, budget):
    """Write one slot's device-side lifecycle state (ngen starts at 1: the
    prefill-sampled first token is emitted at admission)."""
    return {
        "tok": state["tok"].at[slot].set(tok0),
        "pos": state["pos"].at[slot].set(pos0),
        "key": state["key"].at[slot].set(key),
        "active": state["active"].at[slot].set(True),
        "ngen": state["ngen"].at[slot].set(1),
        "budget": state["budget"].at[slot].set(budget),
    }


def _deactivate(state, slot):
    return dict(state, active=state["active"].at[slot].set(False))


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class ContinuousBatchingEngine:
    """Serving tier 3: request queue + slot admission/eviction over one
    compiled fixed-width decode program (see module docstring).

    Parameters
    ----------
    num_slots : compiled batch width — concurrent in-flight requests.
    max_len : per-slot sequence capacity (prompt + generated).
    scfg : engine-level sampling signature (temperature / top_k /
        stop_tokens).  Per-request knobs are ``max_new_tokens`` and
        ``seed``; the sampler signature is baked into the compiled program.
    layout : "paged" (global-attention KV in a shared block pool) or
        "dense" (per-slot buffers).  Interchangeable — same token streams.
    num_blocks : pool size per paged layer; defaults to full occupancy
        (``num_slots * max_len / block_size``).  Smaller pools admit fewer
        long requests at once; if blocks run out mid-flight the youngest
        request is preempted back to the queue (restart-from-scratch is
        deterministic, so its stream is unchanged).
    clock : optional callable returning the current time in seconds; by
        default a virtual clock advances one tick per decode chunk and
        ``Request.arrival`` is in ticks.
    """

    def __init__(
        self,
        params,
        cfg: ModelConfig,
        num_slots: int,
        max_len: int,
        scfg: Optional[SamplerConfig] = None,
        *,
        layout: str = "paged",
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        chunk: int = 8,
        clock: Optional[Callable[[], float]] = None,
    ):
        if cfg.family == "encdec":
            raise NotImplementedError("continuous batching is decoder-only")
        if layout not in ("dense", "paged"):
            raise ValueError(f"unknown cache layout {layout!r}")
        if layout == "paged" and max_len % block_size:
            raise ValueError("max_len must be a multiple of block_size")
        self.params, self.cfg = params, cfg
        self.num_slots, self.max_len = num_slots, max_len
        self.scfg = scfg or SamplerConfig()
        self.layout, self.block_size, self.chunk = layout, block_size, chunk
        self.max_blocks = kv_pool.blocks_for(max_len, block_size)
        self.num_blocks = num_blocks or num_slots * self.max_blocks
        self.allocator = (
            kv_pool.BlockAllocator(self.num_blocks)
            if layout == "paged" else None
        )
        self._clock = clock
        self._now = 0.0  # virtual clock (chunk ticks) when clock is None
        self.host_transfers = 0
        self.preemptions = 0

        self._queue: list[Request] = []
        self._slots: list[Optional[RequestState]] = [None] * num_slots
        self._uid_counter = 0  # monotonic: uids never recycle
        self._stop_set = set(int(t) for t in self.scfg.stop_tokens)

        self._caches = self._init_big_caches()
        b = num_slots
        self._state = {
            "tok": jnp.zeros((b,), jnp.int32),
            "pos": jnp.zeros((b,), jnp.int32),
            "key": jnp.zeros((b, 2), jnp.uint32),
            "active": jnp.zeros((b,), bool),
            "ngen": jnp.zeros((b,), jnp.int32),
            "budget": jnp.zeros((b,), jnp.int32),
        }

        # exact-length prefill retraces per prompt length; where parity
        # allows it (_bucketed_prefill_safe), admission right-pads prompts
        # to power-of-two buckets so one trace covers a whole bucket
        self._prefill = jax.jit(
            _make_prefill_fn(cfg, max_len, self.scfg)
        )
        self._prefill_bucketed = (
            jax.jit(_make_bucketed_prefill_fn(cfg, max_len, self.scfg))
            if _bucketed_prefill_safe(cfg, max_len) else None
        )
        # the cache tree and slot state are donated: the chunk rewrites
        # them in place instead of copying the full KV pool every chunk
        # (the caller rebinds both from the return value)
        self._chunk_fn = jax.jit(
            _make_cb_chunk_fn(cfg, self.scfg, chunk), donate_argnums=(1, 2)
        )
        self._install_fns: dict[int, Callable] = {}
        self._set_tables = jax.jit(_make_set_tables_fn(cfg), donate_argnums=(0,))
        self._admit_jit = jax.jit(_admit_state, donate_argnums=(0,))
        self._deactivate_jit = jax.jit(_deactivate, donate_argnums=(0,))

    # -- construction -------------------------------------------------------

    def _init_big_caches(self):
        """Big cache tree: shapes from a batch-``num_slots`` init, leaf
        dtypes taken from what prefill actually produces (so installing a
        prefilled row never casts — bit parity with ``DecodeEngine``,
        whose caches come straight out of prefill)."""
        cfg, b = self.cfg, self.num_slots
        dummy = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
        small = jax.eval_shape(
            lambda p, t: api.prefill(p, t, cfg, self.max_len)[1],
            self.params, dummy,
        )

        def blockfn(spec, stacked, smallc):
            ax = 1 if stacked else 0
            if (
                self.layout == "paged"
                and spec.mixer == "attn"
                and spec.window == 0
            ):
                cache, _ = kv_pool.init_paged_attention_cache(
                    b, self.max_len, cfg.n_kv_heads, cfg.head_dim,
                    self.num_blocks, self.block_size, smallc["k"].dtype,
                )
                if stacked:
                    r = smallc["k"].shape[0]
                    cache = jax.tree.map(
                        lambda t: jnp.broadcast_to(t[None], (r,) + t.shape),
                        cache,
                    )
                return cache
            return jax.tree.map(
                lambda l: jnp.zeros(
                    l.shape[:ax] + (b,) + l.shape[ax + 1:], l.dtype
                ),
                smallc,
            )

        return _map_blocks(cfg, blockfn, small)

    # -- host boundary ------------------------------------------------------

    def _fetch(self, x) -> np.ndarray:
        self.host_transfers += 1
        return np.asarray(x)

    def now(self) -> float:
        return self._clock() if self._clock is not None else self._now

    # -- public API ---------------------------------------------------------

    def submit(
        self,
        prompt,
        max_new_tokens: Optional[int] = None,
        seed: int = 0,
        uid: Optional[int] = None,
        arrival: float = 0.0,
    ) -> int:
        """Queue a request; returns its uid.  Validates that the request
        can ever fit: prompt + budget within a slot's capacity, and (paged)
        within the whole pool."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        budget = (
            self.scfg.max_new_tokens if max_new_tokens is None
            else max_new_tokens
        )
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if budget < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {budget}")
        total = len(prompt) + budget
        if total > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + budget ({budget}) exceeds the "
                f"slot capacity max_len={self.max_len}"
            )
        if self.allocator is not None:
            need = kv_pool.blocks_for(total, self.block_size)
            if need > self.num_blocks:
                raise ValueError(
                    f"request needs {need} blocks but the pool has only "
                    f"{self.num_blocks}"
                )
        if uid is None:
            uid = self._uid_counter
        self._uid_counter = max(self._uid_counter, uid + 1)
        self._queue.append(
            Request(uid, prompt, budget, seed=seed, arrival=arrival)
        )
        return uid

    def run(self) -> list[FinishedRequest]:
        """Process the queue to completion; FinishedRequests in completion
        order."""
        finished: list[FinishedRequest] = []
        while self._queue or self._live():
            finished.extend(self.step())
        return finished

    def step(self) -> list[FinishedRequest]:
        """One scheduling tick: admit arrived requests, ensure pool blocks
        for the coming chunk, run one compiled decode chunk, evict finished
        requests.  Returns the requests that finished this tick."""
        finished = list(self._admit_arrived())
        if not self._live():
            if self._queue:
                self._advance_clock()
            return finished
        if self.allocator is not None:
            self._ensure_blocks()
        packed = self._fetch(self._run_chunk())
        if self._clock is None:
            self._now += 1.0
        finished.extend(self._process_chunk(packed))
        return finished

    # -- scheduling internals ----------------------------------------------

    def _live(self) -> list[RequestState]:
        return [rs for rs in self._slots if rs is not None]

    def _advance_clock(self) -> None:
        """Nothing in flight: jump (virtual) or wait (real) to the next
        arrival."""
        nxt = min(r.arrival for r in self._queue)
        if self._clock is None:
            self._now = max(self._now, float(nxt))
        else:
            import time

            time.sleep(max(0.0, min(nxt - self.now(), 0.05)))

    def _admit_arrived(self) -> list[FinishedRequest]:
        """FIFO-admit every arrived request that fits a free slot (and, if
        paged, whose prompt blocks are available).  Requests whose first
        token already finishes them (budget 1 / instant stop) complete
        here and never occupy a slot."""
        finished = []
        while True:
            free = [i for i, rs in enumerate(self._slots) if rs is None]
            if not free:
                break
            ready = [r for r in self._queue if r.arrival <= self.now()]
            if not ready:
                break
            req = ready[0]
            blocks: list[int] = []
            if self.allocator is not None:
                nb = kv_pool.blocks_for(len(req.prompt), self.block_size)
                got = self.allocator.alloc(nb)
                if got is None:
                    break  # pool full: wait for evictions, don't preempt
                blocks = got
            self._queue.remove(req)
            done = self._admit(req, free[0], blocks)
            if done is not None:
                finished.append(done)
        return finished

    def _bucket_len(self, s: int) -> int:
        """Smallest power of two >= s, capped at the slot capacity."""
        b = 1
        while b < s:
            b <<= 1
        return min(b, self.max_len)

    def _admission_prefill(self, req: Request):
        """Batch-1 prefill for admission.  Bucketed where parity-safe (one
        trace per power-of-two length bucket); exact-length otherwise."""
        if self._prefill_bucketed is not None:
            s = len(req.prompt)
            padded = np.zeros((self._bucket_len(s),), np.int32)
            padded[:s] = req.prompt
            return self._prefill_bucketed(
                self.params,
                {"tokens": jnp.asarray(padded[None])},
                jnp.asarray(s, jnp.int32),
                jax.random.PRNGKey(req.seed),
            )
        return self._prefill(
            self.params,
            {"tokens": jnp.asarray(req.prompt[None])},
            jnp.asarray(0, jnp.int32),
            jax.random.PRNGKey(req.seed),
        )

    def _admit(
        self, req: Request, slot: int, blocks: list[int]
    ) -> Optional[FinishedRequest]:
        tok0_d, small, pos0, key = self._admission_prefill(req)
        tok0 = int(self._fetch(tok0_d)[0])  # one scalar per admission
        now = self.now()
        if tok0 in self._stop_set or req.max_new_tokens == 1:
            reason = "stop" if tok0 in self._stop_set else "length"
            if blocks:
                self.allocator.free(blocks)
            return FinishedRequest(
                req.uid, np.asarray([tok0], np.int32), reason,
                len(req.prompt), req.arrival, now, now,
            )
        table_row = self._table_row(blocks)
        nb = len(blocks)
        if nb not in self._install_fns:
            self._install_fns[nb] = jax.jit(
                _make_install_fn(self.cfg, nb), donate_argnums=(0,)
            )
        self._caches = self._install_fns[nb](
            self._caches, small, jnp.asarray(slot), table_row
        )
        self._state = self._admit_jit(
            self._state, jnp.asarray(slot), tok0_d[0], key, pos0,
            jnp.asarray(req.max_new_tokens, jnp.int32),
        )
        self._slots[slot] = RequestState(
            request=req, slot=slot, blocks=blocks, tokens=[tok0],
            n_generated=1, admitted_at=now,
        )
        return None

    def _table_row(self, blocks: list[int]) -> Array:
        row = np.zeros((self.max_blocks,), np.int32)
        row[: len(blocks)] = blocks
        return jnp.asarray(row)

    def _ensure_blocks(self) -> None:
        """Grow each live slot's block list to cover the coming chunk,
        preempting the youngest request if the pool runs dry."""
        for rs in sorted(self._live(), key=lambda r: r.admitted_at):
            if self._slots[rs.slot] is not rs:
                continue  # preempted by an earlier iteration of this loop
            total_cap = len(rs.request.prompt) + rs.request.max_new_tokens
            need = kv_pool.blocks_for(
                min(rs.pos + self.chunk, total_cap), self.block_size
            )
            while need > len(rs.blocks):
                got = self.allocator.alloc(need - len(rs.blocks))
                if got is None:
                    victim = self._pick_victim()
                    if victim is None:
                        raise RuntimeError(
                            "KV pool exhausted and nothing to preempt — "
                            "pool too small for the admitted working set"
                        )
                    self._preempt(victim)
                    if victim is rs:
                        break  # the requester itself was youngest: requeued
                    continue
                rs.blocks.extend(got)
                self._caches = self._set_tables(
                    self._caches, jnp.asarray(rs.slot),
                    self._table_row(rs.blocks),
                )

    def _pick_victim(self):
        """Youngest live request — including the one asking for blocks:
        preempting the youngest always discards the least progress, and it
        guarantees the oldest request keeps advancing (a lone request
        always fits the pool by the submit-time check, so the scheduler
        cannot livelock)."""
        live = self._live()
        return max(live, key=lambda r: r.admitted_at) if live else None

    def _preempt(self, rs: RequestState) -> None:
        """Return a request to the queue head; its blocks are reclaimed and
        it restarts from scratch on re-admission (same seed -> same token
        stream, so preemption is invisible in the output)."""
        self.preemptions += 1
        self._state = self._deactivate_jit(
            self._state, jnp.asarray(rs.slot)
        )
        if rs.blocks:
            self.allocator.free(rs.blocks)
        self._slots[rs.slot] = None
        self._queue.insert(0, rs.request)

    def _run_chunk(self):
        packed, self._caches, self._state = self._chunk_fn(
            self.params, self._caches, self._state
        )
        return packed

    def _process_chunk(self, packed: np.ndarray) -> list[FinishedRequest]:
        """Mirror the device's per-step lifecycle over the fetched token
        matrix, then evict finished slots and reclaim their blocks."""
        steps = packed.shape[1] - 1
        for step in range(steps):
            for rs in self._live():
                if rs.done:
                    continue
                tok = int(packed[rs.slot, step])
                rs.tokens.append(tok)
                rs.n_generated += 1
                if tok in self._stop_set:
                    rs.done, rs.finish_reason = True, "stop"
                elif rs.n_generated >= rs.request.max_new_tokens:
                    rs.done, rs.finish_reason = True, "length"
        device_active = packed[:, -1].astype(bool)
        finished = []
        now = self.now()
        for rs in self._live():
            if bool(device_active[rs.slot]) != (not rs.done):
                raise AssertionError(
                    f"slot {rs.slot}: device active mask disagrees with "
                    "the host lifecycle mirror"
                )
            if not rs.done:
                continue
            if rs.blocks:
                self.allocator.free(rs.blocks)
            self._slots[rs.slot] = None
            req = rs.request
            finished.append(
                FinishedRequest(
                    req.uid, np.asarray(rs.tokens, np.int32),
                    rs.finish_reason, len(req.prompt), req.arrival,
                    rs.admitted_at, now,
                )
            )
        return finished
