"""Continuous-batching scheduler: per-request lifecycle over a shared slot
batch and paged KV pool.

``DecodeEngine`` (the lockstep tier) decodes a fixed batch in lockstep:
every request burns the full token budget, and a new request waits for the
whole batch to drain.  ``ContinuousBatchingEngine`` keeps the same compiled
decode program (fixed ``num_slots``-wide batch, ``lax.scan`` chunks,
on-device sampling) but gives every slot its own lifecycle:

* **admission** — with ``prefill_chunk`` set (token-budget chunked
  prefill, Sarathi-style), a queued request only *occupies* a free slot;
  its prompt then streams into the shared caches as fixed-size
  ``forward_chunk`` slices — at most one slice per engine step, written
  directly into pool pages (``kv_pool.write_span``) or dense rows — so a
  long prompt stalls the decode cadence for at most one slice at a time.
  The slice completing the prompt samples the first token with the
  one-shot key-split order, and ONE program is compiled per (budget,
  layout) — ragged final slices are padded and masked, never retraced.
  Configs where slicing would change streams fall back
  (:func:`_chunked_prefill_safe`) to the one-shot path: batch-1 prefill,
  KV prefix installed into the slot.  There, where parity allows
  (:func:`_bucketed_prefill_safe`), the prompt is right-padded to a
  power-of-two bucket so one compiled trace serves every length in the
  bucket; remaining configs retrace per distinct length.
* **decode** — one compiled chunk advances all slots together; per-slot
  positions, EOS/stop-token hits and ``max_new_tokens`` budgets are
  tracked as on-device masks, and finished slots produce **no cache
  writes** (that is what makes reclaiming their blocks safe).
* **eviction** — at the chunk boundary finished requests leave their slot,
  their block references return to the allocator, and the next queued
  request is admitted into the hole.
* **prefix caching** (``prefix_cache=True``, paged layout) — full prompt
  blocks carry a content identity (chain hash of ``(parent_hash,
  block_tokens)`` over the HOST token stream — mesh-shape-independent by
  construction) registered in the allocator once their pages are fully
  written.  Admission walks the prompt's chain through the hash index and
  reuses every leading hit by bumping its refcount; only the unshared
  suffix is prefilled (one padded ``forward_chunk`` slice on the one-shot
  path, or chunked-prefill slices starting at the cached boundary), so a
  cache-hit request's TTFT collapses to its suffix.  Release paths unref:
  a refcount-0 registered block parks on an LRU — still hittable — until
  ``alloc`` evicts it; a fully-cached prompt copies-on-write its final
  hit block before recomputing the last prompt position, so shared pages
  are never mutated.  On release the chain extends over generated tokens,
  so multi-turn follow-ups hit the whole previous conversation.  Streams
  stay bit-for-bit the cold path's (same key-split order, and
  suffix-resume is exactly the chunked-prefill parity property).

Determinism contract: each request carries its own seed, and admission
prefill (one-shot, bucketed or chunked) + per-slot key-splitting reproduce
``DecodeEngine``'s exact key-split order for a batch-1 call.  A request's
token stream is therefore identical to
``DecodeEngine.generate(prompt[None], scfg, seed=seed)`` up to stop-token
truncation — the parity tests assert this bit-for-bit, for both the dense
and paged cache layouts, with and without chunked prefill.

Host-transfer hygiene: one fetch of the packed ``(B, chunk+2)`` token
matrix per decode chunk (the trailing columns are the device's post-chunk
active mask, cross-checked against the host mirror, and the per-slot
quarantine step of the NaN/Inf logit-validity mask), plus one packed
``[token, valid]`` fetch per admission (the prefill-sampled first token).
``host_transfers`` counts them.

Robustness contract (the failure story every later scale PR inherits):

* **request lifecycle** — queued -> prefilling -> decoding ->
  finished(reason), with ``finish_reason`` one of :data:`FINISH_REASONS`;
  every submitted request finishes exactly once.
* **deadlines** — per-request wall-clock ``deadline`` and ``ttft_budget``
  are enforced at chunk boundaries: expired requests are evicted with
  reason ``"deadline"`` (partial tokens kept — a prefix of the fault-free
  stream) and their blocks reclaimed, including mid-chunked-prefill.
* **load shedding** — ``max_queue`` bounds the admission queue;
  ``overload_policy`` picks who is shed (``"reject"`` drops the new
  request, ``"shed_oldest"`` drops the head of the queue) with reason
  ``"shed"``; ``submit`` raises :class:`InadmissibleRequest` for requests
  that can *never* fit instead of deferring the failure to a later stall.
* **NaN/Inf quarantine** — a per-slot logit-validity mask rides the
  existing per-chunk transfer; a slot whose logits go non-finite is
  quarantined and finished with reason ``"error"`` while every other
  stream stays bit-for-bit the fault-free run.
* **watchdog** — a run that stops making progress while work is ready
  raises a diagnosable :class:`SchedulerStall` instead of spinning.
* **fault injection** — a :class:`repro.serve.faults.FaultInjector` can
  deterministically force allocator failures, preemptions, poisoned
  logits and delayed arrivals through no-op-by-default hooks; disabled,
  the compiled programs are byte-identical to the fault-free build.
"""

from __future__ import annotations

import collections
import dataclasses
import logging
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models import api
from repro.models.transformer import build_segments
from repro.serve import kv_pool
from repro.serve.engine import (
    SamplerConfig,
    _hit_stop,
    _make_bucketed_prefill_fn,
    _make_checked_prefill_fn,
    place_params,
    sample_token,
    serving_overrides,
)
from repro.serve.faults import FaultInjector
from repro.serve.metrics import MetricsRegistry, resolve_clock
from repro.serve.tracing import RequestTracer, annotate, maybe_profile

Array = jax.Array

_log = logging.getLogger(__name__)

#: The finish-reason taxonomy.  ``stop`` — stop token; ``length`` — token
#: budget exhausted; ``deadline`` — deadline / TTFT budget expired (queued
#: or live); ``shed`` — dropped by the bounded-queue overload policy;
#: ``rejected`` — dead on arrival at submit (deadline already unmeetable);
#: ``error`` — NaN/Inf logit quarantine.
FINISH_REASONS = frozenset(
    {"stop", "length", "deadline", "shed", "rejected", "error"}
)


class InadmissibleRequest(ValueError):
    """A request that can never be served: prompt + budget exceed the slot
    capacity, or its blocks exceed the whole pool.  Raised by ``submit``
    so impossibility surfaces at the API boundary, not as a later
    scheduler stall."""


class SchedulerStall(RuntimeError):
    """The engine stopped making progress while work was ready (or the
    pool was exhausted with nothing to preempt).  The message carries the
    queue depth, live-slot lifecycle and allocator state so the stall is
    diagnosable from the exception alone."""

# configs whose chunked-prefill decline has already been reported: the
# fallback is a per-config property, so it is logged once per config —
# not once per engine build, and certainly not once per admitted request
_CHUNK_DECLINE_LOGGED: set[tuple] = set()


def _chunk_decline_key(cfg: ModelConfig) -> tuple:
    """The config identity :func:`_chunked_prefill_safe` actually decides
    on — two configs that gate identically share one log line."""
    return (
        cfg.name,
        cfg.family,
        bool(cfg.moe),
        cfg.quant.num_experts,
        cfg.n_image_tokens,
        tuple(
            spec.mixer
            for seg in build_segments(cfg)
            for spec in seg.blocks
        ),
    )


def _log_chunked_prefill_decline(cfg: ModelConfig) -> None:
    key = _chunk_decline_key(cfg)
    if key in _CHUNK_DECLINE_LOGGED:
        return
    _CHUNK_DECLINE_LOGGED.add(key)
    _log.warning(
        "config %r: chunked admission prefill declined (recurrent mixer / "
        "MoE / routed branches / VLM prefix would change streams across "
        "slice boundaries); falling back to one-shot admission prefill",
        cfg.name,
    )


# ---------------------------------------------------------------------------
# Request lifecycle records
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request.  ``seed`` makes the stream reproducible and
    independent of scheduling; ``arrival``, ``deadline`` (absolute) and
    ``ttft_budget`` (relative to arrival) are in the engine's clock units
    (chunk ticks under the default virtual clock, seconds with a real
    one).  ``None`` deadlines never expire."""

    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    seed: int = 0
    arrival: float = 0.0
    deadline: Optional[float] = None
    ttft_budget: Optional[float] = None


@dataclasses.dataclass
class RequestState:
    """Host mirror of an admitted request (the device holds the arrays).

    Under chunked prefill a request occupies its slot while its prompt
    still streams in: ``prefilled`` counts prompt tokens already resident
    in the cache, and ``n_generated == 0`` marks the slot as admitting
    (inactive in decode chunks) until the final slice samples the first
    token.

    With prefix caching, ``block_hashes`` holds the chain hashes of the
    stream's full blocks (prompt blocks at admission, extended over
    generated tokens at release) and ``registered`` counts the leading
    blocks already present in the allocator's hash index — admission hits
    plus blocks registered once their pages were fully written."""

    request: Request
    slot: int
    blocks: list[int]
    tokens: list[int]
    n_generated: int
    admitted_at: float
    prefilled: int = 0
    first_token_at: float = 0.0
    done: bool = False
    finish_reason: str = ""
    block_hashes: list[int] = dataclasses.field(default_factory=list)
    registered: int = 0

    @property
    def pos(self) -> int:
        """Next write position = prompt_len + generated so far."""
        return len(self.request.prompt) + self.n_generated


@dataclasses.dataclass(frozen=True)
class FinishedRequest:
    uid: int
    tokens: np.ndarray  # (n,) int32, n <= max_new_tokens
    finish_reason: str  # one of FINISH_REASONS
    prompt_len: int
    arrival: float
    admitted_at: float
    # when the first token was sampled (TTFT anchor); for zero-token
    # finishes (shed / rejected / deadline-in-queue / prefill quarantine)
    # it equals finished_at
    first_token_at: float
    finished_at: float


# ---------------------------------------------------------------------------
# Compiled pieces
# ---------------------------------------------------------------------------


def _walk_blocks(cfg: ModelConfig):
    """(segment index, block key, spec, stacked) for every cache dict in
    the tree that :func:`repro.models.api.init_cache` builds."""
    for si, seg in enumerate(build_segments(cfg)):
        for bi, spec in enumerate(seg.blocks):
            yield si, f"b{bi}", spec, seg.repeats > 1


def _map_blocks(cfg: ModelConfig, fn, *trees):
    """Apply ``fn(spec, stacked, *block_dicts)`` over parallel cache trees."""
    out = []
    for si, key, spec, stacked in _walk_blocks(cfg):
        while len(out) <= si:
            out.append({})
        out[si][key] = fn(spec, stacked, *(t[si][key] for t in trees))
    return out


def _row_set(big: Array, small: Array, slot: Array, stacked: bool) -> Array:
    """big[..., slot, ...] = small[..., 0, ...] along the batch axis (index
    1 on layer-stacked leaves, 0 otherwise)."""
    ax = 1 if stacked else 0
    idx = (slice(None),) * ax + (slot,)
    return big.at[idx].set(jnp.take(small, 0, axis=ax).astype(big.dtype))


def _make_install_fn(cfg: ModelConfig, nb: int):
    """Install a batch-1 prefill cache into slot ``slot`` of the big cache
    tree.  ``nb`` (static) is the number of prompt-covering pages for
    paged layers — their dense prefill rows land in the pool through the
    same ``kv_pool.write_span`` span scatter chunked prefill writes with
    (one write path, no separate page-install primitive); dense leaves
    copy the whole row."""

    def install(big, small, slot, table_row):
        def blockfn(spec, stacked, bigc, smallc):
            if "table" in bigc:
                start = jnp.zeros((1,), jnp.int32)

                def scatter(pool, dense):
                    # dense: (1, L, H, D) — the slot's prefilled cache;
                    # span-write exactly its nb prompt-covering pages (the
                    # static slice keeps the scatter O(nb * bs), not
                    # O(max_len))
                    bs = pool.shape[1]
                    return kv_pool.write_span(
                        pool, table_row[None], start, dense[:, : nb * bs]
                    )

                if stacked:
                    scatter = jax.vmap(scatter)
                ax = 1 if stacked else 0
                idx = (slice(None),) * ax + (slot,)
                return {
                    "kpool": scatter(bigc["kpool"], smallc["k"]),
                    "vpool": scatter(bigc["vpool"], smallc["v"]),
                    "table": bigc["table"].at[idx].set(table_row),
                }
            return jax.tree.map(
                lambda b, s: _row_set(b, s, slot, stacked), bigc, smallc
            )

        return _map_blocks(cfg, blockfn, big, small)

    return install


def _make_copy_block_fn(cfg: ModelConfig):
    """Copy one pool page (``src`` -> ``dst``) in every paged layer's K and
    V pools — the engine-level copy-on-write primitive.  A slot about to
    write inside a *shared* block (the fully-cached-prompt case recomputes
    the last prompt position, which lives in the final hit block) first
    duplicates that page into a private block and repoints its table row,
    so a registered page is never mutated while other slots may read it."""

    def copy(big, src, dst):
        def blockfn(spec, stacked, bigc):
            if "table" not in bigc:
                return bigc

            def cp(pool):
                return kv_pool.copy_block(pool, src, dst)

            if stacked:
                cp = jax.vmap(cp)
            return dict(bigc, kpool=cp(bigc["kpool"]), vpool=cp(bigc["vpool"]))

        return _map_blocks(cfg, blockfn, big)

    return copy


def _make_set_tables_fn(cfg: ModelConfig):
    """Rewrite one slot's block-table row in every paged layer (block
    extension at a chunk boundary)."""

    def set_tables(big, slot, table_row):
        def blockfn(spec, stacked, bigc):
            if "table" not in bigc:
                return bigc
            ax = 1 if stacked else 0
            idx = (slice(None),) * ax + (slot,)
            return dict(bigc, table=bigc["table"].at[idx].set(table_row))

        return _map_blocks(cfg, blockfn, big)

    return set_tables


def _make_cb_chunk_fn(cfg: ModelConfig, scfg: SamplerConfig, length: int,
                      poison: bool = False):
    """``length`` decode steps over the slot batch with per-slot positions,
    keys, budgets and stop masks.  Returns (packed (B, length+2), caches,
    state) — the packed matrix's last two columns are the post-chunk
    active mask and the per-slot quarantine step, riding the chunk's
    single device->host transfer.

    NaN/Inf quarantine: each step's (B,) logit-validity mask
    (``isfinite`` over the vocab axis — a cheap reduction of logits the
    step already materialized, no extra sync) gates sampling exactly like
    the active mask, so a slot whose logits go non-finite emits no
    garbage token, writes nothing further, and carries the offending step
    index home in the quarantine column (``length`` = untouched).  For
    finite logits every ``where`` picks the same operand as before the
    mask existed — the fault-free program is bitwise unchanged, which is
    what keeps unaffected streams bit-for-bit under quarantine.

    With ``poison=True`` the chunk takes an extra ``(B,) int32`` operand
    naming the scan step at which each slot's logits are overwritten with
    NaN (-1 = never) — the fault-injection variant, compiled lazily and
    ONLY when a FaultInjector schedules a poison, so the disabled path
    runs the exact program it always did.

    Per-slot sampling vmaps the batch-1 sampler over (key, logits-row)
    pairs, which is bit-for-bit what ``DecodeEngine`` computes for a
    batch-1 call with that key — the determinism contract of the module
    docstring."""

    def chunk(params, caches, state, poison_step=None):
        def step(carry, i):
            caches, st = carry
            split = jax.vmap(jax.random.split)(st["key"])  # (B, 2, 2)
            new_key, sub = split[:, 0], split[:, 1]
            with annotate("serve/decode_step"):
                logits, caches = api.decode_step(
                    params, st["tok"][:, None], caches, st["pos"], cfg,
                    active=st["active"],
                )
            logits = logits[:, -1]  # (B, V)
            if poison:
                logits = jnp.where(
                    (poison_step == i)[:, None],
                    jnp.full_like(logits, jnp.nan),
                    logits,
                )
            finite = jnp.isfinite(logits).all(axis=-1)  # (B,)
            ok = st["active"] & finite
            with annotate("serve/sample"):
                nxt = jax.vmap(
                    lambda s, l: sample_token(s, l[None], scfg)[0]
                )(sub, logits)
            nxt = jnp.where(ok, nxt, st["tok"])
            act = ok.astype(jnp.int32)
            ngen = st["ngen"] + act
            alive = (
                ok
                & ~_hit_stop(nxt, scfg)
                & (ngen < st["budget"])
            )
            quar = jnp.where(
                st["active"] & ~finite & (st["quar"] == length),
                i, st["quar"],
            )
            st = {
                "tok": nxt,
                "pos": st["pos"] + act,
                "key": new_key,
                "active": alive,
                "ngen": ngen,
                "budget": st["budget"],
                "quar": quar,
            }
            return (caches, st), nxt

        st0 = dict(
            state, quar=jnp.full(state["tok"].shape, length, jnp.int32)
        )
        (caches, st), toks = jax.lax.scan(
            step, (caches, st0), jnp.arange(length, dtype=jnp.int32)
        )
        toks = jnp.moveaxis(toks, 0, 1)  # (B, length)
        quar = st.pop("quar")
        packed = jnp.concatenate(
            [toks, st["active"][:, None].astype(toks.dtype),
             quar[:, None]], axis=1,
        )
        return packed, caches, st

    return chunk


def _make_prefill_chunk_fn(cfg: ModelConfig, scfg: SamplerConfig, t: int):
    """One admission-prefill slice: ``t`` prompt tokens for (at most) one
    admitting slot, written straight into the BIG cache tree — dense rows
    or pool pages (``kv_pool.write_span``) — with every other slot masked
    out.  Because ragged final slices are right-padded to ``t`` and gated
    by ``lengths``, ONE compiled program serves every prompt length: the
    trace count is per (budget, layout), not per prompt.

    Sampling reproduces ``_prefill_sample``'s key-split order on the
    admitting slot's row (split after prefill, batch-1 sampler), so the
    first token — and with it the whole stream — is bit-for-bit the
    lockstep engine's.  The sampled token and split key are computed every
    slice but only the slice that completes the prompt is read back by the
    host (one packed ``[token, valid]`` fetch per admission — the
    logit-validity bit rides the same transfer, so prefill quarantine
    costs no extra sync; same budget as one-shot admission).
    """

    def pchunk(params, caches, tokens, pos, active, lengths, slot, key):
        assert tokens.shape[1] == t, "slices must be padded to the budget"
        with annotate("serve/prefill_forward"):
            logits, caches = api.forward_chunk(
                params, tokens, caches, pos, cfg, active=active,
                lengths=lengths, logits_at=jnp.maximum(lengths - 1, 0),
            )
        row = jnp.take(logits, slot, axis=0)
        key, sub = jax.random.split(key)
        tok0 = sample_token(sub, row[None], scfg)[0]
        ok = jnp.isfinite(row).all().astype(jnp.int32)
        return jnp.stack([tok0, ok]), caches, key

    return pchunk


def _chunked_prefill_safe(cfg: ModelConfig) -> bool:
    """Whether admission prefill may be split into fixed-budget slices
    without changing any request's stream.

    Safe exactly when slicing a prompt across ``forward_chunk`` calls is
    invisible: attention mixers (incl. ring-cache sliding-window layers —
    their in-chunk path is already sequential per token, so slice
    boundaries change nothing).  Unsafe, falling back to one-shot
    admission prefill:

    * ssm / rec mixers: the chunk recurrences (SSD chunking, associative
      scan) re-associate float accumulation across slice boundaries;
    * MoE / routed 8-bit branches: Switch-style capacity couples the
      tokens of a slice, so slice size changes real tokens' routing;
    * VLM image prefixes (position offsets are caller-managed).
    """
    if cfg.moe or cfg.quant.num_experts > 1 or cfg.n_image_tokens > 0:
        return False
    for seg in build_segments(cfg):
        for spec in seg.blocks:
            if spec.mixer not in ("attn", "mla"):
                return False
    return True


def _prefix_cache_safe(cfg: ModelConfig) -> bool:
    """Whether shared prompt blocks may be reused across requests without
    changing any request's stream.

    Safe exactly when the *paged pool holds the whole recurrent state of a
    prefix*: every mixer is pure global attention (``window == 0``), so
    reusing the hit blocks and running only the unshared suffix is
    bitwise the full prefill (the chunked-prefill parity property, with
    the prefix slices computed by an earlier request).  Unsafe, declining
    to one-shot cold admission:

    * sliding-window / ssm / rec / MLA mixers: their dense ring or latent
      caches are per-slot — a reused pool block would leave that state
      unpopulated for the hitting slot;
    * MoE / routed branches / VLM prefixes: same coupling that makes
      slicing unsafe (:func:`_chunked_prefill_safe`).
    """
    if cfg.moe or cfg.quant.num_experts > 1 or cfg.n_image_tokens > 0:
        return False
    for seg in build_segments(cfg):
        for spec in seg.blocks:
            if spec.mixer != "attn" or spec.window != 0:
                return False
    return True


_PREFIX_DECLINE_LOGGED: set[tuple] = set()


def _log_prefix_cache_decline(cfg: ModelConfig) -> None:
    key = _chunk_decline_key(cfg) + tuple(
        spec.window for seg in build_segments(cfg) for spec in seg.blocks
    )
    if key in _PREFIX_DECLINE_LOGGED:
        return
    _PREFIX_DECLINE_LOGGED.add(key)
    _log.warning(
        "config %r: prefix caching declined (a mixer keeps per-slot state "
        "outside the paged pool, or routing couples tokens); admissions "
        "run cold",
        cfg.name,
    )


def _bucketed_prefill_safe(cfg: ModelConfig, max_len: int) -> bool:
    """Whether admission prefill may right-pad prompts to a shared bucket
    length without changing any request's stream.

    Safe exactly when pad tokens cannot leak into real positions: causal
    attention confines them to cache slots the decode mask gates until the
    real stream overwrites them.  Unsafe cases fall back to exact-length
    prefill (one retrace per distinct length, the pre-bucketing behavior):

    * ring caches (``window < max_len``): prefill keeps the last W
      positions of the *padded* sequence, evicting real tokens;
    * ssm / rec mixers: the recurrent state integrates the pad suffix;
    * MoE / routed 8-bit branches: Switch-style capacity couples tokens,
      so the pad tokens change real tokens' routing;
    * VLM image prefixes (position offsets are caller-managed).
    """
    if cfg.moe or cfg.quant.num_experts > 1 or cfg.n_image_tokens > 0:
        return False
    for seg in build_segments(cfg):
        for spec in seg.blocks:
            if spec.mixer not in ("attn", "mla"):
                return False
            if 0 < spec.window < max_len:
                return False
    return True


def _admit_state(state, slot, tok0, key, pos0, budget):
    """Write one slot's device-side lifecycle state (ngen starts at 1: the
    prefill-sampled first token is emitted at admission)."""
    return {
        "tok": state["tok"].at[slot].set(tok0),
        "pos": state["pos"].at[slot].set(pos0),
        "key": state["key"].at[slot].set(key),
        "active": state["active"].at[slot].set(True),
        "ngen": state["ngen"].at[slot].set(1),
        "budget": state["budget"].at[slot].set(budget),
    }


def _deactivate(state, slot):
    return dict(state, active=state["active"].at[slot].set(False))


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


def _tile_cache_stats() -> dict:
    """Snapshot collector: kernel autotune-cache hit/miss/sweep stats
    (process-wide — they live with the cache, not the engine).  Deferred
    import keeps the scheduler importable without the kernel tier."""
    from repro.kernels import tile_cache

    return {f"tile_cache_{k}": v for k, v in tile_cache.stats().items()}


class ContinuousBatchingEngine:
    """Serving tier 3: request queue + slot admission/eviction over one
    compiled fixed-width decode program (see module docstring).

    Parameters
    ----------
    num_slots : compiled batch width — concurrent in-flight requests.
    max_len : per-slot sequence capacity (prompt + generated).
    scfg : engine-level sampling signature (temperature / top_k /
        stop_tokens).  Per-request knobs are ``max_new_tokens`` and
        ``seed``; the sampler signature is baked into the compiled program.
    layout : "paged" (global-attention KV in a shared block pool) or
        "dense" (per-slot buffers).  Interchangeable — same token streams.
    num_blocks : pool size per paged layer; defaults to full occupancy
        (``num_slots * max_len / block_size``).  Smaller pools admit fewer
        long requests at once; if blocks run out mid-flight the youngest
        request is preempted back to the queue (restart-from-scratch is
        deterministic, so its stream is unchanged).
    prefill_chunk : token budget per engine step for admission prefill
        (Sarathi-style chunked prefill).  ``None`` (default) admits with
        one-shot prefill; an int splits each admitting prompt into
        fixed-size ``forward_chunk`` slices written straight into the
        shared caches (``kv_pool.write_span`` under the paged layout), at
        most one slice per step, so a long prompt never stalls the decode
        cadence for more than one slice.  ONE program is compiled per
        (budget, layout) — slices are padded+masked, never retraced per
        prompt length.  Configs where slicing would change streams
        (recurrent mixers, MoE/routed branches, VLM prefixes — see
        :func:`_chunked_prefill_safe`) fall back to one-shot admission.
    prefix_cache : enable automatic prefix caching (paged layout only —
        requesting it with ``layout="dense"`` raises).  Each full prompt
        block gets a content identity — the chain hash of
        ``(parent_hash, block_tokens)`` over the HOST token stream, so
        hits are mesh-shape-independent by construction — and admission
        walks the prompt's block chain through the allocator's hash
        index: every leading hit is reused by bumping its refcount, and
        only the unshared suffix is prefilled (one padded
        ``forward_chunk`` slice on the one-shot path; chunked prefill
        simply starts its slices at the cached boundary), collapsing
        TTFT for cache-hit requests.  Release paths unref instead of
        freeing — a refcount-0 block with registered content parks on the
        allocator's LRU, still hittable, until ``alloc`` reclaims it.  A
        fully-cached prompt copies-on-write its final hit block before
        recomputing the last prompt position, so a shared page is never
        mutated.  Streams are bit-for-bit the cold path's (the
        chunked-prefill parity property — which is also why configs
        failing :func:`_prefix_cache_safe` decline with a log and run
        cold).  Hit/miss/CoW/eviction land on the
        ``prefix_cache_*_total`` counters and the request trace.
    clock : optional clock — a bare callable returning seconds, or an
        object with ``now()`` and optionally ``sleep(dt)`` (see
        :func:`repro.serve.metrics.resolve_clock`;
        :class:`~repro.serve.metrics.ManualClock` drives tests without
        real sleeping).  By default a virtual clock advances one tick per
        decode chunk and ``Request.arrival`` is in ticks.  Deadline math,
        trace timestamps and the latency histograms all read this one
        clock.
    metrics : optional :class:`repro.serve.metrics.MetricsRegistry` to
        record into (share one across engines / export to Prometheus);
        ``None`` creates a private registry — instrumentation is always
        host-side-only, so this can never change a compiled program.
    tracer : optional :class:`repro.serve.tracing.RequestTracer`; when set
        every request's lifecycle (submitted -> admitted -> prefill ->
        first_token -> decode -> finished(reason)), block alloc/free,
        preemptions and fired faults are emitted as structured events on
        the engine clock.  May also be attached later (``eng.tracer =
        ...``) — benches attach after warm-up.
    max_queue : bound on the admission queue (``None`` = unbounded).  A
        submit into a full queue invokes ``overload_policy`` and the loser
        finishes with reason ``"shed"`` — backpressure is explicit, not an
        unbounded list.
    overload_policy : ``"reject"`` sheds the newly submitted request;
        ``"shed_oldest"`` sheds the head of the queue and admits the new
        one (freshest-work-wins).
    watchdog_steps : consecutive no-progress steps (while work is ready)
        tolerated before ``step`` raises :class:`SchedulerStall`.
    faults : optional :class:`repro.serve.faults.FaultInjector`.  ``None``
        (default) compiles and runs exactly the fault-free programs.
    """

    def __init__(
        self,
        params,
        cfg: ModelConfig,
        num_slots: int,
        max_len: int,
        scfg: Optional[SamplerConfig] = None,
        *,
        layout: str = "paged",
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        chunk: int = 8,
        prefill_chunk: Optional[int] = None,
        prefix_cache: bool = False,
        clock: Optional[Callable[[], float]] = None,
        max_queue: Optional[int] = None,
        overload_policy: str = "reject",
        watchdog_steps: int = 256,
        faults: Optional[FaultInjector] = None,
        metrics: Optional[MetricsRegistry] = None,
        tracer: Optional[RequestTracer] = None,
        mesh=None,
        param_axes=None,
        mesh_overrides: Optional[dict] = None,
    ):
        if cfg.family == "encdec":
            raise NotImplementedError("continuous batching is decoder-only")
        if layout not in ("dense", "paged"):
            raise ValueError(f"unknown cache layout {layout!r}")
        if layout == "paged" and max_len % block_size:
            raise ValueError("max_len must be a multiple of block_size")
        if overload_policy not in ("reject", "shed_oldest"):
            raise ValueError(f"unknown overload policy {overload_policy!r}")
        if prefix_cache and layout != "paged":
            raise ValueError(
                "prefix_cache requires the paged layout (content-hash "
                "identity lives on pool blocks)"
            )
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        # tensor-parallel serving: params go down N-major over the model
        # axis and every compiled program below is traced inside the
        # serving sharding rules (see serve/__init__.py §sharded serving).
        # The host-side scheduler/queue/fault/metrics layers are untouched
        # — they only ever see fetched numpy and per-slot python state.
        self.mesh = mesh
        self._overrides = (
            serving_overrides(cfg, mesh, mesh_overrides)
            if mesh is not None else None
        )
        if mesh is not None:
            params = place_params(params, cfg, mesh, self._overrides,
                                  param_axes)
        self.params, self.cfg = params, cfg
        self.num_slots, self.max_len = num_slots, max_len
        self.scfg = scfg or SamplerConfig()
        self.layout, self.block_size, self.chunk = layout, block_size, chunk
        self.max_blocks = kv_pool.blocks_for(max_len, block_size)
        self.num_blocks = num_blocks or num_slots * self.max_blocks
        self.faults = faults
        # observability: every engine owns a registry (attach your own to
        # share one across engines) — ALL instrumentation is host-side
        # Python at chunk boundaries over data already transferred, so a
        # registry/tracer can never change a compiled program (pinned by
        # tests/test_metrics.py's byte-identical-lowering assert).  The
        # legacy counter attributes (shed_requests, queue_peak, ...) are
        # compatibility aliases over registry metrics — see the property
        # block below __init__.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer
        m = self.metrics
        self._m_submitted = m.counter("requests_submitted_total")
        self._m_finished = {
            r: m.counter("requests_finished_total", reason=r)
            for r in sorted(FINISH_REASONS)
        }
        self._m_shed = m.counter("shed_requests_total")
        self._m_rejected = m.counter("rejected_requests_total")
        self._m_deadline = m.counter("deadline_misses_total")
        self._m_quarantined = m.counter("quarantined_total")
        self._m_preempt = m.counter("preemptions_total")
        self._m_restarts = m.counter("restarts_total")
        self._m_admissions = m.counter("admissions_total")
        self._m_tokens = m.counter("tokens_generated_total")
        self._m_prefill_tokens = m.counter("prefill_tokens_total")
        self._m_transfers = m.counter("host_transfers_total")
        self._m_steps = m.counter("engine_steps_total")
        self._m_queue_depth = m.gauge("admission_queue_depth")
        self._m_queue_peak = m.gauge("admission_queue_peak")
        self._m_occupancy = m.gauge("batch_occupancy")
        # mesh shape as gauges (1/1 when serving single-device) so a
        # metrics snapshot records the parallelism it was measured under
        mesh_shape = dict(mesh.shape) if mesh is not None else {}
        m.gauge("mesh_data_parallelism").set(
            float(mesh_shape.get("data", 1)))
        m.gauge("mesh_model_parallelism").set(
            float(mesh_shape.get("model", 1)))
        self._m_ttft = m.histogram("ttft_seconds")
        self._m_itl = m.histogram("itl_seconds")
        self._m_latency = m.histogram("request_latency_seconds")
        # prefix-cache counters are registered unconditionally (zero when
        # caching is off/declined) so every snapshot — and the CI metrics
        # artifact — carries the hit rate schema-stably
        self._m_pc_hits = m.counter("prefix_cache_hits_total")
        self._m_pc_misses = m.counter("prefix_cache_misses_total")
        self._m_pc_hit_tokens = m.counter("prefix_cache_hit_tokens_total")
        self._m_pc_cow = m.counter("prefix_cache_cow_total")
        m.register_collector(_tile_cache_stats)
        self.allocator = (
            kv_pool.BlockAllocator(
                self.num_blocks,
                fail_hook=faults.on_alloc if faults is not None else None,
                metrics=m,
            )
            if layout == "paged" else None
        )
        self._clock, self._sleep = resolve_clock(clock)
        self._now = 0.0  # virtual clock (chunk ticks) when clock is None
        if faults is not None:
            # fired faults land on the request timeline (checked at fire
            # time, so a tracer attached after construction still sees them)
            faults.on_fire = self._on_fault
        self.max_queue, self.overload_policy = max_queue, overload_policy
        self.watchdog_steps = watchdog_steps
        self._admitted_uids: set[int] = set()  # restart detection
        self._stall_steps = 0
        self._step_idx = 0

        self._queue: collections.deque[Request] = collections.deque()
        # zero-token finishes produced outside step() (shed/rejected at
        # submit); drained into the next step's return value so every
        # request still finishes exactly once through the same channel
        self._pending_finished: list[FinishedRequest] = []
        self._slots: list[Optional[RequestState]] = [None] * num_slots
        self._uid_counter = 0  # monotonic: uids never recycle
        self._stop_set = set(int(t) for t in self.scfg.stop_tokens)

        self._caches = self._init_big_caches()
        b = num_slots
        self._state = {
            "tok": jnp.zeros((b,), jnp.int32),
            "pos": jnp.zeros((b,), jnp.int32),
            "key": jnp.zeros((b, 2), jnp.uint32),
            "active": jnp.zeros((b,), bool),
            "ngen": jnp.zeros((b,), jnp.int32),
            "budget": jnp.zeros((b,), jnp.int32),
        }
        if mesh is not None:
            # paged pools shard over KV heads on `model`; tables, dense
            # ring caches, and per-slot slot state replicate with the
            # host-global scheduler
            from jax.sharding import NamedSharding, PartitionSpec

            with self._mesh_ctx():
                self._caches = jax.device_put(
                    self._caches, kv_pool.cache_sharding(self._caches, mesh)
                )
            self._state = jax.device_put(
                self._state, NamedSharding(mesh, PartitionSpec())
            )

        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        # chunked admission prefill: fixed-budget forward_chunk slices into
        # the big caches, one compiled program per (budget, layout).
        # Stream-unsafe configs fall back to one-shot admission below.
        self.prefill_chunk = (
            prefill_chunk if (prefill_chunk is not None
                              and _chunked_prefill_safe(cfg)) else None
        )
        if prefill_chunk is not None and self.prefill_chunk is None:
            _log_chunked_prefill_decline(cfg)
        # automatic prefix caching: paged-only (checked above), and only
        # where block reuse is stream-invisible (_prefix_cache_safe) —
        # requested-but-unsafe configs decline with a log and run cold
        self.prefix_cache = bool(prefix_cache) and _prefix_cache_safe(cfg)
        if prefix_cache and not self.prefix_cache:
            _log_prefix_cache_decline(cfg)
        # cache-hit admission on the one-shot path: one padded
        # forward_chunk slice over the unshared suffix, compiled per
        # power-of-two suffix bucket (same program family — and the same
        # key-split order — as chunked prefill, so streams are bitwise
        # the cold path's)
        self._suffix_fns: dict[int, Callable] = {}
        self._copy_block_fn = (
            jax.jit(_make_copy_block_fn(cfg), donate_argnums=(0,))
            if self.prefix_cache else None
        )
        self._prefill_chunk = (
            jax.jit(
                _make_prefill_chunk_fn(cfg, self.scfg, self.prefill_chunk),
                donate_argnums=(1,),
            )
            if self.prefill_chunk is not None else None
        )
        # one-shot admission: exact-length prefill retraces per prompt
        # length; where parity allows it (_bucketed_prefill_safe),
        # admission right-pads prompts to power-of-two buckets so one
        # trace covers a whole bucket.  Both return packed [tok, valid]
        # so prefill quarantine rides the admission fetch.
        self._prefill = jax.jit(
            _make_checked_prefill_fn(cfg, max_len, self.scfg)
        )
        self._prefill_bucketed = (
            jax.jit(_make_bucketed_prefill_fn(cfg, max_len, self.scfg))
            if _bucketed_prefill_safe(cfg, max_len) else None
        )
        # the cache tree and slot state are donated: the chunk rewrites
        # them in place instead of copying the full KV pool every chunk
        # (the caller rebinds both from the return value)
        self._chunk_fn = jax.jit(
            _make_cb_chunk_fn(cfg, self.scfg, chunk), donate_argnums=(1, 2)
        )
        # fault-injection variant (extra poison-step operand): compiled
        # lazily and only when a FaultInjector schedules a logit poison,
        # so the fault-free build never traces it
        self._chunk_fn_poison: Optional[Callable] = None
        self._install_fns: dict[int, Callable] = {}
        self._set_tables = jax.jit(_make_set_tables_fn(cfg), donate_argnums=(0,))
        self._admit_jit = jax.jit(_admit_state, donate_argnums=(0,))
        self._deactivate_jit = jax.jit(_deactivate, donate_argnums=(0,))

    def _mesh_ctx(self):
        """Serving sharding rules, active around every compiled-fn call
        (jit traces at call time in the calling thread, so the rule table
        must be installed here, not at construction)."""
        import contextlib

        if self.mesh is None:
            return contextlib.nullcontext()
        return shd.sharding_rules(self.mesh, self._overrides)

    # -- observability ------------------------------------------------------
    #
    # Compatibility aliases: the pre-registry counter attributes survive as
    # properties over registry metrics, with setters because benches reset
    # them (``eng.host_transfers = 0``) and tests read them directly.

    def _alias(metric):  # noqa: N805 — descriptor factory, not a method
        def get(self):
            return int(getattr(self, metric).value)

        def set_(self, v):
            getattr(self, metric).value = v

        return property(get, set_)

    shed_requests = _alias("_m_shed")
    rejected_requests = _alias("_m_rejected")
    deadline_misses = _alias("_m_deadline")
    quarantined = _alias("_m_quarantined")
    preemptions = _alias("_m_preempt")
    admissions = _alias("_m_admissions")
    tokens_generated = _alias("_m_tokens")
    prefill_tokens = _alias("_m_prefill_tokens")
    host_transfers = _alias("_m_transfers")
    queue_peak = _alias("_m_queue_peak")
    del _alias

    @property
    def finished_by_reason(self) -> dict[str, int]:
        """Cumulative finished-request totals per ``finish_reason`` — the
        chaos suite's conservation invariant is
        ``sum(finished_by_reason.values()) == submitted``."""
        return {r: int(c.value) for r, c in self._m_finished.items()}

    def snapshot(self) -> dict:
        """The engine's metrics snapshot (see
        :meth:`repro.serve.metrics.MetricsRegistry.snapshot`)."""
        return self.metrics.snapshot()

    def _on_fault(self, kind: str, info: dict) -> None:
        if self.tracer is not None:
            self.tracer.emit(f"fault_{kind}", t=self.now(), **info)

    def _emit_finished(self, fr: FinishedRequest) -> FinishedRequest:
        """The single finish chokepoint: every FinishedRequest — zero-token
        or streamed, any reason — passes through here exactly once, so the
        per-reason totals conserve requests and the latency histograms see
        every finish.  ITL uses the same formula the bench used to compute
        host-side (span / (n - 1)) so engine-sourced rows are comparable."""
        self._m_finished[fr.finish_reason].inc()
        n = len(fr.tokens)
        if n > 0:
            self._m_ttft.observe(max(0.0, fr.first_token_at - fr.arrival))
            self._m_itl.observe(
                max(0.0, fr.finished_at - fr.first_token_at) / max(1, n - 1)
            )
        self._m_latency.observe(max(0.0, fr.finished_at - fr.arrival))
        if self.tracer is not None:
            self.tracer.emit(
                "finished", t=fr.finished_at, uid=fr.uid,
                reason=fr.finish_reason, n_tokens=n,
            )
        return fr

    def _trace(self, event: str, uid: Optional[int] = None, **fields) -> None:
        if self.tracer is not None:
            self.tracer.emit(event, t=self.now(), uid=uid, **fields)

    def _release_blocks(self, blocks: list[int], uid: int) -> None:
        """Drop a request's references on its blocks (the one release
        path, so every reclamation lands on the trace timeline).  With
        prefix caching this is an *unref*: a registered block whose last
        reference drops parks on the allocator's LRU — still hittable —
        instead of being forgotten; shared blocks simply lose one owner."""
        if blocks:
            self.allocator.unref(blocks)
            self._trace("block_free", uid=uid, n_blocks=len(blocks))

    # -- construction -------------------------------------------------------

    def _init_big_caches(self):
        """Big cache tree: shapes from a batch-``num_slots`` init, leaf
        dtypes taken from what prefill actually produces (so installing a
        prefilled row never casts — bit parity with ``DecodeEngine``,
        whose caches come straight out of prefill)."""
        cfg, b = self.cfg, self.num_slots
        dummy = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
        small = jax.eval_shape(
            lambda p, t: api.prefill(p, t, cfg, self.max_len)[1],
            self.params, dummy,
        )

        def blockfn(spec, stacked, smallc):
            ax = 1 if stacked else 0
            if (
                self.layout == "paged"
                and spec.mixer == "attn"
                and spec.window == 0
            ):
                cache, _ = kv_pool.init_paged_attention_cache(
                    b, self.max_len, cfg.n_kv_heads, cfg.head_dim,
                    self.num_blocks, self.block_size, smallc["k"].dtype,
                )
                if stacked:
                    r = smallc["k"].shape[0]
                    cache = jax.tree.map(
                        lambda t: jnp.broadcast_to(t[None], (r,) + t.shape),
                        cache,
                    )
                return cache
            return jax.tree.map(
                lambda l: jnp.zeros(
                    l.shape[:ax] + (b,) + l.shape[ax + 1:], l.dtype
                ),
                smallc,
            )

        return _map_blocks(cfg, blockfn, small)

    # -- host boundary ------------------------------------------------------

    def _fetch(self, x) -> np.ndarray:
        self.host_transfers += 1
        return np.asarray(x)

    def now(self) -> float:
        return self._clock() if self._clock is not None else self._now

    # -- public API ---------------------------------------------------------

    def submit(
        self,
        prompt,
        max_new_tokens: Optional[int] = None,
        seed: int = 0,
        uid: Optional[int] = None,
        arrival: float = 0.0,
        deadline: Optional[float] = None,
        ttft_budget: Optional[float] = None,
    ) -> int:
        """Queue a request; returns its uid.

        Requests that can *never* be served — prompt + budget beyond a
        slot's capacity, or (paged) beyond the whole pool — raise
        :class:`InadmissibleRequest` here instead of deferring the
        impossibility to a later scheduler stall.  A ``deadline`` already
        unmeetable at submit (``deadline <= arrival``, or a non-positive
        ``ttft_budget``) finishes immediately with reason ``"rejected"``;
        a full bounded queue invokes the overload policy and the shed
        request finishes with reason ``"shed"`` (both surface on the next
        ``step``/``run`` — every request finishes exactly once)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        budget = (
            self.scfg.max_new_tokens if max_new_tokens is None
            else max_new_tokens
        )
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if budget < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {budget}")
        total = len(prompt) + budget
        if total > self.max_len:
            raise InadmissibleRequest(
                f"prompt ({len(prompt)}) + budget ({budget}) exceeds the "
                f"slot capacity max_len={self.max_len}"
            )
        if self.allocator is not None:
            need = kv_pool.blocks_for(total, self.block_size)
            if need > self.num_blocks:
                raise InadmissibleRequest(
                    f"request needs {need} blocks but the pool has only "
                    f"{self.num_blocks}"
                )
        if uid is None:
            uid = self._uid_counter
        self._uid_counter = max(self._uid_counter, uid + 1)
        if self.faults is not None:
            arrival += self.faults.arrival_delay(uid)
        req = Request(
            uid, prompt, budget, seed=seed, arrival=arrival,
            deadline=deadline, ttft_budget=ttft_budget,
        )
        # counted only once validation passed: raised requests never enter
        # the lifecycle, so submitted == sum(finished_by_reason) conserves
        self._m_submitted.inc()
        self._trace(
            "submitted", uid=uid, arrival=req.arrival,
            prompt_len=len(prompt),
        )
        if (deadline is not None and deadline <= arrival) or (
            ttft_budget is not None and ttft_budget <= 0
        ):
            self.rejected_requests += 1
            self._pending_finished.append(
                self._finish_unstarted(req, "rejected")
            )
            return uid
        if (
            self.max_queue is not None
            and len(self._queue) >= self.max_queue
        ):
            if self.overload_policy == "reject":
                self.shed_requests += 1
                self._pending_finished.append(
                    self._finish_unstarted(req, "shed")
                )
                return uid
            victim = self._queue.popleft()  # shed_oldest: O(1) on the deque
            self.shed_requests += 1
            self._pending_finished.append(
                self._finish_unstarted(victim, "shed")
            )
        self._queue.append(req)
        self.queue_peak = max(self.queue_peak, len(self._queue))
        self._m_queue_depth.set(len(self._queue))
        return uid

    def _finish_unstarted(
        self, req: Request, reason: str
    ) -> FinishedRequest:
        """A zero-token finish for a request that never reached a slot
        (shed / rejected / deadline while queued / prefill quarantine)."""
        assert reason in FINISH_REASONS, reason
        now = self.now()
        return self._emit_finished(FinishedRequest(
            req.uid, np.zeros((0,), np.int32), reason, len(req.prompt),
            req.arrival, now, now, now,
        ))

    def run(self) -> list[FinishedRequest]:
        """Process the queue to completion; FinishedRequests in completion
        order.  With ``REPRO_PROFILE_DIR`` set the whole run is bracketed
        by ``jax.profiler.start_trace/stop_trace`` (see
        :func:`repro.serve.tracing.maybe_profile`)."""
        finished: list[FinishedRequest] = []
        with maybe_profile("serve_run"):
            while self._queue or self._live() or self._pending_finished:
                finished.extend(self.step())
        return finished

    def step(self) -> list[FinishedRequest]:
        """One scheduling tick, spending one token budget: surface pending
        zero-token finishes, enforce deadlines, apply injected
        preemptions, admit arrived requests, advance at most one admitting
        prompt by one prefill slice (chunked prefill), ensure pool blocks
        for the coming chunk, run one compiled decode chunk for the
        decoding slots, evict finished requests.  Returns the requests
        that finished this tick.

        Watchdog: a step that finishes nothing, generates no token and
        advances no prefill while work is ready (live slots, or an
        arrived queued request) counts toward ``watchdog_steps``;
        exceeding it raises :class:`SchedulerStall` with the full
        scheduler state in the message instead of spinning forever."""
        before = (self.tokens_generated, self.prefill_tokens)
        finished = self._step_body()
        self._step_idx += 1
        self._m_steps.inc()
        self._m_queue_depth.set(len(self._queue))
        self._m_occupancy.set(len(self._live()))
        progressed = bool(finished) or (
            (self.tokens_generated, self.prefill_tokens) != before
        )
        now = self.now()
        work_ready = bool(self._live()) or any(
            r.arrival <= now for r in self._queue
        )
        if progressed or not work_ready:
            self._stall_steps = 0
        else:
            self._stall_steps += 1
            if self._stall_steps >= self.watchdog_steps:
                report = self._stall_report()
                self._trace("stall", steps=self._stall_steps, report=report)
                raise SchedulerStall(report)
        return finished

    def _step_body(self) -> list[FinishedRequest]:
        finished = self._drain_pending()
        finished.extend(self._expire_deadlines())
        self._injected_preemptions()
        finished.extend(self._admit_arrived())
        finished.extend(self._prefill_tick())
        if not any(rs.n_generated > 0 for rs in self._live()):
            if self._live():
                # every occupied slot is still admitting: the slice above
                # was this tick's work
                if self._clock is None:
                    self._now += 1.0
            elif self._queue:
                self._advance_clock()
            return finished
        if self.allocator is not None:
            self._ensure_blocks()
        with annotate("serve/decode_chunk"):
            packed = self._fetch(self._run_chunk())
        if self._clock is None:
            self._now += 1.0
        self._trace(
            "decode_chunk", step=self._step_idx,
            n_decoding=sum(1 for rs in self._live() if rs.n_generated > 0),
        )
        finished.extend(self._process_chunk(packed))
        return finished

    def _drain_pending(self) -> list[FinishedRequest]:
        out, self._pending_finished = self._pending_finished, []
        return out

    def _stall_report(self) -> str:
        live = [
            f"(uid={rs.request.uid} slot={rs.slot} ngen={rs.n_generated} "
            f"prefilled={rs.prefilled}/{len(rs.request.prompt)} "
            f"blocks={len(rs.blocks)})"
            for rs in self._live()
        ]
        alloc = (
            f"{self.allocator.free_count}/{self.num_blocks} blocks free"
            if self.allocator is not None else "dense layout (no allocator)"
        )
        return (
            f"scheduler made no progress for {self._stall_steps} steps "
            f"(step {self._step_idx}, t={self.now():.3f}): queue depth "
            f"{len(self._queue)}, live slots [{', '.join(live) or 'none'}], "
            f"{alloc}, preemptions={self.preemptions}"
        )

    def _injected_preemptions(self) -> None:
        """Apply any FaultInjector-scheduled preemptions for this step
        (chunk boundary) — the same ``_preempt`` path pool pressure
        takes."""
        if self.faults is None:
            return
        for uid in self.faults.preempt_uids(self._step_idx):
            live = self._live()
            if not live:
                return
            rs = (
                max(live, key=lambda r: r.admitted_at) if uid is None
                else next((r for r in live if r.request.uid == uid), None)
            )
            if rs is not None:
                self.faults.fire(
                    "force_preempt", uid=rs.request.uid, step=self._step_idx
                )
                self._preempt(rs)

    def _deadline_missed(self, req: Request, now: float,
                         has_first: bool) -> bool:
        if req.deadline is not None and now > req.deadline:
            return True
        return (
            not has_first
            and req.ttft_budget is not None
            and now > req.arrival + req.ttft_budget
        )

    def _expire_deadlines(self) -> list[FinishedRequest]:
        """Chunk-boundary deadline enforcement: expired queued requests
        finish with zero tokens; expired live requests are evicted with
        their partial stream (a prefix of the fault-free stream — the
        scheduler is deterministic per request) and their blocks
        reclaimed, including slots still mid-chunked-prefill."""
        now = self.now()
        finished: list[FinishedRequest] = []
        if any(r.deadline is not None or r.ttft_budget is not None
               for r in self._queue):
            keep: collections.deque[Request] = collections.deque()
            for r in self._queue:
                if self._deadline_missed(r, now, has_first=False):
                    self.deadline_misses += 1
                    finished.append(self._finish_unstarted(r, "deadline"))
                else:
                    keep.append(r)
            self._queue = keep
        for rs in list(self._live()):
            req = rs.request
            if not self._deadline_missed(req, now, rs.n_generated > 0):
                continue
            self.deadline_misses += 1
            if rs.n_generated > 0:  # admitting slots were never activated
                with self._mesh_ctx():
                    self._state = self._deactivate_jit(
                        self._state, jnp.asarray(rs.slot)
                    )
            self._register_blocks(rs)
            self._release_blocks(rs.blocks, req.uid)
            self._slots[rs.slot] = None
            finished.append(self._emit_finished(FinishedRequest(
                req.uid, np.asarray(rs.tokens, np.int32), "deadline",
                len(req.prompt), req.arrival, rs.admitted_at,
                rs.first_token_at if rs.n_generated > 0 else now, now,
            )))
        return finished

    # -- scheduling internals ----------------------------------------------

    def _live(self) -> list[RequestState]:
        return [rs for rs in self._slots if rs is not None]

    def _advance_clock(self) -> None:
        """Nothing in flight: jump (virtual) or wait (real) to the next
        arrival."""
        nxt = min(r.arrival for r in self._queue)
        if self._clock is None:
            self._now = max(self._now, float(nxt))
        else:
            # the clock's own sleep (resolve_clock): a ManualClock test
            # advances virtual time here instead of really sleeping, so
            # deadline math, traces and waiting share one timeline
            self._sleep(max(0.0, min(nxt - self.now(), 0.05)))

    def _admit_arrived(self) -> list[FinishedRequest]:
        """FIFO-admit every arrived request that fits a free slot (and, if
        paged, whose prompt blocks are available).  With chunked prefill
        the slot is only *occupied* here — the prompt streams in via
        :meth:`_prefill_tick` slices.  On the one-shot path, requests
        whose first token already finishes them (budget 1 / instant stop)
        complete here and never occupy a slot."""
        finished = []
        while True:
            free = [i for i, rs in enumerate(self._slots) if rs is None]
            if not free:
                break
            req = self._pop_ready()
            if req is None:
                break
            blocks: list[int] = []
            prefilled0, hashes, n_hit = 0, [], 0
            if self.allocator is not None:
                res = self._alloc_prompt_blocks(req)
                if res is None:
                    # pool full: requeue at the head, wait for evictions
                    self._queue.appendleft(req)
                    break
                blocks, prefilled0, hashes, n_hit = res
                self._trace("block_alloc", uid=req.uid, n_blocks=len(blocks))
            self.admissions += 1
            if req.uid in self._admitted_uids:
                self._m_restarts.inc()  # re-admission after preemption
            self._admitted_uids.add(req.uid)
            self._trace(
                "admitted", uid=req.uid, slot=free[0], n_blocks=len(blocks)
            )
            if self.prefill_chunk is not None:
                self._admit_chunked(
                    req, free[0], blocks, prefilled0, hashes, n_hit
                )
            elif prefilled0 > 0:
                done = self._admit_cached(
                    req, free[0], blocks, prefilled0, hashes, n_hit
                )
                if done is not None:
                    finished.append(done)
            else:
                done = self._admit(req, free[0], blocks, hashes)
                if done is not None:
                    finished.append(done)
        return finished

    def _alloc_prompt_blocks(self, req: Request):
        """Blocks covering an admitting prompt, or None if the pool cannot
        satisfy the request right now (nothing changes beyond LRU recency
        on failure — ownership is untouched).

        With prefix caching this is the admission hit-walk: the prompt's
        full-block chain hashes are looked up in the allocator's index,
        every *leading* hit is reused by taking a reference (before the
        tail allocation, so our own alloc can never evict our hits), and
        only the miss/partial tail is allocated.  A block-aligned fully-
        cached prompt still recomputes its last position (the sampler
        needs those logits), which would write inside the final shared
        block — that block is copied-on-write to a private page first.

        Returns ``(blocks, prefilled0, hashes, n_hit)``: the slot's block
        list, how many leading prompt tokens are already resident,
        the prompt's full-block chain hashes, and how many leading blocks
        came from the cache."""
        s = len(req.prompt)
        nb = kv_pool.blocks_for(s, self.block_size)
        if not self.prefix_cache:
            got = self.allocator.alloc(nb)
            return (got, 0, [], 0) if got is not None else None
        hashes = kv_pool.prompt_block_hashes(req.prompt, self.block_size)
        hits: list[int] = []
        for h in hashes:
            b = self.allocator.lookup(h)
            if b is None:
                break
            hits.append(b)
        for b in hits:
            self.allocator.ref(b)
        cached = len(hits) * self.block_size
        cow = cached == s  # fully cached: last position lives in a hit block
        got = self.allocator.alloc(nb - len(hits) + (1 if cow else 0))
        if got is None:
            self.allocator.unref(hits)
            return None
        self._m_pc_hits.inc(len(hits))
        self._m_pc_misses.inc(len(hashes) - len(hits))
        blocks = hits + got
        prefilled0 = min(cached, s - 1)
        self._m_pc_hit_tokens.inc(prefilled0)
        if cow:
            src, dst = blocks[len(hits) - 1], blocks.pop()
            with annotate("serve/prefix_cow"), self._mesh_ctx():
                self._caches = self._copy_block_fn(
                    self._caches, jnp.asarray(src, jnp.int32),
                    jnp.asarray(dst, jnp.int32),
                )
            blocks[len(hits) - 1] = dst
            self.allocator.unref([src])
            self._m_pc_cow.inc()
            self._trace("block_cow", uid=req.uid, src=src, dst=dst)
        if hits:
            self._trace(
                "prefix_hit", uid=req.uid, n_blocks=len(hits),
                n_tokens=prefilled0,
            )
        return blocks, prefilled0, hashes, len(hits)

    def _register_blocks(self, rs: RequestState) -> None:
        """Register every full block whose pages are fully written (and
        will receive no further writes) in the allocator's hash index, so
        later admissions can hit them.  Prompt blocks register as prefill
        slices cover them; on release the chain extends over *generated*
        tokens too, so a multi-turn follow-up prompt (history + reply)
        hits the whole previous conversation.  The last sampled token's
        KV is written only when the token is fed, so decode coverage
        stops one short of ``n_generated``."""
        if not self.prefix_cache:
            return
        bs = self.block_size
        req = rs.request
        s = len(req.prompt)
        covered = (
            s + rs.n_generated - 1 if rs.n_generated > 0 else rs.prefilled
        )
        n_full = min(covered // bs, len(rs.blocks))
        if rs.registered >= n_full:
            return
        h = rs.block_hashes
        if len(h) < n_full:  # extend the chain over generated tokens
            stream = np.concatenate(
                [req.prompt, np.asarray(rs.tokens, np.int32)]
            )
            while len(h) < n_full:
                i = len(h)
                h.append(kv_pool.hash_block_tokens(
                    h[i - 1] if i else None, stream[i * bs : (i + 1) * bs]
                ))
        while rs.registered < n_full:
            i = rs.registered
            self.allocator.register(rs.blocks[i], h[i])
            rs.registered += 1

    def _pop_ready(self) -> Optional[Request]:
        """Pop the first queued request that has arrived.  The head case is
        the O(1) fast path; the scan only happens when arrival delays have
        put an unarrived request in front of an arrived one."""
        now = self.now()
        for i, r in enumerate(self._queue):
            if r.arrival <= now:
                if i == 0:
                    return self._queue.popleft()
                del self._queue[i]
                return r
        return None

    def _admit_chunked(
        self, req: Request, slot: int, blocks: list[int],
        prefilled0: int = 0, hashes=(), n_hit: int = 0,
    ):
        """Occupy a slot without running prefill: install the slot's block
        table (paged) and let :meth:`_prefill_tick` stream the prompt in.
        The slot stays inactive in decode chunks until the final slice
        samples its first token.  A prefix-cache hit just starts the slice
        cursor at the cached boundary (``prefilled0``) — the tick path is
        oblivious to where the resident prefix came from."""
        if blocks:
            with self._mesh_ctx():
                self._caches = self._set_tables(
                    self._caches, jnp.asarray(slot), self._table_row(blocks)
                )
        self._slots[slot] = RequestState(
            request=req, slot=slot, blocks=blocks, tokens=[],
            n_generated=0, admitted_at=self.now(), prefilled=prefilled0,
            block_hashes=list(hashes), registered=n_hit,
        )

    def _prefill_tick(self) -> list[FinishedRequest]:
        """Advance at most ONE admitting request's prompt by one
        fixed-size ``forward_chunk`` slice, straight into the big caches.
        The decode cadence therefore pays for at most ``prefill_chunk``
        prompt tokens per engine step, however long the prompt.

        The slice that completes the prompt samples the first token with
        the one-shot path's exact key-split order, finishing admission
        (or, for instant-stop / budget-1 requests, the whole request)."""
        if self.prefill_chunk is None:
            return []
        pending = [
            rs for rs in self._live()
            if rs.prefilled < len(rs.request.prompt)
        ]
        if not pending:
            return []
        rs = min(pending, key=lambda r: (r.admitted_at, r.slot))
        t = self.prefill_chunk
        req = rs.request
        s = len(req.prompt)
        n = min(t, s - rs.prefilled)
        b = self.num_slots
        toks = np.zeros((b, t), np.int32)
        toks[rs.slot, :n] = req.prompt[rs.prefilled : rs.prefilled + n]
        pos = np.zeros((b,), np.int32)
        pos[rs.slot] = rs.prefilled
        active = np.zeros((b,), bool)
        active[rs.slot] = True
        lengths = np.zeros((b,), np.int32)
        lengths[rs.slot] = n
        with annotate("serve/chunked_prefill"), self._mesh_ctx():
            tok_d, self._caches, key_d = self._prefill_chunk(
                self.params, self._caches, jnp.asarray(toks),
                jnp.asarray(pos), jnp.asarray(active), jnp.asarray(lengths),
                jnp.asarray(rs.slot, jnp.int32), jax.random.PRNGKey(req.seed),
            )
        rs.prefilled += n
        self.prefill_tokens += n
        # blocks this slice just finished filling become hittable (their
        # writes are dispatched; device program order makes later readers
        # safe even while this prompt is still streaming in)
        self._register_blocks(rs)
        self._trace(
            "prefill_chunk", uid=req.uid, prefilled=rs.prefilled, total=s
        )
        if rs.prefilled < s:
            return []
        # one packed [tok0, finite] fetch per admission — validity rides
        # the transfer that was already happening
        arr = self._fetch(tok_d)
        tok0, ok = int(arr[0]), bool(arr[1])
        now = self.now()
        if not ok:
            self.quarantined += 1
            self._release_blocks(rs.blocks, req.uid)
            self._slots[rs.slot] = None
            return [self._emit_finished(FinishedRequest(
                req.uid, np.zeros((0,), np.int32), "error", s,
                req.arrival, rs.admitted_at, now, now,
            ))]
        self.tokens_generated += 1
        self._trace("first_token", uid=req.uid)
        done = self._finish_at_admission(req, tok0, rs.blocks,
                                         rs.admitted_at)
        if done is not None:
            self._slots[rs.slot] = None
            return [done]
        with self._mesh_ctx():
            self._state = self._admit_jit(
                self._state, jnp.asarray(rs.slot), tok_d[0], key_d,
                jnp.asarray(s, jnp.int32),
                jnp.asarray(req.max_new_tokens, jnp.int32),
            )
        rs.tokens = [tok0]
        rs.n_generated = 1
        rs.first_token_at = now
        return []

    def _finish_at_admission(
        self, req: Request, tok0: int, blocks: list[int], admitted_at: float
    ) -> Optional[FinishedRequest]:
        """The first sampled token already finishes the request (stop hit
        or budget 1): free its blocks and emit the FinishedRequest.  The
        single definition of finish-at-admission semantics, shared by
        one-shot (:meth:`_admit`) and chunked (:meth:`_prefill_tick`)
        admission.  Returns None if the request lives on."""
        if tok0 not in self._stop_set and req.max_new_tokens != 1:
            return None
        reason = "stop" if tok0 in self._stop_set else "length"
        self._release_blocks(blocks, req.uid)
        now = self.now()
        return self._emit_finished(FinishedRequest(
            req.uid, np.asarray([tok0], np.int32), reason, len(req.prompt),
            req.arrival, admitted_at, now, now,
        ))

    def _bucket_len(self, s: int) -> int:
        """Smallest power of two >= s, capped at the slot capacity."""
        b = 1
        while b < s:
            b <<= 1
        return min(b, self.max_len)

    def _suffix_fn(self, t: int) -> Callable:
        """The compiled cache-hit admission slice for suffix bucket ``t``
        (lazily jitted; one trace per power-of-two suffix length)."""
        fn = self._suffix_fns.get(t)
        if fn is None:
            fn = jax.jit(
                _make_prefill_chunk_fn(self.cfg, self.scfg, t),
                donate_argnums=(1,),
            )
            self._suffix_fns[t] = fn
        return fn

    def _admit_cached(
        self, req: Request, slot: int, blocks: list[int],
        prefilled0: int, hashes: list[int], n_hit: int,
    ) -> Optional[FinishedRequest]:
        """One-shot admission on a prefix-cache hit: the first
        ``prefilled0`` prompt tokens are already resident in the reused
        blocks, so only the unshared suffix runs — ONE padded
        ``forward_chunk`` slice into the big caches, exactly the program
        family chunked prefill uses.  The slice samples the first token
        with the one-shot key-split order (split after prefill, batch-1
        sampler), so the stream is bit-for-bit the cold admission's while
        TTFT pays for ``s - prefilled0`` tokens instead of ``s``."""
        s = len(req.prompt)
        with self._mesh_ctx():
            self._caches = self._set_tables(
                self._caches, jnp.asarray(slot), self._table_row(blocks)
            )
        n = s - prefilled0
        t = self._bucket_len(n)
        b = self.num_slots
        toks = np.zeros((b, t), np.int32)
        toks[slot, :n] = req.prompt[prefilled0:]
        pos = np.zeros((b,), np.int32)
        pos[slot] = prefilled0
        active = np.zeros((b,), bool)
        active[slot] = True
        lengths = np.zeros((b,), np.int32)
        lengths[slot] = n
        with annotate("serve/admission_prefill"), self._mesh_ctx():
            tok_d, self._caches, key_d = self._suffix_fn(t)(
                self.params, self._caches, jnp.asarray(toks),
                jnp.asarray(pos), jnp.asarray(active), jnp.asarray(lengths),
                jnp.asarray(slot, jnp.int32), jax.random.PRNGKey(req.seed),
            )
        self.prefill_tokens += n
        # one packed [tok0, finite] fetch per admission
        arr = self._fetch(tok_d)
        tok0, ok = int(arr[0]), bool(arr[1])
        now = self.now()
        if not ok:
            self.quarantined += 1
            self._release_blocks(blocks, req.uid)
            return self._emit_finished(FinishedRequest(
                req.uid, np.zeros((0,), np.int32), "error", s,
                req.arrival, now, now, now,
            ))
        # miss blocks are fully written by the slice above — registered
        # only after the finite check so a poisoned page is never indexed
        for i in range(n_hit, len(hashes)):
            self.allocator.register(blocks[i], hashes[i])
        self.tokens_generated += 1
        self._trace("first_token", uid=req.uid)
        done = self._finish_at_admission(req, tok0, blocks, now)
        if done is not None:
            return done
        with self._mesh_ctx():
            self._state = self._admit_jit(
                self._state, jnp.asarray(slot), tok_d[0], key_d,
                jnp.asarray(s, jnp.int32),
                jnp.asarray(req.max_new_tokens, jnp.int32),
            )
        self._slots[slot] = RequestState(
            request=req, slot=slot, blocks=blocks, tokens=[tok0],
            n_generated=1, admitted_at=now, prefilled=s, first_token_at=now,
            block_hashes=list(hashes), registered=len(hashes),
        )
        return None

    def _admission_prefill(self, req: Request):
        """Batch-1 prefill for admission.  Bucketed where parity-safe (one
        trace per power-of-two length bucket); exact-length otherwise."""
        if self._prefill_bucketed is not None:
            s = len(req.prompt)
            padded = np.zeros((self._bucket_len(s),), np.int32)
            padded[:s] = req.prompt
            with self._mesh_ctx():
                return self._prefill_bucketed(
                    self.params,
                    {"tokens": jnp.asarray(padded[None])},
                    jnp.asarray(s, jnp.int32),
                    jax.random.PRNGKey(req.seed),
                )
        with self._mesh_ctx():
            return self._prefill(
                self.params,
                {"tokens": jnp.asarray(req.prompt[None])},
                jnp.asarray(0, jnp.int32),
                jax.random.PRNGKey(req.seed),
            )

    def _admit(
        self, req: Request, slot: int, blocks: list[int], hashes=()
    ) -> Optional[FinishedRequest]:
        with annotate("serve/admission_prefill"):
            tok0_d, small, pos0, key = self._admission_prefill(req)
        # one packed [tok0, finite] fetch per admission
        arr = self._fetch(tok0_d)
        tok0, ok = int(arr[0]), bool(arr[1])
        now = self.now()
        if not ok:
            self.quarantined += 1
            self._release_blocks(blocks, req.uid)
            return self._emit_finished(FinishedRequest(
                req.uid, np.zeros((0,), np.int32), "error",
                len(req.prompt), req.arrival, now, now, now,
            ))
        self.tokens_generated += 1
        self._trace("first_token", uid=req.uid)
        done = self._finish_at_admission(req, tok0, blocks, now)
        if done is not None:
            # finish-at-admission never installs the prefilled cache into
            # the pool, so the blocks hold no content — nothing registers
            return done
        table_row = self._table_row(blocks)
        nb = len(blocks)
        if nb not in self._install_fns:
            self._install_fns[nb] = jax.jit(
                _make_install_fn(self.cfg, nb), donate_argnums=(0,)
            )
        with self._mesh_ctx():
            self._caches = self._install_fns[nb](
                self._caches, small, jnp.asarray(slot), table_row
            )
            self._state = self._admit_jit(
                self._state, jnp.asarray(slot), tok0_d[0], key, pos0,
                jnp.asarray(req.max_new_tokens, jnp.int32),
            )
        # the install above span-writes every prompt page: full blocks are
        # now content-complete and become hittable
        for i, h in enumerate(hashes):
            self.allocator.register(blocks[i], h)
        self._slots[slot] = RequestState(
            request=req, slot=slot, blocks=blocks, tokens=[tok0],
            n_generated=1, admitted_at=now, prefilled=len(req.prompt),
            first_token_at=now, block_hashes=list(hashes),
            registered=len(hashes),
        )
        return None

    def _table_row(self, blocks: list[int]) -> Array:
        row = np.zeros((self.max_blocks,), np.int32)
        row[: len(blocks)] = blocks
        return jnp.asarray(row)

    def _ensure_blocks(self) -> None:
        """Grow each live slot's block list to cover the coming chunk,
        preempting the youngest request if the pool runs dry."""
        for rs in sorted(self._live(), key=lambda r: r.admitted_at):
            if self._slots[rs.slot] is not rs:
                continue  # preempted by an earlier iteration of this loop
            if rs.n_generated == 0:
                continue  # still admitting: blocks already cover the prompt
            total_cap = len(rs.request.prompt) + rs.request.max_new_tokens
            need = kv_pool.blocks_for(
                min(rs.pos + self.chunk, total_cap), self.block_size
            )
            while need > len(rs.blocks):
                got = self.allocator.alloc(need - len(rs.blocks))
                if got is None:
                    victim = self._pick_victim()
                    if victim is None:
                        raise SchedulerStall(
                            "KV pool exhausted and nothing to preempt — "
                            "pool too small for the admitted working set: "
                            + self._stall_report()
                        )
                    self._preempt(victim)
                    if victim is rs:
                        break  # the requester itself was youngest: requeued
                    continue
                rs.blocks.extend(got)
                self._trace(
                    "block_alloc", uid=rs.request.uid, n_blocks=len(got)
                )
                with self._mesh_ctx():
                    self._caches = self._set_tables(
                        self._caches, jnp.asarray(rs.slot),
                        self._table_row(rs.blocks),
                    )

    def _pick_victim(self):
        """Youngest live request — including the one asking for blocks:
        preempting the youngest always discards the least progress, and it
        guarantees the oldest request keeps advancing (a lone request
        always fits the pool by the submit-time check, so the scheduler
        cannot livelock)."""
        live = self._live()
        return max(live, key=lambda r: r.admitted_at) if live else None

    def _preempt(self, rs: RequestState) -> None:
        """Return a request to the queue head; its blocks are reclaimed and
        it restarts from scratch on re-admission (same seed -> same token
        stream, so preemption is invisible in the output)."""
        self.preemptions += 1
        self._trace(
            "preempted", uid=rs.request.uid, n_generated=rs.n_generated
        )
        with self._mesh_ctx():
            self._state = self._deactivate_jit(
                self._state, jnp.asarray(rs.slot)
            )
        # a preempted stream's blocks stay hittable: the deterministic
        # restart walks the same chain and resumes from the cached prefix
        # instead of re-prefilling from scratch
        self._register_blocks(rs)
        self._release_blocks(rs.blocks, rs.request.uid)
        self._slots[rs.slot] = None
        self._queue.appendleft(rs.request)

    def _run_chunk(self):
        """Run one compiled decode chunk.  If the fault injector has a
        logit poison landing inside this chunk for a live decoding slot,
        dispatch the lazily-compiled poisoning variant instead — the
        fault-free program is never recompiled or perturbed."""
        poison = None
        if self.faults is not None and self.faults.has_poison:
            spec = np.full((self.num_slots,), -1, np.int32)
            hit = False
            for rs in self._live():
                if rs.done or rs.n_generated == 0:
                    continue
                g = self.faults.poison_rel_step(
                    rs.request.uid, rs.n_generated, self.chunk
                )
                if g is not None:
                    spec[rs.slot] = g
                    hit = True
            if hit:
                poison = jnp.asarray(spec)
        if poison is not None:
            if self._chunk_fn_poison is None:
                self._chunk_fn_poison = jax.jit(
                    _make_cb_chunk_fn(
                        self.cfg, self.scfg, self.chunk, poison=True
                    ),
                    donate_argnums=(1, 2),
                )
            with self._mesh_ctx():
                packed, self._caches, self._state = self._chunk_fn_poison(
                    self.params, self._caches, self._state, poison
                )
        else:
            with self._mesh_ctx():
                packed, self._caches, self._state = self._chunk_fn(
                    self.params, self._caches, self._state
                )
        return packed

    def _process_chunk(self, packed: np.ndarray) -> list[FinishedRequest]:
        """Mirror the device's per-step lifecycle over the fetched token
        matrix, then evict finished slots and reclaim their blocks.

        ``packed`` is ``[tokens (chunk cols) | active | quarantine]``; a
        quarantine entry < chunk marks the scan step whose logits went
        non-finite — that slot finishes with ``reason="error"`` at that
        step and its later columns are ignored."""
        steps = packed.shape[1] - 2
        quar_col = packed[:, -1]
        for step in range(steps):
            for rs in self._live():
                if rs.done or rs.n_generated == 0:
                    continue  # finished, or still admitting (no decode)
                if int(quar_col[rs.slot]) == step:
                    rs.done, rs.finish_reason = True, "error"
                    self.quarantined += 1
                    continue
                tok = int(packed[rs.slot, step])
                rs.tokens.append(tok)
                rs.n_generated += 1
                self.tokens_generated += 1
                if tok in self._stop_set:
                    rs.done, rs.finish_reason = True, "stop"
                elif rs.n_generated >= rs.request.max_new_tokens:
                    rs.done, rs.finish_reason = True, "length"
        device_active = packed[:, -2].astype(bool)
        finished = []
        now = self.now()
        for rs in self._live():
            expect_active = (not rs.done) and rs.n_generated > 0
            if bool(device_active[rs.slot]) != expect_active:
                raise AssertionError(
                    f"slot {rs.slot}: device active mask disagrees with "
                    "the host lifecycle mirror"
                )
            if not rs.done:
                continue
            if rs.finish_reason != "error":
                # extend the hash chain over the generated tokens so a
                # multi-turn follow-up (history + reply) hits; quarantined
                # streams register nothing (their pages are suspect)
                self._register_blocks(rs)
            self._release_blocks(rs.blocks, rs.request.uid)
            self._slots[rs.slot] = None
            req = rs.request
            finished.append(
                self._emit_finished(FinishedRequest(
                    req.uid, np.asarray(rs.tokens, np.int32),
                    rs.finish_reason, len(req.prompt), req.arrival,
                    rs.admitted_at, rs.first_token_at, now,
                ))
            )
        return finished
