"""Compiled decode engine: the whole generation loop on device.

The legacy ``BatchedServer.generate`` ran a Python per-token loop — every
step launched a jitted decode, synced the sampled token to the host
(``np.asarray``), and re-dispatched.  On a bandwidth-bound W1A8 decode the
dispatch + host-sync overhead dominates the actual GEMV work, so the loop
was Python-bound, not hardware-bound.

``DecodeEngine`` compiles prefill -> ``lax.scan`` of (decode step -> top-k
sample) over the whole token budget into ONE jitted function: sampling runs
on device, the KV caches stay resident as scan carry, and exactly one
device->host transfer happens per ``generate`` call (``host_transfers``
counts them; the engine test asserts the invariant).  ``generate_stream``
is the chunked variant: one transfer per chunk for incremental delivery.

Prefill and decode are the SAME forward: ``api.prefill`` is
``forward_chunk`` from an empty cache and ``api.decode_step`` is
``forward_chunk`` with T=1 (see ``models.transformer``), so this lockstep
tier, the python-loop baseline and the continuous-batching scheduler all
run one cache-resident forward implementation.

Logits contract: prefill and decode both surface ``(B, V)`` next-token
logits (``decode_logits`` normalizes the decode step's ``(B, 1, V)``), so
sampling never branches on step index.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models import api
from repro.serve.tracing import annotate, maybe_profile

Array = jax.Array


# ---------------------------------------------------------------------------
# Tensor-parallel serving helpers (shared with serve.scheduler)
# ---------------------------------------------------------------------------


def serving_overrides(cfg: ModelConfig, mesh, extra: Optional[dict] = None):
    """Sharding-rule overrides for serving ``cfg`` on ``mesh``: the
    column-parallel base (:data:`repro.distributed.sharding.
    SERVING_OVERRIDES`) plus cfg-driven relaxations — when a head count
    doesn't divide the model axis, the whole head family drops to
    replicated so a flattened ``(heads * head_dim)`` weight dim can never
    shard *within* a head (MQA/GQA on a wide mesh)."""
    ov = dict(shd.SERVING_OVERRIDES)
    ws = int(dict(mesh.shape).get("model", 1))
    if ws > 1:
        if getattr(cfg, "n_kv_heads", 0) % ws:
            ov.update({"kv_heads": None, "cache_heads": None})
        if getattr(cfg, "n_heads", 0) % ws:
            ov.update({"heads": None, "act_heads": None})
    if extra:
        ov.update(extra)
    return ov


def _matching_axes(params, cfg: ModelConfig):
    """The logical-axes tree matching ``params``' structure — latent
    (``api.params_shape_and_axes``) or either packed serving export — or
    None when no candidate matches (caller replicates)."""
    import jax.tree_util as jtu

    want = jtu.tree_structure(params)
    candidates = []
    try:
        candidates.append(api.params_shape_and_axes(cfg))
    except Exception:  # noqa: BLE001 — family without a shape oracle
        pass
    try:
        from repro.train.quantized_serving import serving_params_shape_and_axes

        for packed in (True, False):
            candidates.append(serving_params_shape_and_axes(cfg, packed))
    except Exception:  # noqa: BLE001
        pass
    for shapes, axes in candidates:
        if jtu.tree_structure(shapes) == want:
            return axes
    return None


def place_params(params, cfg: ModelConfig, mesh, overrides,
                 param_axes=None):
    """``device_put`` a parameter tree onto ``mesh`` with the N-major
    (column-parallel) serving placement; unmatched trees replicate."""
    axes = param_axes if param_axes is not None else _matching_axes(params, cfg)
    with shd.sharding_rules(mesh, overrides):
        if axes is None:
            shardings = jax.tree.map(
                lambda _: NamedSharding(mesh, PartitionSpec()), params
            )
        else:
            shardings = shd.nmajor_param_sharding(params, axes, mesh)
    return jax.device_put(params, shardings)


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.8
    top_k: int = 40
    max_new_tokens: int = 32
    # tokens that end a sequence.  ``generate`` still runs the full compiled
    # budget (one fused program, fixed shape); ``generate_stream`` tracks a
    # per-sequence done mask on device and exits its Python chunk loop once
    # every sequence has stopped.  The continuous-batching engine
    # (repro.serve.scheduler) short-circuits per request.
    stop_tokens: tuple[int, ...] = ()


def _hit_stop(tok: Array, scfg: SamplerConfig) -> Array:
    """(B,) bool — did this step's token end its sequence?"""
    if not scfg.stop_tokens:
        return jnp.zeros(tok.shape, bool)
    stop = jnp.asarray(scfg.stop_tokens, jnp.int32)
    return (tok[:, None] == stop[None, :]).any(axis=-1)


def sample_token(key: Array, logits: Array, scfg: SamplerConfig) -> Array:
    """logits (B, V) -> (B,) int32, on device (scan-safe: top_k static)."""
    if scfg.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / scfg.temperature
    if scfg.top_k > 0 and scfg.top_k < logits.shape[-1]:
        kth = jax.lax.top_k(logits, scfg.top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def decode_logits(params, tok: Array, caches, pos: Array, cfg: ModelConfig):
    """One decode step under the (B, V) logits contract.

    tok: (B,) int32 current tokens.  Returns ((B, V) logits, new caches).
    """
    logits, caches = api.decode_step(params, tok[:, None], caches, pos, cfg)
    return logits[:, -1], caches


def _scan_decode(params, cfg, tok0, caches, pos0, key, length, scfg,
                 done0=None):
    """length decode steps from tok0: returns (tokens (B, length), carry).

    Key-split order matches the legacy Python loop (split -> sample) so the
    two paths produce identical token streams for a given seed.  This is
    the ONLY definition of the step body: generate, generate_stream chunks
    and the stop-mask tracking all run through it, so the key-split parity
    contract cannot drift between paths.  The carry's trailing ``done``
    mask records which sequences have emitted a stop token (it never
    alters sampling — generate's output stays budget-shaped).
    """
    if done0 is None:
        done0 = jnp.zeros(tok0.shape, bool)

    def step(carry, _):
        tok, caches, pos, key, done = carry
        key, sub = jax.random.split(key)
        with annotate("serve/decode_step"):
            logits, caches = decode_logits(params, tok, caches, pos, cfg)
        with annotate("serve/sample"):
            nxt = sample_token(sub, logits, scfg)
        return (nxt, caches, pos + 1, key, done | _hit_stop(nxt, scfg)), nxt

    carry, toks = jax.lax.scan(
        step, (tok0, caches, pos0, key, done0), None, length=length
    )
    return jnp.moveaxis(toks, 0, 1), carry  # (B, length)


def _prefill_sample(params, batch, pos_off, key, cfg, cache_len, scfg):
    """Prefill + sample the first token.  The single definition of the
    key-split order both generate and generate_stream (and the legacy loop
    equivalence) depend on.  The trailing ``ok`` mask — (B,) bool, are the
    prefill logits finite — is the quarantine signal the continuous
    engine's admission path reads; the lockstep entry points ignore it
    (it is a pure function of logits they already computed, so carrying it
    changes no numerics)."""
    with annotate("serve/prefill_forward"):
        logits, caches = api.prefill(params, batch, cfg, cache_len)
    key, sub = jax.random.split(key)
    tok0 = sample_token(sub, logits, scfg)
    pos0 = jnp.asarray(batch["tokens"].shape[1], jnp.int32) + pos_off
    ok = jnp.isfinite(logits).all(axis=-1)
    return tok0, caches, pos0, key, ok


def _make_generate_fn(cfg: ModelConfig, cache_len: int, scfg: SamplerConfig):
    """The whole generation as one jittable fn: prefill + first sample +
    (T-1)-step scan.  One fused XLA program, no host round-trips inside."""
    t = scfg.max_new_tokens

    def gen(params, batch, pos_off, key):
        tok0, caches, pos0, key, _ = _prefill_sample(
            params, batch, pos_off, key, cfg, cache_len, scfg
        )
        rest, _ = _scan_decode(
            params, cfg, tok0, caches, pos0, key, t - 1, scfg
        )
        return jnp.concatenate([tok0[:, None], rest], axis=1)  # (B, T)

    return gen


def _make_prefill_fn(cfg: ModelConfig, cache_len: int, scfg: SamplerConfig):
    def prefill(params, batch, pos_off, key):
        tok0, caches, pos0, key, _ = _prefill_sample(
            params, batch, pos_off, key, cfg, cache_len, scfg
        )
        return tok0, caches, pos0, key

    return prefill


def _make_checked_prefill_fn(cfg: ModelConfig, cache_len: int,
                             scfg: SamplerConfig):
    """Batch-1 admission prefill with the quarantine signal packed into
    the token fetch: returns ``([tok0, ok] (2,) int32, caches, pos0,
    key)`` so the continuous engine learns about non-finite prefill logits
    on the ONE scalar fetch it already pays per admission — no extra
    device->host sync.  Token and key-split order are exactly
    :func:`_prefill_sample`'s (same fn), preserving stream parity."""

    def prefill(params, batch, pos_off, key):
        tok0, caches, pos0, key, ok = _prefill_sample(
            params, batch, pos_off, key, cfg, cache_len, scfg
        )
        packed = jnp.stack([tok0[0], ok[0].astype(jnp.int32)])
        return packed, caches, pos0, key

    return prefill


def _make_bucketed_prefill_fn(cfg: ModelConfig, cache_len: int,
                              scfg: SamplerConfig):
    """Prefill for bucket-padded prompts: ``batch["tokens"]`` is right-padded
    to a shared bucket length and ``plen`` (traced) is the true prompt
    length, so ONE trace serves every prompt length in the bucket.  Logits
    come from position ``plen - 1`` and ``pos0 = plen``; the key-split
    order matches :func:`_prefill_sample` exactly (split after prefill),
    preserving the per-request determinism contract.  Returns the same
    packed ``[tok0, ok]`` pair as :func:`_make_checked_prefill_fn` (this
    path is only ever the continuous engine's)."""

    def prefill(params, batch, plen, key):
        logits, caches = api.prefill(
            params, batch, cfg, cache_len, last_pos=plen
        )
        key, sub = jax.random.split(key)
        tok0 = sample_token(sub, logits, scfg)
        ok = jnp.isfinite(logits).all(axis=-1)
        packed = jnp.stack([tok0[0], ok[0].astype(jnp.int32)])
        return packed, caches, jnp.asarray(plen, jnp.int32), key

    return prefill


def _make_chunk_fn(cfg: ModelConfig, scfg: SamplerConfig, length: int):
    """Streaming chunk: ``length`` decode steps plus per-sequence done
    tracking.  Returns (packed (B, length+1), carry) where the last packed
    column is the post-chunk done mask — it rides the chunk's single
    device->host transfer so the host loop can early-exit without an extra
    fetch (the transfers-per-chunk invariant test stays honest)."""

    def chunk(params, tok, caches, pos, key, done):
        toks, carry = _scan_decode(
            params, cfg, tok, caches, pos, key, length, scfg, done
        )
        packed = jnp.concatenate(
            [toks, carry[-1][:, None].astype(toks.dtype)], axis=1
        )
        return packed, carry

    return chunk


class DecodeEngine:
    """Fixed-batch compiled generation engine.

    Compiled programs are cached per (max_new_tokens, temperature, top_k)
    sampler signature (jax.jit adds the batch-shape axis underneath), so a
    server reuses one compilation across calls.

    ``mesh`` (a ``(data, model)`` mesh from ``launch.mesh``) turns on
    tensor-parallel serving: parameters are placed N-major over the model
    axis and every compiled program is traced inside the serving sharding
    rules, so the annotations in the model stack become GSPMD constraints
    and the packed-kernel dispatch opens its shard_map islands.  A 1-device
    mesh streams bit-for-bit the meshless engine.
    """

    def __init__(self, params, cfg: ModelConfig, max_len: int, *,
                 mesh=None, param_axes=None, mesh_overrides=None):
        self.cfg, self.max_len = cfg, max_len
        self.mesh = mesh
        self._overrides = (
            serving_overrides(cfg, mesh, mesh_overrides)
            if mesh is not None else None
        )
        if mesh is not None:
            params = place_params(params, cfg, mesh, self._overrides,
                                  param_axes)
        self.params = params
        self._gen_fns: dict = {}
        self._prefill_fns: dict = {}
        self._chunk_fns: dict = {}
        # device->host transfers performed (the engine test asserts exactly
        # one per generate() call)
        self.host_transfers = 0

    def _mesh_ctx(self):
        """Rule context active while a compiled fn is called (tracing runs
        at call time, in the calling thread, so this is where the serving
        rules must be installed)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return shd.sharding_rules(self.mesh, self._overrides)

    # -- compilation caches -------------------------------------------------

    @staticmethod
    def _key(scfg: SamplerConfig):
        return (
            scfg.max_new_tokens,
            float(scfg.temperature),
            int(scfg.top_k),
            tuple(scfg.stop_tokens),
        )

    def _gen_fn(self, scfg: SamplerConfig):
        key = self._key(scfg)
        if key not in self._gen_fns:
            self._gen_fns[key] = jax.jit(
                _make_generate_fn(self.cfg, self.max_len, scfg)
            )
        return self._gen_fns[key]

    def _prefill_fn(self, scfg: SamplerConfig):
        key = self._key(scfg)[1:]  # chunking doesn't depend on T
        if key not in self._prefill_fns:
            self._prefill_fns[key] = jax.jit(
                _make_prefill_fn(self.cfg, self.max_len, scfg)
            )
        return self._prefill_fns[key]

    def _chunk_fn(self, scfg: SamplerConfig, length: int):
        key = self._key(scfg)[1:] + (length,)
        if key not in self._chunk_fns:
            # donate the cache tree: each chunk writes one token per layer
            # into multi-MB KV buffers — without donation XLA copies the
            # whole tree per chunk (the caller always rebinds from the
            # return value, so the donated input is never reused)
            self._chunk_fns[key] = jax.jit(
                _make_chunk_fn(self.cfg, scfg, length), donate_argnums=(2,)
            )
        return self._chunk_fns[key]

    # -- host boundary ------------------------------------------------------

    def _fetch(self, x: Array) -> np.ndarray:
        self.host_transfers += 1
        return np.asarray(x)

    def _batch_and_off(self, prompts, extra_inputs):
        batch = {"tokens": prompts, **(extra_inputs or {})}
        off = (
            self.cfg.n_image_tokens
            if (extra_inputs and "image_embeds" in extra_inputs)
            else 0
        )
        return batch, jnp.asarray(off, jnp.int32)

    # -- public API ---------------------------------------------------------

    def generate(
        self,
        prompts: Array,  # (B, S) int32, right-aligned equal-length prompts
        scfg: Optional[SamplerConfig] = None,
        extra_inputs: Optional[dict] = None,
        seed: int = 0,
    ) -> np.ndarray:
        """(B, max_new_tokens) int32 — one device->host transfer total."""
        scfg = SamplerConfig() if scfg is None else scfg
        if scfg.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {scfg.max_new_tokens}"
            )
        batch, pos_off = self._batch_and_off(prompts, extra_inputs)
        with maybe_profile("decode_engine_generate"), self._mesh_ctx():
            toks = self._gen_fn(scfg)(
                self.params, batch, pos_off, jax.random.PRNGKey(seed)
            )
        return self._fetch(toks)

    def generate_stream(
        self,
        prompts: Array,
        scfg: Optional[SamplerConfig] = None,
        extra_inputs: Optional[dict] = None,
        seed: int = 0,
        chunk: int = 8,
    ) -> Iterator[np.ndarray]:
        """Chunked streaming: yields arrays whose concatenation equals
        ``generate``'s output, one host transfer per chunk.  The first yield
        is (B, <=chunk+1) — the prefill-sampled token rides with the first
        decode chunk — and later yields are (B, <=chunk).

        With ``scfg.stop_tokens`` set, the chunk loop exits early once
        every sequence has produced a stop token: the on-device done mask
        rides the existing per-chunk transfer as one extra packed column,
        so early exit costs no additional fetches.  (The concatenated
        yields are then a prefix of ``generate``'s output — truncation at
        the stop token itself is the caller's policy.)"""
        scfg = SamplerConfig() if scfg is None else scfg
        if chunk <= 0:
            raise ValueError(f"chunk must be positive, got {chunk}")
        if scfg.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {scfg.max_new_tokens}"
            )
        batch, pos_off = self._batch_and_off(prompts, extra_inputs)
        with self._mesh_ctx():
            tok, caches, pos, key = self._prefill_fn(scfg)(
                self.params, batch, pos_off, jax.random.PRNGKey(seed)
            )
        done = _hit_stop(tok, scfg)  # stays on device (no transfer)
        pending = tok[:, None]  # first token rides with the first chunk
        remaining = scfg.max_new_tokens - 1
        while remaining > 0:
            step = min(chunk, remaining)
            with self._mesh_ctx():
                packed, (tok, caches, pos, key, done) = self._chunk_fn(
                    scfg, step
                )(self.params, tok, caches, pos, key, done)
            if pending is not None:  # device-side concat: one fetch per chunk
                packed = jnp.concatenate([pending, packed], axis=1)
                pending = None
            fetched = self._fetch(packed)
            yield fetched[:, :-1]
            remaining -= step
            if scfg.stop_tokens and fetched[:, -1].all():
                return  # every sequence stopped: skip the remaining chunks
        if pending is not None:  # max_new_tokens == 1: prefill sample only
            yield self._fetch(pending)
