"""Serving subsystem — three engine tiers over ONE model forward.

Every tier runs the same cache-resident multi-token forward,
``repro.models.api.forward_chunk``: T tokens per slot at per-slot position
offsets, K/V written into an *existing* cache (dense ring or paged) under
a causal mask against the already-resident prefix.  Prefill is
forward_chunk from an empty cache; a decode step is forward_chunk with
T=1; chunked admission prefill is a sequence of forward_chunk slices.
One read path to optimise — the prerequisite the paged-attention kernel
work builds on.

1. **Python loop** (``repro.train.serve.BatchedServer.generate_python_loop``)
   — one jitted decode + one host sync per token.  Kept as the benchmark
   baseline and the scan-equivalence oracle.
2. **Compiled lockstep** (:class:`~repro.serve.engine.DecodeEngine`) —
   prefill (one forward_chunk) + ``lax.scan`` decode + on-device sampling
   fused into one XLA program; a fixed batch decodes in lockstep, one
   device->host transfer per ``generate`` (per chunk when streaming, with
   the stop-token done mask riding the same transfer for early exit).
3. **Continuous batching**
   (:class:`~repro.serve.scheduler.ContinuousBatchingEngine`) — the same
   compiled chunked decode, plus a request lifecycle around it: queued
   requests are admitted into slots at chunk boundaries, tracked with
   per-slot positions / PRNG keys / stop masks on device, and evicted the
   chunk they finish, freeing their KV blocks for the next request.  With
   ``prefill_chunk`` set, admission runs token-budget **chunked prefill**
   (Sarathi-style): each engine step spends a bounded slice of at most
   one admitting prompt alongside the decode chunk, writing straight into
   the shared caches (``kv_pool.write_span``), so a long prompt no longer
   freezes every live decode stream — the head-of-line latency the tier
   exists to remove.

Cache-adapter protocol: decode caches are per-layer dicts in one of two
interchangeable layouts — dense ``{"k", "v"}`` ring buffers, or paged
``{"kpool", "vpool", "table"}`` backed by the shared block pool in
:mod:`repro.serve.kv_pool` (a ``(num_blocks, block, n_kv_heads, head_dim)``
pool per global-attention layer plus per-slot block tables; sliding-window
layers keep their dense ring caches, whose length *is* the window).  The
model stack dispatches on the ``"table"`` key, so every engine tier runs
either layout and produces identical tokens.

Paged attention kernel: scoring over the paged layout dispatches to the
Pallas block-table kernel (``repro.kernels.paged_attention``) whenever
``kernels.ops.paged_attention_enabled()`` — ``REPRO_PAGED_ATTN=1`` forces
it on (interpret mode off-TPU), ``=0`` forces the fallback, default
enables it on TPU only — and the static shapes qualify
(``ops.paged_attention_supported``: GQA grouping divides, block_size and
head_dim 8-aligned).  The kernel walks each slot's block table in place
with a flash-decoding online softmax (per-slot work bounded by the
resident length, never the table capacity) and serves all three tiers
through the one read path: decode steps (T=1), chunked-prefill slices and
one-shot prefill (T>1).  The ``kv_pool.read`` gather + SDPA path remains
the fallback and parity oracle — it is bitwise the dense computation,
while the kernel is float-rounding-close (online softmax re-associates
the reduction), which is exactly why the default keeps the fallback on
CPU where the bit-for-bit cross-layout suites run.  Pages-per-step is
autotuned per (T, heads, head_dim, block, table-width) signature via
``ops.sweep_paged_tiles`` and persisted per backend alongside the GEMV
tile tables (``REPRO_TILE_CACHE`` / ``REPRO_TILE_CACHE_DIR`` env vars).

All three tiers serve either weight layout: latent fake-quant params (float
matmuls on the quantization grid) or the packed integer export from
``repro.train.quantized_serving.quantize_params_for_serving(packed=True)``,
where every backbone linear runs the Pallas W1A8 kernel tier and decode
steps hit the fused-act-quant GEMV kernels (``repro.kernels``).  The packed
engines are bit-for-bit self-consistent across tiers and stay within float
rounding of the fake-quant oracle (``tests/test_packed_serving.py``).

Sharded serving — every tier accepts ``mesh=`` (a ``(data, model)`` device
mesh from ``repro.launch.mesh.make_host_mesh`` / ``mesh_from_env``, or the
``--mesh DxM`` flag on ``examples/serve_lm.py``) and runs the same
compiled programs tensor-parallel:

* **What shards** — weights column-parallel only (N-major, the *output*
  dim: packed sign-bit planes, INT8-branch matrices and their latent
  float counterparts for Q/K/V and the FFN up/gate projections) over the
  ``model`` axis, with the per-tensor AbsMean / AbsMax scales replicated
  — a shard dequantizes with the same scalar as the whole weight, so
  every per-shard output is a bitwise slice of the unsharded result (no
  K reduction is ever split).  Paged K/V pools shard over KV heads
  (``cache_heads``); packed-weight kernels run inside per-shard
  ``shard_map`` islands (``kernels.ops.*_nshard``) so each shard
  autotunes its own GEMV tile for its local N.
* **What replicates** — the host-side scheduler, admission queue,
  fault/metrics/tracing layers, per-slot positions / masks / PRNG keys,
  block tables, and dense ring caches (serving overrides map ``batch``
  to no mesh axis; indivisible head counts relax to replicated).
* **Where the collective sits** — one all-gather per sublayer, at the
  boundary where the N-sharded activation meets the replicated
  down/output projection; XLA inserts it from the shardings, so the
  1-device mesh lowers to exactly the meshless program.

``tests/test_sharded_serving.py`` pins the contract: mesh ``(1,1)`` is
bit-for-bit the meshless engine (both layouts, one-shot and chunked
prefill, greedy and sampled), and a forced 2-device CPU mesh reproduces
the token streams with weights and pools genuinely sharded.  The mesh
shape is exported as ``mesh_data_parallelism`` / ``mesh_model_parallelism``
gauges in the metrics snapshot.

Request lifecycle (tier 3) — every submitted request traverses the state
machine exactly once and finishes exactly once::

    submit() ──────────────▶ queued ──admit──▶ prefilling ──first token──▶
        │                      │                  │
        │ dead on arrival      │ shed / deadline  │ deadline / NaN logits
        ▼                      ▼                  ▼
    finished(rejected)    finished(shed |    finished(deadline | error)
                          deadline)
                                                ┌──────────────────────┐
    decoding ──stop token──▶ finished(stop)     │ preemption loops back│
        │        budget ────▶ finished(length)  │ to queued; restart is│
        │        deadline ──▶ finished(deadline)│ deterministic, so the│
        └──non-finite logits▶ finished(error)   │ stream is unchanged  │
                                                └──────────────────────┘

``FinishedRequest.finish_reason`` is one of ``FINISH_REASONS``
(``stop | length | deadline | shed | rejected | error``).  Robustness
knobs on :class:`~repro.serve.scheduler.ContinuousBatchingEngine`:
``max_queue`` + ``overload_policy`` bound the admission queue (load
shedding), per-request ``deadline`` / ``ttft_budget`` are enforced at
chunk boundaries, non-finite logits quarantine only the poisoned stream
(reason ``"error"``; everyone else is bit-for-bit untouched), and a
watchdog raises :class:`~repro.serve.scheduler.SchedulerStall` instead of
spinning when no progress is possible.

Prefix caching (``prefix_cache=True``, paged layout only) — KV blocks
gain content identity and a second lifecycle that overlays the request
state machine.  Every full prompt block is named by the chain hash
``hash((parent_hash, block_tokens))`` over the HOST token stream (mesh-
and layout-independent), and each block walks::

                 alloc (miss)                register
    blank ────────────────────▶ private ──────────────▶ cached+referenced
      ▲                            │                      │           ▲
      │ LRU eviction               │ unref                │ unref     │ ref
      │ (hash entry dies)          ▼                      ▼           │ (hit)
      └───────────────────── blank pool            cached+unreferenced
                                                     (parked on LRU,
                                                      still hittable)

* **hit** — admission walks the prompt's block-hash chain through the
  allocator's index; every *leading* hit is taken by ``ref`` (refcount++,
  off the LRU) before the tail is allocated, so an admission can never
  evict its own hits.  Only the unshared suffix is prefilled — bitwise
  the full prefill, which is why streams stay bit-for-bit identical to a
  cold engine (``tests/test_prefix_cache.py``).
* **miss** — the tail blocks come from the blank pool first, then by
  evicting the least-recently-released refcount-0 cached block (its hash
  entry dies with it: ``prefix_cache_evictions_total``).  A block
  registers into the index only once its pages are fully written and
  will receive no more writes; on release the chain extends over
  *generated* tokens, so multi-turn follow-ups hit the whole previous
  conversation.
* **CoW** — a block-aligned fully-cached prompt still recomputes its
  final position (the sampler needs those logits), which would write
  inside the last shared block: admission copies that page to a private
  block first (``prefix_cache_cow_total``; trace event ``block_cow``),
  so no slot ever mutates a page another slot references.
* **unref** — "free" is refcount decrement: a released shared block
  stays resident for its other owners, and a refcount-0 *cached* block
  parks on the LRU — still hittable, still counted free
  (``free_count = blank + parked``), so a drained engine reconciles to
  ``pool_blocks_used == 0`` with a warm cache.

Configs whose recurrent state lives outside the paged pool (sliding-
window rings, SSM/rec state, MLA latents) or whose routing couples
tokens (MoE, VLM prefixes) decline the cache with one warning and run
cold.  Hits/misses/reused tokens are exported as
``prefix_cache_{hits,misses,hit_tokens}_total`` and admission hits land
on the request trace as ``prefix_hit`` events.

Fault injection (:mod:`repro.serve.faults`) drives all of this
deterministically for tests and chaos runs::

    from repro.serve import ContinuousBatchingEngine
    from repro.serve.faults import (
        AllocFailure, FaultInjector, PoisonLogits,
    )

    inj = FaultInjector([
        AllocFailure(index=3),          # 4th alloc call fails
        PoisonLogits(uid=1, gen_index=5),  # NaN logits at token 5
    ])  # or FaultInjector.random(seed, uids) for a seeded schedule
    eng = ContinuousBatchingEngine(params, cfg, num_slots=4, max_len=128,
                                   faults=inj)
    eng.submit(prompt, max_new_tokens=16, deadline=40.0)
    done = eng.run()   # uid 1 finishes with reason "error"; all other
                       # streams are bit-for-bit the fault-free run

With ``faults=None`` (default) the hooks are skipped entirely and the
compiled programs are byte-identical to the fault-free build — the chaos
suite (``tests/test_chaos.py``) asserts the graceful-degradation
contract under random schedules in both cache layouts.

Observability tier (:mod:`repro.serve.metrics` +
:mod:`repro.serve.tracing`) — zero-overhead-when-disabled telemetry
threaded through the whole stack:

* **Metrics registry** — every engine owns a
  :class:`~repro.serve.metrics.MetricsRegistry` of typed counters,
  gauges and fixed-bucket histograms (log-spaced edges, bounded memory —
  no per-request lists).  ``engine.snapshot()`` returns one plain dict
  (``validate_snapshot`` pins the schema,
  ``MetricsRegistry.prometheus_text`` renders the exposition format)
  covering submissions, per-``finish_reason`` totals, shed / rejection /
  deadline / quarantine counts, preemptions and restarts, admission-queue
  depth and batch occupancy, paged-pool block utilization, and
  engine-computed TTFT / inter-token-latency / request-latency
  histograms on the engine's own clock — the benchmark reports what the
  engine measures, not a host-side recount.  Legacy counter attributes
  (``engine.shed_requests`` etc.) remain as aliases over the registry.
  Process-wide autotune-cache stats (``kernels.tile_cache``: dispatch
  hits/misses, sweeps, sweep milliseconds) ride the same snapshot via a
  registered collector.
* **Request tracing** — pass a
  :class:`~repro.serve.tracing.RequestTracer` (``tracer=``) wrapping a
  :class:`~repro.serve.tracing.JsonlSink` or
  :class:`~repro.serve.tracing.ListSink` to stream one structured event
  per lifecycle edge: submitted → block_alloc → admitted →
  prefill_chunk → first_token → decode_chunk → finished(reason), plus
  block_free, preempted, stall and fault_* events, all timestamped on
  the engine clock.  ``tracer=None`` (default) skips every emission.
* **Profiling hooks** — :func:`~repro.serve.tracing.annotate` brackets
  the admission-prefill / chunked-prefill / decode-chunk / sample
  regions (and the kernel dispatch sites in ``kernels.ops``) with
  ``jax.profiler.TraceAnnotation`` + ``named_scope``; the annotations
  are applied unconditionally, so enabling or disabling metrics/tracing
  changes NO compiled program — byte-identical lowering is asserted in
  ``tests/test_metrics.py``.  Setting ``REPRO_PROFILE_DIR=/path`` wraps
  engine runs in ``jax.profiler.start_trace``/``stop_trace`` for a
  loadable device profile.

Clocks: ``clock=None`` keeps the deterministic virtual clock (one tick
per decode chunk); any ``now()`` callable or a
:class:`~repro.serve.metrics.ManualClock` /
:class:`~repro.serve.metrics.MonotonicClock` object supplies real (or
test-controlled) time, including the drive-loop sleep — tests fake time
without sleeping.
"""

from repro.serve.engine import (  # noqa: F401
    DecodeEngine,
    SamplerConfig,
    decode_logits,
    sample_token,
)
from repro.serve.faults import (  # noqa: F401
    AllocFailure,
    DelayArrival,
    FaultInjector,
    ForcePreempt,
    PoisonLogits,
)
from repro.serve.metrics import (  # noqa: F401
    ManualClock,
    MetricsRegistry,
    MonotonicClock,
    validate_snapshot,
)
from repro.serve.scheduler import (  # noqa: F401
    FINISH_REASONS,
    ContinuousBatchingEngine,
    FinishedRequest,
    InadmissibleRequest,
    Request,
    RequestState,
    SchedulerStall,
)
from repro.serve.tracing import (  # noqa: F401
    JsonlSink,
    ListSink,
    RequestTracer,
    annotate,
    maybe_profile,
)
