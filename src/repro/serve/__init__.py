"""Serving subsystem: the compiled decode engine lives here; the legacy
``repro.train.serve`` module re-exports it for backward compatibility."""

from repro.serve.engine import (  # noqa: F401
    DecodeEngine,
    SamplerConfig,
    decode_logits,
    sample_token,
)
