"""Compatibility re-export: the metrics registry moved to
``repro.telemetry.metrics`` so the training loop shares one metrics core
with the serving stack.  Serving-side imports keep working unchanged."""

from repro.telemetry.metrics import (  # noqa: F401
    DEFAULT_TIME_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    ManualClock,
    MetricsRegistry,
    MonotonicClock,
    _fmt_labels,
    resolve_clock,
    validate_snapshot,
)
