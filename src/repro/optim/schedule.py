"""Two-phase LR / weight-decay schedule (paper Appendix B.2, Figure 9).

Phase 1 [0, mid): warmup then linear decay from peak_lr; weight decay 0.1.
Phase 2 [mid, end): restart at a lower LR, linear decay to ~0; WD disabled.

The mid-training loss drop the paper highlights (Figure 5b) comes from this
schedule, so it is reproduced exactly.  FP16 baselines use a standard
cosine schedule (paper §E: "half-precision models did not benefit from a
similar decay strategy").
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TwoPhaseSchedule:
    peak_lr: float = 1.5e-3
    phase2_lr: float = 1e-4
    final_lr: float = 1e-5
    warmup_steps: int = 500  # paper: 500 warmup steps
    total_steps: int = 10000
    midpoint_frac: float = 0.5
    wd_phase1: float = 0.1
    wd_phase2: float = 0.0

    @property
    def mid(self) -> int:
        return int(self.total_steps * self.midpoint_frac)

    def lr(self, step: Array) -> Array:
        s = jnp.asarray(step, jnp.float32)
        warm = self.peak_lr * s / max(self.warmup_steps, 1)
        mid = float(self.mid)
        # phase 1: linear peak -> phase2_lr at midpoint
        p1 = self.peak_lr + (self.phase2_lr - self.peak_lr) * (
            (s - self.warmup_steps) / jnp.maximum(mid - self.warmup_steps, 1.0)
        )
        # phase 2: linear phase2_lr -> final_lr at end
        p2 = self.phase2_lr + (self.final_lr - self.phase2_lr) * (
            (s - mid) / jnp.maximum(self.total_steps - mid, 1.0)
        )
        out = jnp.where(s < self.warmup_steps, warm, jnp.where(s < mid, p1, p2))
        return jnp.maximum(out, 0.0)

    def wd(self, step: Array) -> Array:
        s = jnp.asarray(step, jnp.float32)
        return jnp.where(s < self.mid, self.wd_phase1, self.wd_phase2)


@dataclasses.dataclass(frozen=True)
class CosineSchedule:
    """Baseline (FP16) schedule: warmup + cosine decay, constant WD."""

    peak_lr: float = 3e-4
    final_lr: float = 3e-5
    warmup_steps: int = 500
    total_steps: int = 10000
    weight_decay: float = 0.1

    def lr(self, step: Array) -> Array:
        s = jnp.asarray(step, jnp.float32)
        warm = self.peak_lr * s / max(self.warmup_steps, 1)
        t = (s - self.warmup_steps) / jnp.maximum(
            self.total_steps - self.warmup_steps, 1.0
        )
        t = jnp.clip(t, 0.0, 1.0)
        cos = self.final_lr + 0.5 * (self.peak_lr - self.final_lr) * (
            1.0 + jnp.cos(jnp.pi * t)
        )
        return jnp.where(s < self.warmup_steps, warm, cos)

    def wd(self, step: Array) -> Array:
        return jnp.full_like(jnp.asarray(step, jnp.float32), self.weight_decay)


def schedule_for_mode(quant_mode: str, total_steps: int, peak_lr: float | None = None):
    if quant_mode == "none":
        return CosineSchedule(
            total_steps=total_steps, peak_lr=peak_lr or 3e-4,
            warmup_steps=min(500, max(10, total_steps // 20)),
        )
    return TwoPhaseSchedule(
        total_steps=total_steps, peak_lr=peak_lr or 1.5e-3,
        warmup_steps=min(500, max(10, total_steps // 20)),
    )
