"""AdamW built from scratch (no optax in this environment).

Matches the paper's recipe: Adam beta=(0.9, 0.95), FP32 moments and master
(latent) weights, global-norm gradient clipping, schedule-driven decoupled
weight decay (the two-phase WD comes in via the schedule object).

The optimizer state is a plain pytree so it shards with the same logical
axes as the parameters (FSDP over `data` x TP over `model`) and checkpoints
through ``repro.checkpoint``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    clip_norm: float = 1.0
    # parameters whose path contains one of these fragments skip weight
    # decay (norms, scalars, biases — and the feature-scaling alpha/beta)
    no_decay_fragments: tuple = ("norm", "alpha", "beta", "lam", "dt_bias", "A_log", "D")


class AdamWState(NamedTuple):
    step: Array
    mu: Any
    nu: Any


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=zeros,
        nu=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    )


def adamw_state_axes(param_axes) -> AdamWState:
    """Logical axes for the optimizer state (moments shard like params)."""
    return AdamWState(step=(), mu=param_axes, nu=param_axes)


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def _decay_mask(params, cfg: AdamWConfig):
    import jax.tree_util as jtu

    paths, treedef = jtu.tree_flatten_with_path(params)
    mask = []
    for path, leaf in paths:
        keys = "/".join(str(getattr(e, "key", getattr(e, "idx", ""))) for e in path)
        skip = any(f in keys for f in cfg.no_decay_fragments) or leaf.ndim <= 1
        mask.append(not skip)
    return jtu.tree_unflatten(treedef, mask)


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr: Array,
    wd: Array,
    cfg: AdamWConfig = AdamWConfig(),
):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    decay_mask = _decay_mask(params, cfg)

    def upd(g, m, v, p, do_decay):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if do_decay:
            delta = delta + wd * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_mask = treedef.flatten_up_to(decay_mask)

    out = [
        upd(g, m, v, p, dm)
        for g, m, v, p, dm in zip(flat_g, flat_m, flat_v, flat_p, flat_mask)
    ]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr, "wd": wd}
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), metrics
