"""BitLinear: the 1-bit linear layer used for all MHA projections (paper §3.1).

Forward (training, fake-quant):  Y = lambda/gamma * W_int1 @ Q(RMSNorm(X))
implemented as  Y = binarize(W) @ quant_act(X)  on the dequantized grid so
autodiff + STE handle the backward pass.  The true integer path (packed
weights, INT8 GEMM) is exercised by ``repro.kernels`` at inference.

Convention used across the framework: every module exposes

    init_<name>(key, ...) -> (params, axes)

where ``params`` is a pytree of arrays and ``axes`` is an identically
structured pytree of logical-axis tuples consumed by
``repro.distributed.sharding``.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.quantization import (
    QuantConfig,
    fake_quant_linear_weights,
    is_packed_1bit,
    maybe_quant_acts,
)

Array = jax.Array


def init_linear(
    key: Array,
    d_in: int,
    d_out: int,
    axes: Sequence[str | None],
    dtype=jnp.float32,
    scale: Optional[float] = None,
):
    """Dense kernel init (truncated-normal fan-in, LLaMA-style)."""
    if scale is None:
        scale = d_in**-0.5
    w = jax.random.truncated_normal(key, -3.0, 3.0, (d_in, d_out), dtype) * scale
    return {"w": w}, {"w": tuple(axes)}


def init_rmsnorm(d: int, dtype=jnp.float32, axis: str | None = None):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": (axis,)}


def rmsnorm(params, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


def bitlinear(
    params,
    x: Array,
    cfg: QuantConfig,
    sublayer_norm=None,
    waxes=None,
) -> Array:
    """Apply a (possibly quantized) linear layer.

    sublayer_norm: optional RMSNorm params applied to the *input* before
    activation quantization (BitNet SubLN placement, paper Appendix B: the
    norm compresses the activation dynamic range so AbsMax INT8 behaves).
    waxes: the weight's logical axes — required for the INT8 quantized
    FSDP gather (cfg.qgather, see repro.distributed.qgather).
    """
    if sublayer_norm is not None:
        x = rmsnorm(sublayer_norm, x)
    w = params["w"]
    if is_packed_1bit(w):
        # packed serving layout: run the true-integer W1A8 kernel tier
        # (act-quant fused; decode shapes hit the GEMV kernels) instead of
        # dequantize-then-float-matmul.  Under an active mesh whose rules
        # shard this weight's output dim, the call runs as a shard_map
        # island over the N-major shards (tensor-parallel serving).
        from repro.kernels import ops  # deferred: kernels are serving-only
        from repro.distributed.sharding import nmajor_axis

        axis = nmajor_axis(w["packed"].shape[-1],
                           waxes[-1] if waxes else None)
        if axis is not None:
            return ops.bit_linear_infer_nshard(
                x, w["packed"], w["scale"], axis, out_dtype=x.dtype)
        return ops.bit_linear_infer(x, w["packed"], w["scale"],
                                    out_dtype=x.dtype)
    if cfg.mode == "none" and not isinstance(w, dict):
        return x @ w.astype(x.dtype)
    xq = maybe_quant_acts(x, cfg)
    if cfg.qgather and waxes is not None and cfg.mode in ("bitnet", "pquant"):
        from repro.distributed.qgather import binarize_gather

        wq = binarize_gather(w, tuple(waxes)).astype(x.dtype)
    else:
        wq = fake_quant_linear_weights(w, cfg).astype(x.dtype)
    return xq @ wq


def linear_param_count(d_in: int, d_out: int) -> int:
    return d_in * d_out
