"""Inference-time bit-packing for 1-bit weights (paper Appendix A).

Signs {-1, +1} are stored 8-per-uint8 along the input-feature (K) axis:
16x smaller than FP16, 8x smaller than the INT8 sign view.  The Pallas
W1A8 kernel streams packed tiles HBM->VMEM and unpacks in-register; this
module provides the host-side pack/unpack and the pure-jnp oracle used by
kernel tests.

Bit convention: bit b of byte k along K encodes sign of weight k*8+b,
bit=1 -> +1, bit=0 -> -1.  Little-endian within the byte.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def pack_signs(signs: Array) -> Array:
    """Pack +-1 (or bool) signs along the K (second-to-last) axis into uint8.

    signs: (..., K, N) with values in {-1, +1}; leading axes (layer stacks,
    expert stacks) pack per slice.  K must be a multiple of 8.
    Returns (..., K//8, N) uint8.
    """
    *lead, k, n = signs.shape
    assert k % 8 == 0, f"K={k} must be a multiple of 8"
    bits = (signs > 0).astype(jnp.uint8).reshape(*lead, k // 8, 8, n)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))[:, None]
    return jnp.sum(bits * weights, axis=-2, dtype=jnp.uint8)


def unpack_signs(packed: Array, dtype=jnp.int8) -> Array:
    """Inverse of :func:`pack_signs`: (..., K//8, N) uint8 -> (..., K, N) +-1."""
    *lead, kb, n = packed.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)[:, None]
    bits = (packed[..., :, None, :] >> shifts) & jnp.uint8(1)
    signs = bits.astype(jnp.int8) * 2 - 1
    return signs.reshape(*lead, kb * 8, n).astype(dtype)


@dataclasses.dataclass
class PackedBitWeight:
    """Inference export of one 1-bit linear layer.

    packed: (K//8, N) uint8 sign bits.
    lam:    per-tensor AbsMean dequant scale (float32 scalar array).
    shape:  original (K, N).
    """

    packed: Array
    lam: Array
    shape: tuple[int, int]

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.packed.shape)) + 4

    def dequantize(self, dtype=jnp.float32) -> Array:
        return unpack_signs(self.packed, jnp.int8).astype(dtype) * self.lam.astype(
            dtype
        )


def export_bit_weight(w: Array) -> PackedBitWeight:
    """Offline-quantize a latent FP weight to its packed inference form
    (paper: 'parameters in the 1-bit branch are offline quantized and
    stored in 1-bit precision during inference')."""
    mu = jnp.mean(w)
    lam = jnp.mean(jnp.abs(w))
    signs = jnp.where(w - mu >= 0, 1, -1).astype(jnp.int8)
    return PackedBitWeight(
        packed=pack_signs(signs), lam=lam.astype(jnp.float32), shape=tuple(w.shape)
    )


@dataclasses.dataclass
class PackedInt8Weight:
    """Inference export of one INT8 (high-precision branch) weight."""

    q: Array  # int8, same shape as the latent weight
    scale: Array  # float32 scalar (per-tensor AbsMax)

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.q.shape)) + 4

    def dequantize(self, dtype=jnp.float32) -> Array:
        return self.q.astype(dtype) / self.scale.astype(dtype)


def export_int8_weight(w: Array) -> PackedInt8Weight:
    amax = jnp.max(jnp.abs(w))
    scale = 127.0 / (amax + 1e-5)
    q = jnp.clip(jnp.round(w * scale), -127, 127).astype(jnp.int8)
    return PackedInt8Weight(q=q, scale=scale.astype(jnp.float32))


def model_weight_bytes(
    n_1bit: int, n_8bit_total: int, n_fp16: int, seq_active_8bit: int | None = None
) -> dict[str, float]:
    """Bytes moved per forward for weight streaming (paper Figure 6).

    With top-1 routing only one 8-bit branch is *read* per token regardless
    of N (``seq_active_8bit``), while all N are *stored*.
    """
    read_8bit = seq_active_8bit if seq_active_8bit is not None else n_8bit_total
    return {
        "stored_bytes": n_1bit / 8 + n_8bit_total + n_fp16 * 2,
        "read_bytes": n_1bit / 8 + read_8bit + n_fp16 * 2,
    }
