"""Quantization primitives for pQuant (paper §3.1, Eq. 3-10).

All training-time quantizers are *fake-quant*: they return values in the
original float dtype but restricted to the quantization grid, and carry a
straight-through estimator (STE) so gradients flow to the latent weights.

The inference-time (packed, integer) path lives in ``repro.core.packing``
and ``repro.kernels``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.telemetry import probes

Array = jax.Array

# Small epsilon used throughout to avoid division by zero in scale
# computation (paper's `eps` in Eq. 7 guards the clip range instead; we fold
# it into the scale denominator, which is equivalent and cheaper).
EPS = 1e-5

INT8_QMAX = 127.0  # paper uses [-2^7, 2^7]; we clip to the representable 127


# ---------------------------------------------------------------------------
# Straight-through estimator
# ---------------------------------------------------------------------------


@jax.custom_vjp
def ste(x: Array, x_quant: Array) -> Array:
    """Return ``x_quant`` in the forward pass, d/dx = identity in backward.

    Canonical STE: the quantizer is treated as the identity for gradient
    purposes (paper Appendix B.1).
    """
    return x_quant


def _ste_fwd(x, x_quant):
    return x_quant, None


def _ste_bwd(_, g):
    return g, None


ste.defvjp(_ste_fwd, _ste_bwd)


def ste_round(x: Array) -> Array:
    """round() with identity gradient."""
    return ste(x, jnp.round(x))


def ste_sign(x: Array) -> Array:
    """sign() mapped to {-1, +1} with identity gradient.

    ``jnp.sign(0) == 0`` would create a third level; the paper's Eq. 4 only
    defines +-1, so we map 0 -> +1 (measure-zero under continuous latents).
    """
    s = jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)
    return ste(x, s)


# ---------------------------------------------------------------------------
# Weight quantizers
# ---------------------------------------------------------------------------


def binarize_weights(w: Array) -> tuple[Array, Array]:
    """1-bit weight fake-quant (paper Eq. 3-6).

    W_int1 = Sign(W - mu),   mu = mean(W),   lambda = mean(|W|)

    Returns ``(w_q, lam)`` where ``w_q`` contains +-lambda values (the
    dequantized 1-bit weights, still in float dtype) and ``lam`` is the
    per-tensor AbsMean scale.  The +-1 integer view is ``w_q / lam``.
    """
    mu = jnp.mean(w)
    lam = jnp.mean(jnp.abs(w)) + EPS
    signs = ste_sign(w - mu)
    return signs * lam, lam


def binarize_weights_grouped(w: Array, group_size: int) -> tuple[Array, Array]:
    """Group-wise 1-bit quantization (paper §4.6 ablation, groups of 64).

    Groups run along the last (input-feature) axis.  One fp scale per group:
    better accuracy, 16-bit metadata per ``group_size`` weights (the paper
    notes this is hardware-unfriendly; we keep it as an ablation).
    """
    *lead, k = w.shape
    assert k % group_size == 0, f"{k=} not divisible by {group_size=}"
    wg = w.reshape(*lead, k // group_size, group_size)
    mu = jnp.mean(wg, axis=-1, keepdims=True)
    lam = jnp.mean(jnp.abs(wg), axis=-1, keepdims=True) + EPS
    signs = ste_sign(wg - mu)
    return (signs * lam).reshape(w.shape), lam.squeeze(-1)


def binarize_weights_channelwise(w: Array) -> tuple[Array, Array]:
    """Channel-wise (per output column) 1-bit quantization (paper §4.6)."""
    mu = jnp.mean(w, axis=0, keepdims=True)
    lam = jnp.mean(jnp.abs(w), axis=0, keepdims=True) + EPS
    signs = ste_sign(w - mu)
    return signs * lam, lam.squeeze(0)


def binarize_weights_stacked(w: Array, n_batch_axes: int = 1) -> tuple[Array, Array]:
    """Per-slice 1-bit quantization for stacked (e.g. per-expert) weights.

    w: (N..., d_in, d_out) with ``n_batch_axes`` leading stack axes; mu and
    lambda are computed per slice so each expert keeps its own scale.
    """
    red = tuple(range(n_batch_axes, w.ndim))
    mu = jnp.mean(w, axis=red, keepdims=True)
    lam = jnp.mean(jnp.abs(w), axis=red, keepdims=True) + EPS
    signs = ste_sign(w - mu)
    return signs * lam, lam


def ternarize_weights_stacked(w: Array, n_batch_axes: int = 1) -> tuple[Array, Array]:
    """Per-slice ternary quantization for stacked weights."""
    red = tuple(range(n_batch_axes, w.ndim))
    lam = jnp.mean(jnp.abs(w), axis=red, keepdims=True) + EPS
    q = jnp.clip(ste_round(w / lam), -1.0, 1.0)
    return q * lam, lam


def quantize_weights_int8_stacked(w, n_batch_axes: int = 1) -> tuple[Array, Array]:
    """Per-slice INT8 AbsMax for stacked weights.  Accepts the serving dict
    layout ({"q": int8, "scale"}), in which case it dequantizes directly."""
    if isinstance(w, dict):
        return _dequant_stored(w), w["scale"]
    red = tuple(range(n_batch_axes, w.ndim))
    amax = jnp.max(jnp.abs(w), axis=red, keepdims=True)
    scale = INT8_QMAX / (amax + EPS)
    q = jnp.clip(ste_round(w * scale), -INT8_QMAX, INT8_QMAX)
    return q / scale, scale


def fake_quant_stacked(w, cfg: "QuantConfig", n_batch_axes: int = 1) -> Array:
    """Backbone quantizer for stacked (per-expert) weights."""
    if isinstance(w, dict):
        return _dequant_stored(w)
    if cfg.mode == "none":
        return w
    if cfg.mode == "bitnet158":
        return ternarize_weights_stacked(w, n_batch_axes)[0]
    return binarize_weights_stacked(w, n_batch_axes)[0]


def ternarize_weights(w: Array) -> tuple[Array, Array]:
    """BitNet-1.58 ternary {-1, 0, +1} AbsMean quantization (baseline).

    W_q = RoundClip(W / mean(|W|), -1, 1) * mean(|W|)
    """
    lam = jnp.mean(jnp.abs(w)) + EPS
    q = jnp.clip(ste_round(w / lam), -1.0, 1.0)
    return q * lam, lam


def quantize_weights_int8(w: Array, axis: Optional[int] = None) -> tuple[Array, Array]:
    """INT8 AbsMax weight fake-quant for the high-precision branch.

    The paper quantizes the 8-bit branch "identically to 8-bit activations"
    (AbsMax, Eq. 7-9).  ``axis=None`` gives a per-tensor scale; pass an axis
    for per-channel.
    """
    if axis is None:
        amax = jnp.max(jnp.abs(w))
    else:
        amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = INT8_QMAX / (amax + EPS)
    q = jnp.clip(ste_round(w * scale), -INT8_QMAX, INT8_QMAX)
    return q / scale, scale


# ---------------------------------------------------------------------------
# Activation quantizer
# ---------------------------------------------------------------------------


def act_scale_int8(x: Array) -> Array:
    """Per-token AbsMax INT8 scale: gamma = 127 / (max|x| + eps) along the
    feature (last) axis, computed in float32.

    The SINGLE source of truth for activation quantization scales: the
    fake-quant trainer path (:func:`quantize_activations_int8`), the
    runtime integer path (:func:`quantize_act_int8`, re-exported by
    ``repro.kernels.ops``) and the fused kernel prologues
    (``w1a8_gemv._quant_prologue``, ``rmsnorm_quant``) all compute exactly
    this — float32 amax, ``INT8_QMAX / (amax + EPS)`` — so packed-vs-
    fake-quant parity cannot drift in bf16 (bf16 amax used to round
    differently from the kernels' f32 amax).
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return INT8_QMAX / (amax + EPS)


def quantize_activations_int8(x: Array) -> tuple[Array, Array]:
    """Per-token AbsMax INT8 activation fake-quant (paper Eq. 7-9).

    gamma = 127 / max|x| along the feature (last) axis, per token
    (:func:`act_scale_int8`).  Returns ``(x_q, gamma)`` with
    ``x_q = RoundClip(x * gamma) / gamma`` in the input dtype.
    """
    gamma = act_scale_int8(x)
    q = jnp.clip(ste_round(x.astype(jnp.float32) * gamma), -INT8_QMAX, INT8_QMAX)
    if probes.active():
        # saturation fraction at the INT8 rails, weighted by element count
        # so summaries() yields the global rate across all tap sites
        probes.add_mean(
            "clip_act", jnp.mean(jnp.abs(q) >= INT8_QMAX), float(x.size)
        )
    return (q / gamma).astype(x.dtype), gamma


def quantize_act_int8(x: Array) -> tuple[Array, Array]:
    """Per-token AbsMax INT8 (runtime, true-integer path).

    Same grid as :func:`quantize_activations_int8` (one
    :func:`act_scale_int8` source of truth), but returns the int8 tensor
    and a flat per-row gamma for the kernel epilogues.
    """
    gamma = act_scale_int8(x)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) * gamma), -INT8_QMAX, INT8_QMAX
    )
    return q.astype(jnp.int8), gamma[..., 0]


# ---------------------------------------------------------------------------
# Quantization mode config
# ---------------------------------------------------------------------------

QuantMode = Literal["none", "bitnet", "bitnet158", "pquant"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Selects the quantization scheme for a whole model.

    mode:
      none       FP16/BF16 baseline (LLaMA-class).
      bitnet     all linear layers 1-bit W1A8 (BitNet baseline).
      bitnet158  all linear layers ternary W1.58A8 (BitNet-1.58 baseline).
      pquant     MHA 1-bit; FFN decoupled 1-bit + r-wide INT8 branch(es).
    r:           width of the 8-bit branch (per paper Table 1; multiples of 128).
    num_experts: N routable 8-bit branches (paper §3.3); 1 = single branch.
    alpha_init / beta_init: feature-scaling init (paper §3.2: alpha >> beta).
    act_bits:    activation precision (8 everywhere in the paper).
    weight_scheme: per-tensor | channelwise | groupwise (paper §4.6 ablations).
    group_size:  group width for groupwise.
    native_mix_frac: if > 0, run the "Native Mix" ablation (paper Fig. 7):
                 keep this fraction of *1-bit* weights in high precision
                 in-place instead of the decoupled branch.
    """

    mode: QuantMode = "pquant"
    r: int = 128
    num_experts: int = 1
    alpha_init: float = 2.0
    beta_init: float = 0.2
    act_bits: int = 8
    weight_scheme: Literal["tensor", "channel", "group"] = "tensor"
    group_size: int = 64
    native_mix_frac: float = 0.0
    # beyond-paper: all-gather FSDP weight shards as INT8 signs instead of
    # fp latents (repro.distributed.qgather); measured in EXPERIMENTS §Perf
    qgather: bool = False

    @property
    def quantize_acts(self) -> bool:
        return self.mode != "none"

    def binarize(self, w: Array) -> tuple[Array, Array]:
        if self.weight_scheme == "channel":
            return binarize_weights_channelwise(w)
        if self.weight_scheme == "group":
            return binarize_weights_grouped(w, self.group_size)
        return binarize_weights(w)


def _dequant_stored(w: dict) -> Array:
    """Dequantize a serving-format weight: {"q": int8, "scale": f32} or
    {"packed": uint8 (..., K//8, N), "scale": f32} (see
    train/quantized_serving; leading axes are layer/expert stacks).
    The integer tensor is what lives in HBM — this is the paper's deployment
    layout (§A) expressed in the compiled artifact.

    This float fallback is only for paths without a packed kernel (training
    utilities, routed 8-bit experts); the model forward dispatches packed
    layouts to ``repro.kernels.ops`` (``bit_linear_infer`` /
    ``decoupled_first_gemm`` / ``int8_linear_infer``) instead."""
    if "packed" in w:
        from repro.core.packing import unpack_signs

        signs = unpack_signs(w["packed"], jnp.int8)
        return signs.astype(w["scale"].dtype) * w["scale"]
    return w["q"].astype(w["scale"].dtype) * w["scale"]


def is_packed_1bit(w) -> bool:
    """True for the bit-packed 1-bit serving layout {"packed", "scale"}
    consumable by ``ops.bit_linear_infer`` / ``ops.decoupled_first_gemm``."""
    return isinstance(w, dict) and "packed" in w


def is_stored_int8(w) -> bool:
    """True for the INT8 serving layout {"q", "scale"} (8-bit branch, or the
    1-bit sign fallback when K isn't byte-aligned)."""
    return isinstance(w, dict) and "q" in w


def fake_quant_linear_weights(w, cfg: QuantConfig) -> Array:
    """Apply the configured *backbone* weight quantizer (1-bit or ternary).
    Accepts either a latent float tensor (training fake-quant) or the
    pre-quantized serving dict layout."""
    if isinstance(w, dict):
        return _dequant_stored(w)
    if cfg.mode == "none":
        return w
    if cfg.mode == "bitnet158":
        return ternarize_weights(w)[0]
    return cfg.binarize(w)[0]


def maybe_quant_acts(x: Array, cfg: QuantConfig) -> Array:
    if not cfg.quantize_acts:
        return x
    return quantize_activations_int8(x)[0]


# ---------------------------------------------------------------------------
# Effective bits-per-weight accounting (paper reports 1.28 / 1.35 bit)
# ---------------------------------------------------------------------------


def effective_bits(n_1bit: int, n_8bit: int, n_fp16: int = 0) -> float:
    """Weighted average bits/weight across parameter populations."""
    total = n_1bit + n_8bit + n_fp16
    if total == 0:
        return 0.0
    return (n_1bit * 1.0 + n_8bit * 8.0 + n_fp16 * 16.0) / total
