"""pQuant core: quantizers, decoupled linear layer, routable 8-bit experts,
sensitivity analysis, and inference bit-packing."""

from repro.core.quantization import (  # noqa: F401
    QuantConfig,
    act_scale_int8,
    binarize_weights,
    ternarize_weights,
    quantize_act_int8,
    quantize_activations_int8,
    quantize_weights_int8,
    effective_bits,
    ste,
    ste_sign,
    ste_round,
)
from repro.core.decoupled import (  # noqa: F401
    init_decoupled_ffn,
    decoupled_ffn,
    set_feature_scaling,
)
from repro.core.bitlinear import bitlinear, init_linear, init_rmsnorm, rmsnorm  # noqa: F401
from repro.core.routing import RouterConfig  # noqa: F401
