"""The decoupled FFN layer — pQuant's core contribution (paper §3.2, Eq. 11).

    Y = alpha * FFN^{INT8}_{[:r]}(LN(x)) + beta * FFN^{INT1}_{[r:]}(LN(x))

The FFN hidden dimension is structurally split: ``r`` hidden units route
through an INT8 branch (weights + activations INT8), the remaining
``d_ff_1bit`` units through the 1-bit branch (sign/AbsMean weights, INT8
activations).  ``alpha`` and ``beta`` are learnable scalars initialised
``alpha >> beta`` so the high-precision path receives stronger gradient
feedback — this is the *feature scaling* that guides sensitive parameters
into the 8-bit branch instead of pre-assigning positions.

§3.3 scaling: the 8-bit branch is replicated ``N`` times and a top-1
softmax router picks one branch per token; the 1-bit branch acts as the
always-active shared expert.  Active parameter count is constant in N.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import routing
from repro.core.bitlinear import init_linear, init_rmsnorm, rmsnorm
from repro.distributed.sharding import shard_hint
from repro.core.quantization import (
    QuantConfig,
    is_packed_1bit,
    is_stored_int8,
    maybe_quant_acts,
    quantize_weights_int8_stacked,
    fake_quant_linear_weights,
)
from repro.core.routing import RouterConfig
from repro.telemetry import probes

Array = jax.Array


def _tap_branch_norms(y1_scaled: Array, y8_scaled: Array) -> None:
    """Record both decoupled-branch output norms (QAT health probe:
    ``qat_branch_share8`` — paper §3.2's allocation claim, live)."""
    probes.add(
        "branch1_sq", jnp.sum(jnp.square(y1_scaled.astype(jnp.float32)))
    )
    probes.add(
        "branch8_sq", jnp.sum(jnp.square(y8_scaled.astype(jnp.float32)))
    )

ACTIVATIONS: dict[str, Callable[[Array], Array]] = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def init_decoupled_ffn(
    key: Array,
    d_model: int,
    d_ff_1bit: int,
    r: int,
    num_experts: int = 1,
    glu: bool = True,
    dtype=jnp.float32,
    alpha_init: float = 2.0,
    beta_init: float = 0.2,
):
    """Parameters for a decoupled (GLU-)FFN.

    1-bit branch: gate/up (d_model, d_ff_1bit), down (d_ff_1bit, d_model).
    8-bit branch: stacked over experts, gate/up (N, d_model, r),
    down (N, r, d_model).  ``r == 0`` degenerates to a plain quantized FFN;
    ``d_ff_1bit == 0`` to a pure 8-bit FFN (both exercised in tests).
    """
    keys = jax.random.split(key, 8)
    params: dict = {}
    axes: dict = {}

    def add(name, p, a):
        params[name] = p
        axes[name] = a

    s_in = d_model**-0.5
    if d_ff_1bit > 0:
        if glu:
            add(
                "w1_gate",
                jax.random.truncated_normal(
                    keys[0], -3, 3, (d_model, d_ff_1bit), dtype
                )
                * s_in,
                ("embed", "ffn"),
            )
        add(
            "w1_up",
            jax.random.truncated_normal(keys[1], -3, 3, (d_model, d_ff_1bit), dtype)
            * s_in,
            ("embed", "ffn"),
        )
        add(
            "w1_down",
            jax.random.truncated_normal(keys[2], -3, 3, (d_ff_1bit, d_model), dtype)
            * (d_ff_1bit**-0.5),
            ("ffn", "embed"),
        )
    if r > 0:
        n = num_experts
        if glu:
            add(
                "w8_gate",
                jax.random.truncated_normal(keys[3], -3, 3, (n, d_model, r), dtype)
                * s_in,
                ("experts", "embed", "ffn8"),
            )
        add(
            "w8_up",
            jax.random.truncated_normal(keys[4], -3, 3, (n, d_model, r), dtype)
            * s_in,
            ("experts", "embed", "ffn8"),
        )
        add(
            "w8_down",
            jax.random.truncated_normal(keys[5], -3, 3, (n, r, d_model), dtype)
            * (r**-0.5),
            ("experts", "ffn8", "embed"),
        )
        # feature scaling (paper §3.2): learnable scalars, alpha >> beta
        add("alpha", jnp.asarray(alpha_init, dtype), ())
        add("beta", jnp.asarray(beta_init, dtype), ())
        if n > 1:
            rp, ra = routing.init_router(
                keys[6], d_model, RouterConfig(num_experts=n, top_k=1)
            )
            add("router", rp, {"w": ra["w"]})
    # SubLN before the down-projection (BitNet placement, Appendix B)
    ln_p, ln_a = init_rmsnorm(d_ff_1bit if d_ff_1bit > 0 else r, dtype, axis="ffn")
    add("subln", ln_p, ln_a)
    return params, axes


def set_feature_scaling(params, alpha: float, beta: float):
    """Initialise alpha/beta after init (kept separate so ablations can
    re-initialise; paper §4.6 studies (1.0, 0.5) vs (2.0, 0.2))."""
    if "alpha" in params:
        params["alpha"] = jnp.asarray(alpha, params["alpha"].dtype)
        params["beta"] = jnp.asarray(beta, params["beta"].dtype)
    return params


# ---------------------------------------------------------------------------
# Packed serving path (true-integer kernel tier)
# ---------------------------------------------------------------------------


def _int8_kernel_view(w: dict):
    """Serving {"q", "scale"} (possibly 1-stacked over experts) ->
    (q 2-D int8, kernel wscale).  ``scale`` is stored as the dequant
    multiplier (deq = q * scale); the int8 kernels fold the *quant*
    multiplier (deq = q / wscale) into their epilogue, so pass 1/scale."""
    q, s = w["q"], w["scale"]
    if q.ndim == 3:
        q, s = q[0], s[0]
    return q, 1.0 / s.reshape(())


def _serving_ffn_layout(params, glu: bool) -> bool:
    """True when the FFN has a single-expert INT8 serving branch and (if a
    1-bit trunk exists) a fully packed trunk — the layouts
    :func:`_ffn_packed_apply` fuses.  Routed (N > 1) 8-bit branches keep
    the float dequant path (routing gathers per-expert token groups; the
    decode hot path is N == 1), and 1-bit-only layouts go through
    :func:`_branch1_apply`'s packed arm instead — one copy of the packed
    trunk sequence."""
    if "w8_up" not in params:
        return False
    names = ("w8_gate", "w8_up", "w8_down") if glu else ("w8_up", "w8_down")
    if not all(
        is_stored_int8(params[n]) and params[n]["q"].shape[0] == 1
        for n in names
    ):
        return False
    if "w1_up" in params:
        names = ("w1_gate", "w1_up", "w1_down") if glu else ("w1_up", "w1_down")
        if not all(is_packed_1bit(params[n]) for n in names):
            return False
    return True


def _ffn_packed_apply(params, xf: Array, glu: bool, act_fn) -> Array:
    """Decoupled FFN on serving-layout weights (8-bit branch present, per
    :func:`_serving_ffn_layout`): integers stay packed in HBM and every
    linear runs through the kernel tier (``decoupled_first_gemm`` fuses
    each 1-bit/8-bit up-projection pair so the activations are read once;
    decode-shaped rows hit the fused-act-quant GEMV kernels).

    Feature scaling (alpha/beta) is applied to the branch *outputs*, exactly
    where the fake-quant path applies it, so the two paths share one
    quantization grid and differ only by integer-vs-float accumulation.
    """
    from repro.kernels import ops  # deferred: kernels are serving-only
    from repro.distributed.sharding import nmajor_axis

    has_1bit = "w1_up" in params
    dt = xf.dtype
    one = jnp.ones((), jnp.float32)

    # last (output) logical axis per serving weight — drives the N-major
    # shard_map island dispatch under an active mesh (no-op without one)
    _NAXIS = {"w1_gate": "ffn", "w1_up": "ffn", "w1_down": "embed",
              "w8_gate": "ffn8", "w8_up": "ffn8", "w8_down": "embed"}

    def bit_lin(name, h):
        w = params[name]
        ax = nmajor_axis(w["packed"].shape[-1], _NAXIS[name])
        if ax is not None:
            return ops.bit_linear_infer_nshard(
                h, w["packed"], w["scale"], ax, out_dtype=dt)
        return ops.bit_linear_infer(h, w["packed"], w["scale"], out_dtype=dt)

    def int8_lin(name, h):
        q, s = _int8_kernel_view(params[name])
        ax = nmajor_axis(q.shape[-1], _NAXIS[name])
        if ax is not None:
            return ops.int8_linear_infer_nshard(h, q, s, ax, out_dtype=dt)
        return ops.int8_linear_infer(h, q, s, out_dtype=dt)

    h1 = None
    if has_1bit:
        def pair(name1, name8):
            w1 = params[name1]
            q8, s8 = _int8_kernel_view(params[name8])
            ax = nmajor_axis(w1["packed"].shape[-1], _NAXIS[name1])
            if ax is not None:
                return ops.decoupled_first_gemm_nshard(
                    xf, w1["packed"], q8, w1["scale"], s8, one, one, ax,
                    out_dtype=dt,
                )
            return ops.decoupled_first_gemm(
                xf, w1["packed"], q8, w1["scale"], s8, one, one, out_dtype=dt
            )

        up1, up8 = pair("w1_up", "w8_up")
        if glu:
            g1, g8 = pair("w1_gate", "w8_gate")
            h1, h8 = act_fn(g1) * up1, act_fn(g8) * up8
        else:
            h1, h8 = act_fn(up1), act_fn(up8)
    else:
        up8 = int8_lin("w8_up", xf)
        h8 = act_fn(int8_lin("w8_gate", xf)) * up8 if glu else act_fn(up8)

    y = params["alpha"].astype(dt) * int8_lin("w8_down", h8)
    if h1 is not None:
        h1 = rmsnorm(params["subln"], h1)
        y = y + params["beta"].astype(dt) * bit_lin("w1_down", h1)
    return y


def _branch8_apply(params, x: Array, glu: bool, act_fn, qcfg: QuantConfig) -> Array:
    """Batched-over-experts 8-bit FFN: x (N, C, D) -> (N, C, D)."""
    wq = lambda w: (
        w if qcfg.mode == "none" else quantize_weights_int8_stacked(w)[0]
    ).astype(x.dtype)
    xq = maybe_quant_acts(x, qcfg)
    up = jnp.einsum("ncd,ndr->ncr", xq, wq(params["w8_up"]))
    if glu:
        gate = jnp.einsum("ncd,ndr->ncr", xq, wq(params["w8_gate"]))
        h = act_fn(gate) * up
    else:
        h = act_fn(up)
    hq = maybe_quant_acts(h, qcfg)
    return jnp.einsum("ncr,nrd->ncd", hq, wq(params["w8_down"]))


def _branch1_apply(params, x: Array, glu: bool, act_fn, qcfg: QuantConfig) -> Array:
    """1-bit FFN branch: x (T, D) -> (T, D).  Packed serving weights run the
    W1A8 kernel tier (this arm covers routed-8-bit configs whose 8-bit
    branch can't take the fused path; the common case goes through
    :func:`_ffn_packed_apply`)."""
    if all(
        is_packed_1bit(params[n])
        for n in (("w1_gate", "w1_up", "w1_down") if glu
                  else ("w1_up", "w1_down"))
    ):
        from repro.kernels import ops
        from repro.distributed.sharding import nmajor_axis

        def lin(name, h):
            w = params[name]
            ax = nmajor_axis(w["packed"].shape[-1],
                             "embed" if name == "w1_down" else "ffn")
            if ax is not None:
                return ops.bit_linear_infer_nshard(
                    h, w["packed"], w["scale"], ax, out_dtype=x.dtype)
            return ops.bit_linear_infer(
                h, w["packed"], w["scale"], out_dtype=x.dtype
            )

        up = lin("w1_up", x)
        h = act_fn(lin("w1_gate", x)) * up if glu else act_fn(up)
        h = rmsnorm(params["subln"], h)
        return lin("w1_down", h)
    if qcfg.qgather and qcfg.mode in ("bitnet", "pquant"):
        from repro.distributed.qgather import binarize_gather

        def wq(w, axes):
            return binarize_gather(w, axes).astype(x.dtype)
    else:
        def wq(w, axes):
            del axes
            return fake_quant_linear_weights(w, qcfg).astype(x.dtype)

    xq = maybe_quant_acts(x, qcfg)
    up = xq @ wq(params["w1_up"], ("embed", "ffn"))
    # SHARDING NOTE: SubLN + per-token AbsMax need full-d_ff statistics,
    # which breaks GSPMD's Megatron FFN pattern — without an explicit
    # constraint the partitioner replicates the whole FFN over `model`
    # (16x FLOPs).  Pinning the hidden activation to (batch, model) turns
    # the norm/absmax into cheap per-token cross-model all-reduces and
    # keeps both dots sharded.  (EXPERIMENTS.md §Perf, iteration 0.)
    up = shard_hint(up, "batch", "act_ffn")
    if glu:
        h = act_fn(xq @ wq(params["w1_gate"], ("embed", "ffn"))) * up
    else:
        h = act_fn(up)
    h = shard_hint(h, "batch", "act_ffn")
    if qcfg.mode != "none":
        # SubLN (BitNet placement) compresses the dynamic range ahead of the
        # down-projection's activation quantization; the FP baseline (LLaMA)
        # has no such norm, so skip it there for fidelity.
        h = rmsnorm(params["subln"], h)
    hq = maybe_quant_acts(h, qcfg)
    return hq @ wq(params["w1_down"], ("ffn", "embed"))


def decoupled_ffn(
    params,
    x: Array,
    qcfg: QuantConfig,
    glu: bool = True,
    activation: str = "silu",
    router_cfg: RouterConfig | None = None,
):
    """Apply the decoupled FFN.  x: (..., D).  Returns (y, aux_loss).

    aux_loss is zero unless the 8-bit branch is routed (N > 1).
    """
    act_fn = ACTIVATIONS[activation]
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    t = xf.shape[0]
    aux = jnp.zeros((), jnp.float32)

    if _serving_ffn_layout(params, glu):
        return _ffn_packed_apply(params, xf, glu, act_fn).reshape(*lead, d), aux

    y = jnp.zeros_like(xf)
    has_1bit = "w1_up" in params
    has_8bit = "w8_up" in params

    y1s = None
    if has_1bit:
        y1 = _branch1_apply(params, xf, glu, act_fn, qcfg)
        beta = params["beta"].astype(x.dtype) if has_8bit else jnp.asarray(1.0, x.dtype)
        y1s = beta * y1
        y = y + y1s

    if has_8bit:
        w8 = params["w8_up"]
        n = (w8["q"] if isinstance(w8, dict) else w8).shape[0]
        if n == 1:
            y8 = _branch8_apply(params, xf[None], glu, act_fn, qcfg)[0]
        else:
            assert router_cfg is not None and router_cfg.num_experts == n
            y8, aux = routing.route_and_apply(
                params["router"],
                xf,
                router_cfg,
                lambda xe: _branch8_apply(params, xe, glu, act_fn, qcfg),
            )
        y8s = params["alpha"].astype(x.dtype) * y8
        y = y + y8s
        if probes.active() and has_1bit:
            _tap_branch_norms(y1s, y8s)

    return y.reshape(*lead, d), aux


# ---------------------------------------------------------------------------
# Decoupled projection — the FFN-free adaptation (DESIGN.md §5, SSM family)
# ---------------------------------------------------------------------------


def init_decoupled_proj(
    key: Array,
    d_in: int,
    d_out: int,
    r: int,
    axes_in: str | None = "embed",
    axes_out: str | None = "ffn",
    num_experts: int = 1,
    dtype=jnp.float32,
    alpha_init: float = 2.0,
    beta_init: float = 0.2,
):
    """Decoupled single linear: dominant 1-bit W plus a compact 8-bit
    bottleneck branch (d_in -> r -> d_out) with feature scaling.

    This adapts the paper's FFN-hidden-dim split to layers that are plain
    projections (Mamba-2 in/out projections have no FFN hidden dim to
    split).  The 8-bit branch stays ``r``-narrow so the bits/weight budget
    matches the paper's Table 1 accounting.
    """
    ks = jax.random.split(key, 4)
    n = num_experts
    params = {
        "w1": jax.random.truncated_normal(ks[0], -3, 3, (d_in, d_out), dtype)
        * (d_in**-0.5),
        "w8_a": jax.random.truncated_normal(ks[1], -3, 3, (n, d_in, r), dtype)
        * (d_in**-0.5),
        "w8_b": jax.random.truncated_normal(ks[2], -3, 3, (n, r, d_out), dtype)
        * (r**-0.5),
        "alpha": jnp.asarray(alpha_init, dtype),
        "beta": jnp.asarray(beta_init, dtype),
    }
    axes = {
        "w1": (axes_in, axes_out),
        "w8_a": ("experts", axes_in, "ffn8"),
        "w8_b": ("experts", "ffn8", axes_out),
        "alpha": (),
        "beta": (),
    }
    if n > 1:
        rp, ra = routing.init_router(ks[3], d_in, RouterConfig(num_experts=n, top_k=1))
        params["router"], axes["router"] = rp, ra
    return params, axes


def decoupled_proj(
    params,
    x: Array,
    qcfg: QuantConfig,
    router_cfg: RouterConfig | None = None,
):
    """Apply a decoupled projection over (..., d_in). Returns (y, aux)."""
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    aux = jnp.zeros((), jnp.float32)

    if (
        is_packed_1bit(params["w1"])
        and is_stored_int8(params["w8_a"])
        and is_stored_int8(params["w8_b"])
        and params["w8_a"]["q"].shape[0] == 1
    ):
        # serving layout: fused dual-branch first GEMM (1-bit full projection
        # + 8-bit bottleneck in one activation read), then the INT8 second
        # bottleneck matmul — all on stored integers.
        from repro.kernels import ops

        dt = x.dtype
        one = jnp.ones((), jnp.float32)
        qa, sa = _int8_kernel_view(params["w8_a"])
        y1, h8 = ops.decoupled_first_gemm(
            xf, params["w1"]["packed"], qa, params["w1"]["scale"], sa,
            one, one, out_dtype=dt,
        )
        qb, sb = _int8_kernel_view(params["w8_b"])
        y8 = ops.int8_linear_infer(h8, qb, sb, out_dtype=dt)
        y = (
            params["beta"].astype(dt) * y1
            + params["alpha"].astype(dt) * y8
        )
        return y.reshape(*lead, -1), aux

    if is_packed_1bit(params["w1"]):
        # routed (N > 1) 8-bit branch below keeps the float path, but the
        # dominant 1-bit trunk still computes on packed integers
        from repro.kernels import ops

        y1 = ops.bit_linear_infer(
            xf, params["w1"]["packed"], params["w1"]["scale"],
            out_dtype=x.dtype,
        )
    else:
        xq = maybe_quant_acts(xf, qcfg)
        w1q = fake_quant_linear_weights(params["w1"], qcfg).astype(x.dtype)
        y1 = xq @ w1q
    y1s = params["beta"].astype(x.dtype) * y1
    y = y1s

    w8q = lambda w: (
        w if qcfg.mode == "none" else quantize_weights_int8_stacked(w)[0]
    ).astype(x.dtype)

    def branch(xe: Array) -> Array:  # xe: (N, C, d_in)
        xeq = maybe_quant_acts(xe, qcfg)
        h = jnp.einsum("ncd,ndr->ncr", xeq, w8q(params["w8_a"]))
        hq = maybe_quant_acts(h, qcfg)
        return jnp.einsum("ncr,nrd->ncd", hq, w8q(params["w8_b"]))

    w8a = params["w8_a"]
    n = (w8a["q"] if isinstance(w8a, dict) else w8a).shape[0]
    if n == 1:
        y8 = branch(xf[None])[0]
    else:
        assert router_cfg is not None
        y8, aux = routing.route_and_apply(params["router"], xf, router_cfg, branch)
    y8s = params["alpha"].astype(x.dtype) * y8
    y = y + y8s
    if probes.active():
        _tap_branch_norms(y1s, y8s)
    return y.reshape(*lead, -1), aux


def decoupled_ffn_flops(
    d_model: int, d_ff_1bit: int, r: int, glu: bool, tokens: int
) -> int:
    """Active-path MACs*2 per ``tokens`` tokens (top-1: one 8-bit branch)."""
    mats = 3 if glu else 2
    per_tok = mats * d_model * (d_ff_1bit + r) * 2
    return per_tok * tokens


def decoupled_param_counts(
    d_model: int, d_ff_1bit: int, r: int, num_experts: int, glu: bool
) -> tuple[int, int]:
    """(n_1bit_params, n_8bit_params) for effective-bits accounting."""
    mats = 3 if glu else 2
    n1 = mats * d_model * d_ff_1bit
    n8 = mats * d_model * r * num_experts
    return n1, n8
