"""Perturbation-based weight sensitivity (paper §2.3, Eq. 1-2) and the
*parameter democratization* score used to reproduce Figures 2 and 5a.

For weight w_ij of W (d_in, d_out) under calibration inputs X (T, d_in),

    s_ij = w_ij^2 / ( 2 * [(X^T X)^{-1}]_jj )      (generalized OBS)

with quant(w_ij) = 0 as the perturbation (the paper's choice for probing
the landscape).  Note the Hessian of ||XW - XW'||^2 w.r.t. a column of W is
H = X^T X (row-vector convention in the paper; our X is (tokens, features)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def input_hessian(x: Array, damp_frac: float = 1e-2) -> Array:
    """H = X^T X over a flat calibration batch, with GPTQ-style dampening."""
    xf = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    h = xf.T @ xf
    damp = damp_frac * jnp.mean(jnp.diag(h)) + 1e-8
    return h + damp * jnp.eye(h.shape[0], dtype=h.dtype)


def obs_sensitivity(w: Array, x: Array, damp_frac: float = 1e-2) -> Array:
    """Per-weight OBS sensitivity map, same shape as ``w`` (d_in, d_out)."""
    h = input_hessian(x, damp_frac)
    h_inv_diag = jnp.diag(jnp.linalg.inv(h))  # (d_in,)
    return (w.astype(jnp.float32) ** 2) / (2.0 * h_inv_diag[:, None] + 1e-12)


def democratization_score(sens: Array, eps: float = 1e-12) -> Array:
    """Scalar in (0, 1]: how *uniform* the sensitivity landscape is.

    Normalised entropy of the sensitivity distribution: 1.0 means perfectly
    democratized (all weights equally sensitive, the BitNet pathology);
    small values mean a differentiated landscape (FP16 / pQuant behaviour).
    """
    s = sens.reshape(-1).astype(jnp.float32)
    p = s / (jnp.sum(s) + eps)
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log(p + eps), 0.0))
    return ent / jnp.log(jnp.asarray(float(s.size)))


def sensitivity_kurtosis(sens: Array) -> Array:
    """Excess kurtosis of log-sensitivity — heavy tails = differentiated
    landscape.  Complementary view to the entropy score."""
    ls = jnp.log(sens.reshape(-1).astype(jnp.float32) + 1e-20)
    mu = jnp.mean(ls)
    sd = jnp.std(ls) + 1e-12
    return jnp.mean(((ls - mu) / sd) ** 4) - 3.0


def top_fraction_mass(sens: Array, frac: float = 0.01) -> Array:
    """Share of total sensitivity mass held by the top ``frac`` of weights.

    FP16 models concentrate a large share in few weights; democratized 1-bit
    models spread it thin.  (Used in bench_sensitivity.)
    """
    s = jnp.sort(sens.reshape(-1).astype(jnp.float32))[::-1]
    k = max(1, int(s.size * frac))
    return jnp.sum(s[:k]) / (jnp.sum(s) + 1e-12)


def max_pool_2d(sens: Array, out_shape: tuple[int, int]) -> Array:
    """Down-sample a sensitivity map by max-pooling, as the paper does for
    visualisation (Figure 2)."""
    m, n = sens.shape
    om, on = out_shape
    pm, pn = m // om, n // on
    trimmed = sens[: om * pm, : on * pn]
    return trimmed.reshape(om, pm, on, pn).max(axis=(1, 3))
