"""Sort-based top-k token->expert dispatch, shared by pQuant's routable
8-bit branches (top-1, paper §3.3) and the DeepSeek-style MoE architectures
(top-6 with shared experts).

Why sort-based: the classic one-hot dispatch einsum (Switch/MTF) costs
O(T * N * C * d) matmul FLOPs purely to move tokens.  A sort-based gather
moves the same tokens with zero matmul FLOPs, so the compiled HLO FLOP count
stays close to MODEL_FLOPS (this shows up directly in the roofline's
"useful-FLOPs ratio").  Shapes stay static: experts have a fixed capacity
``C = ceil(T * k / N * capacity_factor)`` and overflow tokens are dropped
(their combine weight is zeroed), matching Switch semantics.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.telemetry import probes

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    num_experts: int
    top_k: int = 1
    capacity_factor: float = 1.25
    # z-loss / aux load-balancing loss weights (Shazeer-style)
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3
    dtype: str = "float32"


def init_router(key: Array, d_model: int, cfg: RouterConfig):
    w = jax.random.truncated_normal(
        key, -3.0, 3.0, (d_model, cfg.num_experts), jnp.float32
    ) * (d_model**-0.5)
    return {"w": w}, {"w": ("embed", None)}


def router_probs(params, x: Array) -> Array:
    """Softmax router logits -> probs, computed in fp32 for stability."""
    logits = x.astype(jnp.float32) @ params["w"].astype(jnp.float32)
    return jax.nn.softmax(logits, axis=-1), logits


def expert_capacity(num_tokens: int, cfg: RouterConfig) -> int:
    cap = int(num_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    # keep capacity MXU-friendly and nonzero
    return max(8, -(-cap // 8) * 8)


def topk_dispatch(
    probs: Array,
    cfg: RouterConfig,
):
    """Compute dispatch metadata for a flat token batch.

    probs: (T, N) router probabilities.
    Returns a dict with:
      expert_index   (T, k)  chosen expert per token per slot
      combine_weight (T, k)  gate prob, zeroed for dropped tokens
      buffer_token   (N, C)  flat token id feeding each expert slot
                             (T used as the OOB/padding sentinel)
      buffer_slot    (T, k)  position of (token, slot) within its expert
                             buffer, C when dropped
      aux_loss       scalar  load-balancing auxiliary loss
    """
    t, n = probs.shape
    k = cfg.top_k
    c = expert_capacity(t, cfg)

    gate_vals, expert_index = jax.lax.top_k(probs, k)  # (T, k)

    # --- position of each (token, slot) within its expert, via sort ---
    flat_expert = expert_index.reshape(-1)  # (T*k,)
    # stable sort by expert id; ties keep token order (deterministic)
    order = jnp.argsort(flat_expert, stable=True)  # (T*k,)
    sorted_expert = flat_expert[order]
    # rank within expert = index within the sorted run
    ar = jnp.arange(t * k)
    seg_start = jnp.searchsorted(sorted_expert, jnp.arange(n), side="left")
    rank_sorted = ar - seg_start[sorted_expert]
    # scatter ranks back to (token, slot) order
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    rank = rank.reshape(t, k)

    kept = rank < c
    combine_weight = jnp.where(kept, gate_vals, 0.0)
    buffer_slot = jnp.where(kept, rank, c)

    # --- expert buffers: (N, C) flat-token indices, sentinel = t ---
    buffer_token = jnp.full((n, c), t, jnp.int32)
    tok_ids = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
    buffer_token = buffer_token.at[
        flat_expert, rank.reshape(-1)
    ].set(jnp.where(kept.reshape(-1), tok_ids, t), mode="drop")

    # --- aux load-balancing loss (Shazeer/Switch): N * sum(f_i * p_i) ---
    me = jnp.mean(probs, axis=0)  # mean prob per expert
    one_hot_top1 = jax.nn.one_hot(expert_index[:, 0], n, dtype=probs.dtype)
    ce = jnp.mean(one_hot_top1, axis=0)  # fraction routed (top-1 slot)
    aux_loss = jnp.sum(me * ce) * n * cfg.aux_loss_weight

    if probes.active() and n > 1:
        # normalized load entropy (1 = balanced, 0 = collapsed) over the
        # realized top-1 assignment fractions — QAT probe qat_router_entropy
        cf = ce.astype(jnp.float32)
        ent = -jnp.sum(cf * jnp.log(cf + 1e-12)) / jnp.log(float(n))
        probes.add_mean("router_entropy", ent, 1.0)

    return {
        "expert_index": expert_index,
        "combine_weight": combine_weight.astype(probs.dtype),
        "buffer_token": buffer_token,
        "buffer_slot": buffer_slot,
        "capacity": c,
        "aux_loss": aux_loss,
    }


def dispatch_gather(x: Array, dispatch) -> Array:
    """Gather token activations into expert buffers.

    x: (T, D).  Returns (N, C, D); dropped/padded slots read zeros.
    """
    t, d = x.shape
    xz = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)  # sentinel row
    return xz[dispatch["buffer_token"]]  # (N, C, D)


def combine_scatter(y_experts: Array, dispatch, num_tokens: int) -> Array:
    """Scatter expert outputs back to token order, weighted by gate prob.

    y_experts: (N, C, D) -> (T, D)
    """
    n, c, d = y_experts.shape
    k = dispatch["expert_index"].shape[1]
    # per (token, slot): gather its expert output row
    flat_e = dispatch["expert_index"].reshape(-1)  # (T*k,)
    flat_s = dispatch["buffer_slot"].reshape(-1)  # (T*k,) == c when dropped
    yz = jnp.concatenate(
        [y_experts, jnp.zeros((n, 1, d), y_experts.dtype)], axis=1
    )  # (N, C+1, D)
    rows = yz[flat_e, flat_s]  # (T*k, D)
    w = dispatch["combine_weight"].reshape(-1, 1).astype(rows.dtype)
    out = (rows * w).reshape(num_tokens, k, d)
    return jnp.sum(out, axis=1)


# ---------------------------------------------------------------------------
# Einsum (one-hot) dispatch — the sharding-friendly alternative
# ---------------------------------------------------------------------------


def einsum_dispatch_combine(probs: Array, cfg: RouterConfig, group_size: int):
    """Grouped one-hot dispatch (Switch/MTF style).

    Why it exists: the sort-based dispatch's gathers from token-sharded to
    expert-sharded buffers force the SPMD partitioner into full-activation
    all-gathers (measured: ~240 GiB/dev for deepseek-moe-16b train_4k).
    With one-hot einsums, dispatch contracts locally (tokens stay on their
    data shard, experts on their model shard) and only the combine einsum
    all-reduces one activation-sized tensor over `model` per layer — the
    same cost as a Megatron FFN.  Price: the (G, S, E, C) combine tensor
    and ~O(S*k*cf*D) extra MACs per token, bounded by ``group_size``.

    probs: (T, E) with T divisible by group_size.
    Returns (combine (G,S,E,C), dispatch (G,S,E,C), aux_loss).
    """
    t, e = probs.shape
    k = cfg.top_k
    s = group_size
    assert t % s == 0, (t, s)
    g = t // s
    pg = probs.reshape(g, s, e)
    gate, idx = jax.lax.top_k(pg, k)  # (g, s, k)

    # rank of each (token, slot) within its expert, ordered by (s, k)
    oh = jax.nn.one_hot(idx, e, dtype=jnp.float32).reshape(g, s * k, e)
    pos_before = jnp.cumsum(oh, axis=1) - oh
    rank = jnp.sum(pos_before * oh, axis=-1).astype(jnp.int32)  # (g, s*k)
    c = expert_capacity(s, cfg)
    kept = (rank < c).reshape(g, s, k)
    rank = rank.reshape(g, s, k)

    combine = jnp.zeros((g, s, e, c), probs.dtype)
    gi = jnp.arange(g)[:, None, None]
    si = jnp.arange(s)[None, :, None]
    combine = combine.at[gi, si, idx, jnp.where(kept, rank, 0)].add(
        jnp.where(kept, gate, 0.0)
    )
    dispatch = (combine > 0).astype(probs.dtype)

    # aux load-balancing loss (same definition as the sort path)
    me = jnp.mean(probs, axis=0)
    one_hot_top1 = jax.nn.one_hot(idx.reshape(-1, k)[:, 0], e, dtype=probs.dtype)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = jnp.sum(me * ce) * e * cfg.aux_loss_weight
    return combine, dispatch, aux


def route_and_apply(
    router_params,
    x: Array,
    cfg: RouterConfig,
    expert_fn: Callable[[Array], Array],
):
    """Full routed application over a flat token batch.

    expert_fn: (N, C, D_in) -> (N, C, D_out) batched-over-experts FFN.
    Returns (y, aux_loss).
    """
    t, _ = x.shape
    probs, logits = router_probs(router_params, x)
    dispatch = topk_dispatch(probs, cfg)
    xe = dispatch_gather(x, dispatch)
    ye = expert_fn(xe)
    y = combine_scatter(ye, dispatch, t)
    # router z-loss discourages logit blow-up
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * cfg.router_z_weight
    return y, dispatch["aux_loss"] + z.astype(dispatch["aux_loss"].dtype)
