"""Roofline summary benchmark — surfaces the dry-run-derived terms
(results/roofline_baseline.json) as CSV rows, one per (arch x shape) cell.
``us_per_call`` is the bound step time (max of the three terms)."""

import json
import os

from benchmarks.common import row

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def run(path: str | None = None) -> list:
    path = path or os.path.join(RESULTS, "roofline_baseline.json")
    if not os.path.exists(path):
        row("roofline/missing", 0.0, f"run launch/roofline.py first ({path})")
        return []
    recs = json.load(open(path))
    ok = [r for r in recs if "error" not in r]
    for r in ok:
        bound_us = r["step_time_lower_bound_s"] * 1e6
        row(
            f"roofline/{r['arch']}/{r['shape']}",
            bound_us,
            f"bottleneck={r['bottleneck']};compute_ms={r['compute_s']*1e3:.1f};"
            f"memory_ms={r['memory_s']*1e3:.1f};coll_ms={r['collective_s']*1e3:.1f};"
            f"useful_flops={r['useful_flops_ratio']:.2f};"
            f"peakGiB={r['memory']['peak_bytes']/2**30:.2f}",
        )
    n_bad = len(recs) - len(ok)
    row("roofline/cells", 0.0, f"ok={len(ok)};failed={n_bad}")
    return ok


if __name__ == "__main__":
    run()
