"""Figure 10 — training stability at aggressive hyperparameters.

The paper observes BitNet training spikes/diverges at large batch+LR and
needs checkpoint rollbacks, while pQuant stays stable.  We train both at a
deliberately hot LR and count instability events (non-finite or >2x loss
spikes).
"""

import time

import numpy as np

from benchmarks.common import quick_train, row, tiny_config


def _spikes(hist) -> int:
    losses = [h["loss"] for h in hist]
    spikes = sum(1 for a, b in zip(losses, losses[1:])
                 if not np.isfinite(b) or b > a * 2.0)
    return spikes


def run(steps: int = 100) -> dict:
    out = {}
    for mode in ("bitnet", "pquant"):
        t0 = time.perf_counter()
        hist, tr = quick_train(tiny_config(mode), steps=steps, peak_lr=2e-2)
        us = (time.perf_counter() - t0) * 1e6 / max(len(hist), 1)
        out[mode] = {"spikes": _spikes(hist), "recoveries": tr.recoveries,
                     "final": hist[-1]["loss"] if hist else float("nan")}
        row(f"fig10/stability/{mode}", us,
            f"spikes={out[mode]['spikes']};final={out[mode]['final']:.3f}")
    row("fig10/pquant_no_less_stable", 0.0,
        f"ok={out['pquant']['spikes'] <= out['bitnet']['spikes']}")
    return out


if __name__ == "__main__":
    run()
