"""Figure 10 — training stability at aggressive hyperparameters.

The paper observes BitNet training spikes/diverges at large batch+LR and
needs checkpoint rollbacks, while pQuant stays stable.  We train both at a
deliberately hot LR and count instability events (non-finite or >2x loss
spikes).

Under ``smoke=True`` the pQuant leg runs with QAT health probes on and
writes the trainer's telemetry artifacts (``metrics_out`` — the
``validate_snapshot``-schema metrics snapshot; ``trace_out`` — the JSONL
lifecycle trace), so CI can archive a real train-run trace per commit.
"""

import json
import time

import numpy as np

from benchmarks.common import quick_train, row, tiny_config


def _steps_only(hist):
    # the history interleaves per-step records with lifecycle events
    # (recovery/restore); stability stats only read the step records
    return [h for h in hist if "loss" in h and "event" not in h]


def _spikes(hist) -> int:
    losses = [h["loss"] for h in _steps_only(hist)]
    spikes = sum(1 for a, b in zip(losses, losses[1:])
                 if not np.isfinite(b) or b > a * 2.0)
    return spikes


def run(steps: int = 100, smoke: bool = False,
        metrics_out: str | None = None, trace_out: str | None = None) -> dict:
    if smoke:
        steps = min(steps, 12)
    out = {}
    for mode in ("bitnet", "pquant"):
        tcfg_kw = {}
        if mode == "pquant" and (smoke or metrics_out or trace_out):
            tcfg_kw = {"probes": True, "sensitivity_every": max(steps // 2, 1),
                       "trace_path": trace_out}
        t0 = time.perf_counter()
        hist, tr = quick_train(tiny_config(mode), steps=steps, peak_lr=2e-2,
                               **tcfg_kw)
        us = (time.perf_counter() - t0) * 1e6 / max(len(hist), 1)
        step_hist = _steps_only(hist)
        out[mode] = {"spikes": _spikes(hist), "recoveries": tr.recoveries,
                     "final": step_hist[-1]["loss"] if step_hist
                     else float("nan")}
        row(f"fig10/stability/{mode}", us,
            f"spikes={out[mode]['spikes']};final={out[mode]['final']:.3f}")
        if mode == "pquant" and metrics_out:
            with open(metrics_out, "w") as f:
                json.dump(tr.snapshot(), f, indent=2)
    row("fig10/pquant_no_less_stable", 0.0,
        f"ok={out['pquant']['spikes'] <= out['bitnet']['spikes']}")
    return out


if __name__ == "__main__":
    run()
