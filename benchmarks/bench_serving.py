"""Serving-tier benchmark: lockstep vs continuous batching (with and
without chunked prefill) under a Poisson arrival trace.

Rows (``name,us_per_call,derived`` — us_per_call is p50 request latency):
  serving/lockstep      fixed batches on DecodeEngine: a batch forms in
                        arrival order, waits for its last member, decodes
                        the full budget for everyone (prompts left-padded
                        to the batch width — the "padding games" the
                        continuous engine removes)
  serving/continuous    ContinuousBatchingEngine: per-request admission at
                        chunk boundaries over the paged KV pool, one-shot
                        admission prefill
  serving/continuous_chunked  same engine with token-budget chunked
                        prefill (``prefill_chunk``): an admitting prompt
                        streams in as bounded forward_chunk slices, so a
                        long prompt no longer stalls every live decode
                        stream — the head-of-line latency this tier exists
                        to remove
  serving/overload      2x-capacity Poisson trace against the bounded
                        admission queue + per-request deadlines (the
                        robustness layer): shed rate, deadline-miss rate
                        and surviving tok/s — graceful degradation, not
                        raw throughput
  serving/continuous_packed  continuous engine on
                        quantize_params_for_serving(packed=True) weights —
                        decode chunks execute the W1A8 GEMV kernel tier
                        (interpret mode on CPU: a wiring check there, a
                        bandwidth story on TPU)
  serving/pool          paged-pool accounting for the continuous run
  serving/paged_long_gather   long-context Poisson trace (every prompt is
                        the long one) on the continuous+chunked engine
                        with the gather+SDPA read path (REPRO_PAGED_ATTN=0)
  serving/paged_long_kernel   the same trace with the Pallas block-table
                        paged-attention kernel (REPRO_PAGED_ATTN=1); its
                        derived column carries decode tok/s for BOTH paths
                        plus their ratio — the long-context read-path
                        comparison ``BENCH_serving.json`` tracks per run
                        (interpret mode on CPU: a wiring/parity check
                        there — the kernel-beats-gather claim is a TPU
                        statement, the CPU interpreter is expected to
                        lose)

Every serving row carries tok_s (useful tokens over the trace makespan),
request-latency p50/p95, TTFT (time-to-first-token) p50/p95 and p95
inter-token latency, so one CSV captures throughput, tail latency AND the
decode-cadence story chunked prefill is about.  The continuous-tier rows
source their latency percentiles from the ENGINE's own metrics snapshot
(the ``ttft_seconds`` / ``itl_seconds`` / ``request_latency_seconds``
histograms) rather than recomputing them host-side, and the overload row
reads ``finished_by_reason`` — the bench measures what the engine reports,
with a cross-check assert that the engine's TTFT p50 bucket brackets the
exactly-computed percentile (guards the histogram wiring).
``--metrics-out`` / ``--trace-out`` dump the continuous run's snapshot
(schema-validated) and its structured JSONL request trace.  The trace
always includes at least one long prompt — that is the request that
freezes the no-chunking decode cadence.  ``--smoke`` shrinks the trace to
a seconds-scale CI subset (compile-dominated: the numbers are a wiring
check there, not a scheduling signal).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def make_trace(n: int, seed: int, mean_gap_s: float, prompt_lens, budgets):
    """Poisson arrivals: exponential inter-arrival gaps, ragged prompts and
    budgets cycled deterministically per seed."""
    rng = np.random.default_rng(seed)
    t = 0.0
    trace = []
    for i in range(n):
        t += float(rng.exponential(mean_gap_s))
        s = int(prompt_lens[i % len(prompt_lens)])
        trace.append(
            dict(
                uid=i,
                prompt=rng.integers(3, 250, size=s).astype(np.int32),
                budget=int(budgets[i % len(budgets)]),
                seed=i,
                arrival=t,
            )
        )
    return trace


def _pctl(xs_s, q):
    return float(np.percentile(np.asarray(xs_s) * 1e3, q))


def _latency_fields(lat, ttft, itl):
    """Shared derived-column block: request latency, TTFT, inter-token."""
    return (
        f"p50_ms={_pctl(lat, 50):.1f};p95_ms={_pctl(lat, 95):.1f};"
        f"ttft_p50_ms={_pctl(ttft, 50):.1f};ttft_p95_ms={_pctl(ttft, 95):.1f};"
        f"itl_p95_ms={_pctl(itl, 95):.2f}"
    )


def _snapshot_latency_fields(snap):
    """The same derived-column block sourced from the engine's own metrics
    snapshot (its TTFT/ITL/latency histograms) instead of host-side
    recomputation — the engine's telemetry IS the reported number."""
    h = snap["histograms"]
    lat = h["request_latency_seconds"]
    ttft = h["ttft_seconds"]
    itl = h["itl_seconds"]
    return (
        f"p50_ms={lat['p50'] * 1e3:.1f};p95_ms={lat['p95'] * 1e3:.1f};"
        f"ttft_p50_ms={ttft['p50'] * 1e3:.1f};"
        f"ttft_p95_ms={ttft['p95'] * 1e3:.1f};"
        f"itl_p95_ms={itl['p95'] * 1e3:.2f}"
    )


def _check_engine_ttft(eng, ttft_exact) -> None:
    """Cross-check: the bucket the engine's TTFT histogram puts the p50 in
    must contain the exactly-computed p50 of the same requests (an
    inverted-CDF quantile, so both sides name an actual observation).
    Bucket edges are the honest error bar of a fixed-bucket histogram —
    this guards the wiring (wrong clock, wrong anchor, missed observe),
    not sub-bucket resolution."""
    hist = eng.metrics.histogram("ttft_seconds")
    exact = float(
        np.quantile(np.asarray(ttft_exact), 0.5, method="inverted_cdf")
    )
    lo, hi = hist.quantile_bounds(0.5)
    if not lo <= exact <= hi:
        raise AssertionError(
            f"engine TTFT p50 bucket ({lo:.6g}, {hi:.6g}] does not contain "
            f"the bench-computed p50 {exact:.6g}s — histogram wiring broke"
        )


def _run_lockstep(server, trace, num_slots, scfg, t0, pad_to):
    """Arrival-order batches of num_slots; each batch waits for its last
    member, prompts are left-padded to ``pad_to`` (pass the full-trace
    width so warm-up and timed runs compile the same shape), and every
    member burns the full compiled budget.  TTFT is the whole-batch
    completion (generate is one blocking call — no earlier tokens exist),
    and ITL spreads the whole call span — prefill included, since the
    fused program exposes no per-token timestamps — over the token
    budget; the continuous tiers measure ITL from first_token_at, so the
    cross-tier ITL comparison flatters lockstep less than it seems."""
    import jax.numpy as jnp
    lat, ttft, itl = [], [], []
    done_tokens = 0
    for i in range(0, len(trace), num_slots):
        batch = trace[i : i + num_slots]
        while len(batch) < num_slots:  # ragged tail: repeat to batch width
            batch = batch + [batch[-1]]
        start = max(r["arrival"] for r in batch)
        while time.perf_counter() - t0 < start:
            time.sleep(1e-4)
        prompts = np.zeros((num_slots, pad_to), np.int32)
        for j, r in enumerate(batch):
            prompts[j, pad_to - len(r["prompt"]) :] = r["prompt"]
        launch = time.perf_counter() - t0
        server.generate(jnp.asarray(prompts), scfg, seed=batch[0]["seed"])
        finish = time.perf_counter() - t0
        for r in trace[i : i + num_slots]:
            lat.append(finish - r["arrival"])
            ttft.append(finish - r["arrival"])
            itl.append((finish - launch) / max(1, r["budget"]))
            done_tokens += r["budget"]
    return lat, ttft, itl, done_tokens, time.perf_counter() - t0


def _run_continuous(engine, trace, t0):
    """Returns (lat, ttft, itl, done_tokens, span, finished) — finished is
    the FinishedRequest list, for callers that derive extra stats."""
    for r in trace:
        engine.submit(
            r["prompt"], max_new_tokens=r["budget"], seed=r["seed"],
            uid=r["uid"], arrival=r["arrival"],
        )
    fin = engine.run()
    lat = [f.finished_at - f.arrival for f in fin]
    ttft = [f.first_token_at - f.arrival for f in fin]
    itl = [
        (f.finished_at - f.first_token_at) / max(1, len(f.tokens) - 1)
        for f in fin
    ]
    done_tokens = sum(len(f.tokens) for f in fin)
    return lat, ttft, itl, done_tokens, time.perf_counter() - t0, fin


def _decode_tok_s(fin) -> float:
    """Decode-phase throughput: post-first tokens over the decode span
    (first token sampled -> trace drained) — the number the paged read
    path moves, prefill excluded."""
    toks = sum(max(0, len(f.tokens) - 1) for f in fin)
    span = max(f.finished_at for f in fin) - min(f.first_token_at for f in fin)
    return toks / max(span, 1e-9)


def _run_long_context(params, cfg, num_slots, scfg, trace, block, chunk,
                      prefill_chunk, max_len, clock_box, enabled: bool):
    """One long-context run with the paged-attention kernel forced on or
    off (fresh engine per setting: the dispatch decision is baked into the
    engine's compiled programs at trace time).  Returns (stats, fin)."""
    import os

    from repro.serve.scheduler import ContinuousBatchingEngine

    prev = os.environ.get("REPRO_PAGED_ATTN")
    os.environ["REPRO_PAGED_ATTN"] = "1" if enabled else "0"
    try:
        clock = lambda: time.perf_counter() - clock_box["t0"]  # noqa: E731
        eng = ContinuousBatchingEngine(
            params, cfg, num_slots=num_slots, max_len=max_len, scfg=scfg,
            layout="paged", block_size=block, chunk=chunk,
            prefill_chunk=prefill_chunk, clock=clock,
        )
        clock_box["t0"] = time.perf_counter()
        _run_continuous(eng, [dict(r, arrival=0.0) for r in trace],
                        clock_box["t0"])  # warm the compiled programs
        clock_box["t0"] = t0 = time.perf_counter()
        lat, ttft, itl, toks, span, fin = _run_continuous(eng, trace, t0)
        return dict(lat=lat, ttft=ttft, itl=itl,
                    tok_s=toks / span, decode_tok_s=_decode_tok_s(fin)), fin
    finally:
        if prev is None:
            os.environ.pop("REPRO_PAGED_ATTN", None)
        else:
            os.environ["REPRO_PAGED_ATTN"] = prev


def run(smoke: bool = False, num_slots: int | None = None,
        n_requests: int | None = None, seed: int = 0,
        metrics_out: str | None = None, trace_out: str | None = None):
    import jax
    from benchmarks.common import row, tiny_config
    from repro.models import api
    from repro.serve.engine import DecodeEngine, SamplerConfig
    from repro.serve.metrics import validate_snapshot
    from repro.serve.scheduler import ContinuousBatchingEngine
    from repro.serve.tracing import JsonlSink, RequestTracer

    num_slots = num_slots or (2 if smoke else 4)
    n_requests = n_requests or (6 if smoke else 24)
    # at least one LONG prompt per cycle: the request whose one-shot
    # admission prefill stalls every live stream without chunking
    prompt_lens = (4, 20, 6) if smoke else (8, 64, 12, 16)
    budgets = (4, 6) if smoke else (8, 16, 24)
    chunk = 4 if smoke else 8
    prefill_chunk = 4 if smoke else 8
    cfg = tiny_config(d_model=64, d_ff=128, n_layers=2, vocab=256)
    max_len = max(prompt_lens) + max(budgets)
    block = 4
    max_len += (-max_len) % block
    params, axes = api.init_model(jax.random.PRNGKey(0), cfg)
    scfg = SamplerConfig(temperature=0.0, top_k=0,
                         max_new_tokens=max(budgets))
    trace = make_trace(n_requests, seed, 0.02 if smoke else 0.05,
                       prompt_lens, budgets)

    box = {"t0": time.perf_counter()}
    clock = lambda: time.perf_counter() - box["t0"]  # noqa: E731
    # engines are built one at a time and dropped before the next so only
    # ONE paged KV pool is ever device-resident
    eng = ContinuousBatchingEngine(
        params, cfg, num_slots=num_slots, max_len=max_len, scfg=scfg,
        layout="paged", block_size=block, chunk=chunk, clock=clock,
    )
    server = DecodeEngine(params, cfg, max_len)

    # warm each path on an arrival-0 copy of the trace so the timed runs
    # measure scheduling, not XLA compiles (the engines are reused: their
    # compilation caches carry over)
    t0 = box["t0"]
    warm = [dict(r, arrival=0.0) for r in trace]
    pad_to = max(len(r["prompt"]) for r in trace)
    _run_lockstep(server, warm[: num_slots], num_slots, scfg, t0, pad_to)
    _run_continuous(eng, warm, t0)
    # warm-run hygiene: compiled programs stay, every metric (counters,
    # gauges, the latency histograms the rows are sourced from) zeroes
    eng.metrics.reset()
    tracer = None
    if trace_out is not None:
        tracer = RequestTracer(JsonlSink(trace_out))
        eng.tracer = tracer  # attach post-warm: the trace is the timed run

    rows = []
    t0 = time.perf_counter()
    lat, ttft, itl, toks, span = _run_lockstep(
        server, trace, num_slots, scfg, t0, pad_to
    )
    rows.append(row(
        "serving/lockstep", _pctl(lat, 50) * 1e3,
        f"tok_s={toks / span:.1f};" + _latency_fields(lat, ttft, itl),
    ))

    box["t0"] = t0 = time.perf_counter()
    clat, cttft, citl, ctoks, cspan, _ = _run_continuous(eng, trace, t0)
    _check_engine_ttft(eng, cttft)
    csnap = eng.snapshot()
    if tracer is not None:
        eng.tracer = None
        tracer.close()
    if metrics_out is not None:
        validate_snapshot(csnap)
        with open(metrics_out, "w", encoding="utf-8") as f:
            json.dump(csnap, f, indent=1, sort_keys=True)
    c_itl_p95_ms = csnap["histograms"]["itl_seconds"]["p95"] * 1e3
    rows.append(row(
        "serving/continuous",
        csnap["histograms"]["request_latency_seconds"]["p50"] * 1e6,
        f"tok_s={ctoks / cspan:.1f};"
        + _snapshot_latency_fields(csnap)
        + f";p50_speedup={_pctl(lat, 50) / max(_pctl(clat, 50), 1e-9):.2f}x",
    ))
    rows.append(row(
        "serving/pool", 0.0,
        f"blocks={eng.num_blocks};free={eng.allocator.free_count};"
        f"preemptions={eng.preemptions};host_transfers={eng.host_transfers}",
    ))

    del eng
    ceng = ContinuousBatchingEngine(
        params, cfg, num_slots=num_slots, max_len=max_len, scfg=scfg,
        layout="paged", block_size=block, chunk=chunk,
        prefill_chunk=prefill_chunk, clock=clock,
    )
    box["t0"] = time.perf_counter()
    _run_continuous(ceng, [dict(r, arrival=0.0) for r in warm], box["t0"])
    ceng.metrics.reset()
    box["t0"] = t0 = time.perf_counter()
    klat, kttft, kitl, ktoks, kspan, _ = _run_continuous(ceng, trace, t0)
    _check_engine_ttft(ceng, kttft)
    ksnap = ceng.snapshot()
    k_itl_p95_ms = ksnap["histograms"]["itl_seconds"]["p95"] * 1e3
    rows.append(row(
        "serving/continuous_chunked",
        ksnap["histograms"]["request_latency_seconds"]["p50"] * 1e6,
        f"tok_s={ktoks / kspan:.1f};"
        + _snapshot_latency_fields(ksnap)
        + f";prefill_chunk={prefill_chunk}"
        + f";itl_p95_vs_continuous="
        + f"{c_itl_p95_ms / max(k_itl_p95_ms, 1e-9):.2f}x",
    ))

    # -- overload: 2x-capacity Poisson load against the robustness layer --
    # arrivals/deadlines run on the engine's virtual clock (1 tick per
    # engine step ~ `chunk` decode tokens per slot), so the offered load
    # is set analytically: mean budget per arrival gap = 2x the pool's
    # token service rate.  The bounded queue + per-request deadlines must
    # shed — this row tracks HOW MUCH is shed/missed and what throughput
    # survives, the graceful-degradation trajectory BENCH_serving.json
    # follows per PR.
    del ceng
    over_n = 12 if smoke else 48
    mean_budget = float(np.mean(budgets))
    over_gap = mean_budget / (2.0 * num_slots * chunk)  # ticks
    deadline_slack = 6.0 if smoke else 10.0  # ticks after arrival
    otrace = make_trace(over_n, seed + 2, over_gap, prompt_lens, budgets)
    oeng = ContinuousBatchingEngine(
        params, cfg, num_slots=num_slots, max_len=max_len, scfg=scfg,
        layout="paged", block_size=block, chunk=chunk,
        max_queue=2 * num_slots, overload_policy="shed_oldest",
    )
    for r in otrace[:num_slots]:  # warm the compiled programs
        oeng.submit(r["prompt"], max_new_tokens=r["budget"], seed=r["seed"],
                    uid=r["uid"], arrival=0.0)
    oeng.run()
    oeng.metrics.reset()  # warm finishes must not count into the rates
    base = oeng.now()  # the virtual clock keeps ticking across runs
    wall0 = time.perf_counter()
    for r in otrace:
        oeng.submit(
            r["prompt"], max_new_tokens=r["budget"], seed=r["seed"],
            uid=over_n + r["uid"], arrival=base + r["arrival"],
            deadline=base + r["arrival"] + deadline_slack,
        )
    ofin = oeng.run()
    wall = time.perf_counter() - wall0
    otoks = sum(len(f.tokens) for f in ofin)
    # shed/miss/serve rates come from the engine's own per-reason
    # counters, not a host-side recount of the FinishedRequest list
    fbr = oeng.finished_by_reason
    assert sum(fbr.values()) == len(ofin) == over_n, (fbr, len(ofin))
    shed = fbr["shed"] + fbr["rejected"]
    missed = fbr["deadline"]
    served = fbr["stop"] + fbr["length"]
    rows.append(row(
        "serving/overload", 0.0,
        f"tok_s={otoks / max(wall, 1e-9):.1f};"
        f"shed_rate={shed / over_n:.2f};"
        f"deadline_miss_rate={missed / over_n:.2f};"
        f"served_rate={served / over_n:.2f};"
        f"offered_x_capacity=2.0;max_queue={2 * num_slots};"
        f"deadline_slack_ticks={deadline_slack:g};"
        f"free_blocks={oeng.allocator.free_count}/{oeng.num_blocks}",
    ))

    from repro.train.quantized_serving import quantize_params_for_serving

    del oeng, server
    qparams, _ = quantize_params_for_serving(params, axes, cfg, packed=True)
    peng = ContinuousBatchingEngine(
        qparams, cfg, num_slots=num_slots, max_len=max_len, scfg=scfg,
        layout="paged", block_size=block, chunk=chunk, clock=clock,
    )
    box["t0"] = time.perf_counter()
    _run_continuous(peng, [dict(r, arrival=0.0) for r in warm], box["t0"])
    peng.metrics.reset()
    box["t0"] = t0 = time.perf_counter()
    plat, pttft, pitl, ptoks, pspan, _ = _run_continuous(peng, trace, t0)
    _check_engine_ttft(peng, pttft)
    psnap = peng.snapshot()
    rows.append(row(
        "serving/continuous_packed",
        psnap["histograms"]["request_latency_seconds"]["p50"] * 1e6,
        f"tok_s={ptoks / pspan:.1f};"
        + _snapshot_latency_fields(psnap)
        + f";vs_fakequant_tok_s={ctoks / cspan:.1f}",
    ))

    # -- sharded serving: the same packed engine on a (data, model) mesh --
    # CI runners expose one CPU device, so the smoke mesh is 1x1 — the row
    # pins that the mesh-aware data path (sharding-annotated params/caches,
    # rule-scoped dispatch) serves the trace at parity-tested numerics; the
    # comm estimate is the analytic all-gather traffic of the column-
    # parallel design (one gather per sublayer where the N-sharded
    # activation meets the replicated down/output projection), reported
    # for the actual mesh and projected at 2-way model parallelism
    del peng
    from repro.launch.mesh import make_host_mesh, mesh_from_env

    mesh = mesh_from_env() or make_host_mesh(1, 1)
    ws = int(dict(mesh.shape).get("model", 1))
    seng = ContinuousBatchingEngine(
        qparams, cfg, num_slots=num_slots, max_len=max_len, scfg=scfg,
        layout="paged", block_size=block, chunk=chunk, clock=clock,
        mesh=mesh,
    )
    box["t0"] = time.perf_counter()
    _run_continuous(seng, [dict(r, arrival=0.0) for r in warm], box["t0"])
    seng.metrics.reset()
    box["t0"] = t0 = time.perf_counter()
    slat, sttft, sitl, stoks, sspan, _ = _run_continuous(seng, trace, t0)
    ssnap = seng.snapshot()
    act_bytes = 4 * cfg.n_layers * (cfg.d_model + cfg.d_ff)  # f32 per token
    comm = stoks * act_bytes * (ws - 1)            # ring all-gather wire
    comm_ws2 = stoks * act_bytes                   # same trace, 2-way model
    rows.append(row(
        "serving/sharded",
        ssnap["histograms"]["request_latency_seconds"]["p50"] * 1e6,
        f"tok_s={stoks / sspan:.1f};"
        f"mesh=data{dict(mesh.shape).get('data', 1)}xmodel{ws};"
        f"comm_mb={comm / 1e6:.2f};comm_mb_at_model2={comm_ws2 / 1e6:.2f};"
        f"vs_unsharded_tok_s={ptoks / pspan:.1f}",
    ))

    # -- long-context: paged-attention kernel vs gather+SDPA read path ----
    # every prompt in this trace is long, so the paged read dominates;
    # block_size 8 satisfies the kernel's support gate (the main trace's
    # block=4 deliberately exercises the fallback)
    del seng
    long_block = 8
    long_prompt = 40 if smoke else 192
    long_budget = 8 if smoke else 24
    long_n = 3 if smoke else 12
    long_max = long_prompt + long_budget
    long_max += (-long_max) % long_block
    long_scfg = SamplerConfig(temperature=0.0, top_k=0,
                              max_new_tokens=long_budget)
    ltrace = make_trace(long_n, seed + 1, 0.02 if smoke else 0.05,
                        (long_prompt,), (long_budget,))
    long_slots = min(2, num_slots)
    box = {"t0": time.perf_counter()}
    stats, fins = {}, {}
    for name, enabled in (("gather", False), ("kernel", True)):
        stats[name], fins[name] = _run_long_context(
            params, cfg, long_slots, long_scfg, ltrace, long_block, chunk,
            prefill_chunk, long_max, box, enabled,
        )
    # greedy sampling: both read paths should produce identical streams
    # (array_equal, not ==, so a length divergence reads as 0, not a crash)
    streams_match = all(
        np.array_equal(a.tokens, b.tokens)
        for a, b in zip(
            sorted(fins["gather"], key=lambda f: f.uid),
            sorted(fins["kernel"], key=lambda f: f.uid),
        )
    )
    g, k = stats["gather"], stats["kernel"]
    rows.append(row(
        "serving/paged_long_gather", _pctl(g["lat"], 50) * 1e3,
        f"tok_s={g['tok_s']:.1f};decode_tok_s={g['decode_tok_s']:.1f};"
        + _latency_fields(g["lat"], g["ttft"], g["itl"])
        + f";prompt={long_prompt};block={long_block}",
    ))
    rows.append(row(
        "serving/paged_long_kernel", _pctl(k["lat"], 50) * 1e3,
        f"tok_s={k['tok_s']:.1f};decode_tok_s={k['decode_tok_s']:.1f};"
        + _latency_fields(k["lat"], k["ttft"], k["itl"])
        + f";prompt={long_prompt};block={long_block}"
        + f";gather_decode_tok_s={g['decode_tok_s']:.1f}"
        + f";kernel_vs_gather="
        + f"{k['decode_tok_s'] / max(g['decode_tok_s'], 1e-9):.2f}x"
        + f";streams_match={int(streams_match)}",
    ))

    # -- prefix caching: cold vs warm admission over a shared prompt ------
    # Every request carries the same long "system prompt" plus a short
    # private tail — the canonical hit shape.  The row compares admission
    # cost COLD (first pass populates the hash index) against WARM (a
    # second pass over the same prompts hits the cached prefix blocks) on
    # a deterministic virtual tick clock: every ``now()`` call is one
    # tick, so TTFT counts engine work (admission-prefill slices above
    # all) instead of wall noise — the warm/cold ratio is the slices the
    # cache skipped.  Stream parity vs a no-cache engine is asserted on
    # both admission paths (one-shot and chunked); the kernel read path's
    # bit parity over reused pages is pinned by the dedicated prefix-cache
    # test suite.
    pc_block = 8
    sys_len = 32 if smoke else 96
    pc_budget = 4 if smoke else 8
    pc_n = 4 if smoke else 8
    pc_chunk = 4
    pc_max = sys_len + 4 + pc_budget
    pc_max += (-pc_max) % pc_block
    pc_rng = np.random.default_rng(seed + 3)
    sys_prompt = pc_rng.integers(0, cfg.vocab_size, sys_len).astype(np.int32)
    pc_trace = []
    for i in range(pc_n):
        tail = pc_rng.integers(0, cfg.vocab_size, 1 + i % 3).astype(np.int32)
        pc_trace.append(dict(
            uid=i, prompt=np.concatenate([sys_prompt, tail]),
            budget=pc_budget, seed=i, arrival=0.0,
        ))
    pc_scfg = SamplerConfig(temperature=0.0, top_k=0,
                            max_new_tokens=pc_budget)

    def tick_clock():
        tbox = {"t": 0.0}

        def now():
            tbox["t"] += 1.0
            return tbox["t"]

        return now

    def pc_run(engine, uid0=0):
        """Submit the shared-prefix trace (uids offset so reruns stay
        unique) and return (uid -> tokens, ttft list in ticks).  The tick
        clock is monotonic across runs, so TTFT is measured from this
        run's starting tick, not the absolute arrival."""
        t0 = engine.now()
        for r in pc_trace:
            engine.submit(r["prompt"], max_new_tokens=r["budget"],
                          seed=r["seed"], uid=uid0 + r["uid"], arrival=0.0)
        fin = engine.run()
        return (
            {f.uid - uid0: np.asarray(f.tokens) for f in fin},
            [f.first_token_at - t0 for f in fin],
        )

    base_eng = ContinuousBatchingEngine(
        params, cfg, num_slots=2, max_len=pc_max, scfg=pc_scfg,
        layout="paged", block_size=pc_block, chunk=chunk,
        clock=tick_clock(),
    )
    base_streams, _ = pc_run(base_eng)  # the no-cache greedy oracle
    del base_eng

    match = {}
    pc_stats = {}
    for mode, pchunk in (("oneshot", None), ("chunked", pc_chunk)):
        ceng2 = ContinuousBatchingEngine(
            params, cfg, num_slots=2, max_len=pc_max, scfg=pc_scfg,
            layout="paged", block_size=pc_block, chunk=chunk,
            prefill_chunk=pchunk, prefix_cache=True, clock=tick_clock(),
        )
        cold_streams, cold_ttft = pc_run(ceng2, uid0=0)
        ceng2.metrics.reset()  # warm-pass hit rate, uncontaminated
        warm_streams, warm_ttft = pc_run(ceng2, uid0=1000)
        snap2 = ceng2.snapshot()
        hits = snap2["counters"]["prefix_cache_hits_total"]
        misses = snap2["counters"]["prefix_cache_misses_total"]
        match[mode] = all(
            np.array_equal(cold_streams[u], base_streams[u])
            and np.array_equal(warm_streams[u], base_streams[u])
            for u in base_streams
        )
        pc_stats[mode] = dict(
            cold=_pctl(cold_ttft, 50), warm=_pctl(warm_ttft, 50),
            hit_rate=hits / max(hits + misses, 1),
            cow=snap2["counters"]["prefix_cache_cow_total"],
            leak=ceng2.allocator.free_count != ceng2.num_blocks,
        )
        del ceng2
    ch = pc_stats["chunked"]
    rows.append(row(
        "serving/prefix_cache", ch["warm"],
        f"ttft_cold_p50_ticks={ch['cold']:.0f};"
        f"ttft_warm_p50_ticks={ch['warm']:.0f};"
        f"warm_speedup={ch['cold'] / max(ch['warm'], 1e-9):.2f}x;"
        f"hit_rate={ch['hit_rate']:.2f};"
        f"oneshot_hit_rate={pc_stats['oneshot']['hit_rate']:.2f};"
        f"oneshot_warm_speedup="
        f"{pc_stats['oneshot']['cold'] / max(pc_stats['oneshot']['warm'], 1e-9):.2f}x;"
        f"cow={ch['cow']};sys_prompt={sys_len};"
        f"streams_match_oneshot={int(match['oneshot'])};"
        f"streams_match_chunked={int(match['chunked'])};"
        f"leaked={int(ch['leak'] or pc_stats['oneshot']['leak'])}",
    ))
    return rows


def main():
    # allow `python benchmarks/bench_serving.py` from the repo root
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI subset")
    ap.add_argument("--num-slots", type=int, default=None)
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None,
                    help="write the continuous run's schema-validated "
                         "metrics snapshot (JSON) here")
    ap.add_argument("--trace-out", default=None,
                    help="write the continuous run's request trace "
                         "(JSONL, one event per line) here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, num_slots=args.num_slots,
        n_requests=args.n_requests, seed=args.seed,
        metrics_out=args.metrics_out, trace_out=args.trace_out)


if __name__ == "__main__":
    main()
