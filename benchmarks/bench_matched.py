"""Table 3 — matched-parameter comparison.

pQuant with reduced width + N=8 routable branches (total params matched to
BitNet1.58, fewer ACTIVE params) should match the 2-bit baseline's quality;
memory footprint comes from the packing model.
"""

import time

from repro.configs.base import param_count
from benchmarks.common import final_nll, quick_train, row, tiny_config


def run(steps: int = 120) -> dict:
    # BitNet1.58 reference at d_ff=128
    t0 = time.perf_counter()
    h_ref, _ = quick_train(tiny_config("bitnet158", d_ff=128), steps=steps)
    us_ref = (time.perf_counter() - t0) * 1e6 / max(len(h_ref), 1)

    # pQuant with narrower 1-bit trunk + N=8 branches: match total params
    # tiny-scale analogue of Table 3's 926M-active/1.3B-total config
    cfg_pq = tiny_config("pquant", n_experts=8, d_ff=96, r=16)
    t0 = time.perf_counter()
    h_pq, _ = quick_train(cfg_pq, steps=steps)
    us_pq = (time.perf_counter() - t0) * 1e6 / max(len(h_pq), 1)

    ref_total = param_count(tiny_config("bitnet158", d_ff=128))["total"]
    pq = param_count(cfg_pq)
    nll_ref, nll_pq = final_nll(h_ref), final_nll(h_pq)
    row("table3/bitnet158", us_ref, f"params={ref_total};nll={nll_ref:.4f}")
    row("table3/pquant_N8_matched", us_pq,
        f"params={pq['total']};active_frac={(pq['total']-7*pq['n_8bit']//8)/pq['total']:.2f};"
        f"nll={nll_pq:.4f}")
    row("table3/parity", 0.0, f"delta_nll={nll_pq - nll_ref:+.4f}")
    return {"bitnet158": nll_ref, "pquant": nll_pq}


if __name__ == "__main__":
    run()
