"""Figure 8 / Appendix A — linear-op compute time across precisions.

CPU cannot time TPU kernels, so this benchmark reports BOTH:
  * measured: XLA-compiled CPU wall-time of the three dequantized linear
    paths at identical logical shape (relative ordering only);
  * derived: the TPU-side roofline prediction for decode GEMV — the op is
    weight-bandwidth-bound, so time ~ weight bytes moved:
        W1A8 packed : W2 (ternary) : FP16  =  1/16 : 1/4(2bit) : 1
    matching the paper's 38% / 82% reductions in spirit.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import (
    QuantConfig,
    binarize_weights,
    quantize_activations_int8,
    ternarize_weights,
)
from benchmarks.common import row, time_fn

M, K, N = 64, 2048, 2048  # decode-ish GEMV batch at 7B-scale layer dims


def run() -> dict:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32) * 0.3)
    w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32) * 0.02)

    def fp16_path(x, w):
        return x.astype(jnp.bfloat16) @ w.astype(jnp.bfloat16)

    def w1a8_path(x, w):
        xq, _ = quantize_activations_int8(x)
        wq, _ = binarize_weights(w)
        return xq @ wq

    def w2_path(x, w):
        xq, _ = quantize_activations_int8(x)
        wq, _ = ternarize_weights(w)
        return xq @ wq

    out = {}
    for name, fn in (("fp16", fp16_path), ("w1a8_pquant", w1a8_path),
                     ("w2_bitnet158", w2_path)):
        f = jax.jit(fn)
        us = time_fn(f, x, w)
        out[name] = us
        row(f"fig8/linear_cpu/{name}", us, f"shape={M}x{K}x{N}")

    # derived TPU decode-GEMV weight traffic (the regime the paper measures)
    wbytes = {"fp16": K * N * 2, "w2_bitnet158": K * N // 4,
              "w1a8_pquant": K * N // 8}
    for name, b in wbytes.items():
        t_us = b / 819e9 * 1e6  # HBM-bound read time on v5e
        row(f"fig8/tpu_derived/{name}", t_us, f"weight_bytes={b}")
    red_vs_fp16 = 1 - wbytes["w1a8_pquant"] / wbytes["fp16"]
    red_vs_w2 = 1 - wbytes["w1a8_pquant"] / wbytes["w2_bitnet158"]
    row("fig8/tpu_derived/reduction", 0.0,
        f"vs_fp16={red_vs_fp16:.1%};vs_2bit={red_vs_w2:.1%}")
    return out


if __name__ == "__main__":
    run()
