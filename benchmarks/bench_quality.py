"""Table 2 proxy — main quality comparison at matched size and data:
pQuant vs BitNet (1-bit) vs BitNet1.58 (2-bit) vs FP16, trained from
scratch on the same synthetic corpus.  Reports final NLL and perplexity.

Paper claim being checked: pQuant closes most of the 1-bit -> FP16 gap and
lands between BitNet1.58 and FP16.
"""

from benchmarks.common import final_nll, ppl, quick_train, row, tiny_config, time_fn


def run(steps: int = 120) -> dict:
    results = {}
    t_us = {}
    for mode in ("pquant", "bitnet", "bitnet158", "none"):
        import time

        t0 = time.perf_counter()
        hist, _ = quick_train(tiny_config(mode), steps=steps)
        t_us[mode] = (time.perf_counter() - t0) * 1e6 / max(len(hist), 1)
        results[mode] = final_nll(hist)
    for mode, nll in results.items():
        row(
            f"table2/quality/{mode}",
            t_us[mode],
            f"nll={nll:.4f};ppl={ppl(nll):.2f}",
        )
    gap_closed = 0.0
    if results["bitnet"] != results["none"]:
        gap_closed = (results["bitnet"] - results["pquant"]) / max(
            results["bitnet"] - results["none"], 1e-9
        )
    row("table2/gap_closed_vs_fp16", 0.0, f"frac={gap_closed:.2f}")
    return results


if __name__ == "__main__":
    run()
