"""Table 8 / Appendix H — QAT training-time overhead vs FP16.

Measures wall-clock per train step at identical dims: the fake-quant
(quantize/dequantize/STE) graph adds elementwise work; the paper reports
QAT training is slower than standard pre-training for this reason.  Also
reports the HLO-FLOPs overhead ratio from the roofline pass when present.
"""

import json
import os

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, SyntheticSource, host_batch
from repro.train.trainer import init_train_state, make_train_step
from benchmarks.common import row, time_fn, tiny_config


def run() -> dict:
    out = {}
    src = SyntheticSource(256, seed=0)
    batch = {k: jnp.asarray(v) for k, v in
             host_batch(src, DataConfig(seq_len=64, global_batch=8), 0).items()}
    base = None
    for mode in ("none", "bitnet158", "pquant"):
        cfg = tiny_config(mode, d_model=128, d_ff=256, n_layers=4)
        state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
        step = jax.jit(make_train_step(cfg, 100))
        us = time_fn(step, state, batch, warmup=1, iters=3)
        out[mode] = us
        if mode == "none":
            base = us
        row(f"table8/step_time/{mode}", us,
            f"overhead_vs_fp16={us / base:.2f}x" if base else "")
    # roofline-derived QAT flops overhead (useful-FLOPs ratio), if available
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "roofline_baseline.json")
    if os.path.exists(path):
        recs = [r for r in json.load(open(path))
                if r.get("kind") == "train" and "useful_flops_ratio" in r]
        if recs:
            avg = sum(r["useful_flops_ratio"] for r in recs) / len(recs)
            row("table8/hlo_useful_flops_ratio_train", 0.0,
                f"avg={avg:.2f};n={len(recs)}")
    return out


if __name__ == "__main__":
    run()
