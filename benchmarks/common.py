"""Shared helpers for the benchmark harness: small-scale training runs and
timing utilities.  Every benchmark prints ``name,us_per_call,derived`` CSV
rows through :func:`row`."""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig
from repro.data.pipeline import DataConfig, SyntheticSource, host_batch
from repro.train.trainer import Trainer, TrainerConfig


def row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.2f},{derived}"
    print(line)
    return line


def tiny_config(
    quant_mode: str = "pquant",
    n_experts: int = 1,
    d_model: int = 64,
    d_ff: int = 128,
    r: int = 16,
    n_layers: int = 2,
    vocab: int = 256,
    **kw,
) -> ModelConfig:
    qc = QuantConfig(
        mode=quant_mode,
        r=r if quant_mode == "pquant" else 0,
        num_experts=n_experts,
    )
    base = dict(
        name=f"bench-{quant_mode}-n{n_experts}",
        family="decoder",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=4,
        d_ff=d_ff,
        vocab_size=vocab,
        max_seq_len=64,
        quant=qc,
    )
    base.update(kw)
    return ModelConfig(**base)


def quick_train(
    cfg: ModelConfig,
    steps: int = 80,
    seq: int = 32,
    batch: int = 8,
    seed: int = 0,
    peak_lr: float | None = None,
    **tcfg_kw,
):
    """Train on the synthetic corpus; returns (history, trainer).

    Extra keyword args flow into :class:`TrainerConfig` — the stability
    bench uses this to switch on QAT health probes and the JSONL trace.
    """
    src = SyntheticSource(cfg.vocab_size, seed=seed)
    dcfg = DataConfig(seq_len=seq, global_batch=batch, seed=seed)

    def it():
        for s in range(steps + 1):
            yield s, host_batch(src, dcfg, s)

    tcfg = TrainerConfig(total_steps=steps, log_every=10**9, ckpt_every=10**9,
                         peak_lr=peak_lr, **tcfg_kw)
    tr = Trainer(cfg, tcfg, it())
    hist = tr.run()
    return hist, tr


def final_nll(hist, k: int = 10) -> float:
    return float(np.mean([h["nll"] for h in hist[-k:]]))


def ppl(nll: float) -> float:
    return float(np.exp(nll))


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time of fn(*args) in microseconds (blocks on output)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))
