"""Figure 6 + Table 3 memory column — weight bytes transferred per forward.

Exact accounting from the packed representation (1-bit packed 8/byte,
INT8 branch 1 byte/weight, FP16 embeddings/norms 2 bytes): pQuant's *read*
traffic is invariant in N (top-1 routing), stored bytes grow mildly.
"""

from repro.configs.base import param_count
from repro.configs.registry import get_config
from repro.core.packing import model_weight_bytes
from benchmarks.common import row


def run() -> dict:
    out = {}
    for size in ("300m", "700m", "1.3b", "2.6b"):
        rows = {}
        for mode, label in (("pquant", "pquant"), ("bitnet158", "bitnet158"),
                            ("none", "fp16")):
            cfg = get_config(f"pquant-{size}", quant_mode=mode)
            pc = param_count(cfg)
            if mode == "none":
                bytes_fwd = pc["total"] * 2  # fp16 everything
            elif mode == "bitnet158":
                # ternary: 2 bits/weight practical packing (paper uses ~1.58)
                bytes_fwd = pc["n_1bit"] / 4 + pc["n_fp16"] * 2
            else:
                mb = model_weight_bytes(
                    pc["n_1bit"], pc["n_8bit"], pc["n_fp16"],
                    seq_active_8bit=pc["n_8bit"],  # N=1 => all 8-bit active
                )
                bytes_fwd = mb["read_bytes"]
            rows[label] = bytes_fwd
            row(f"fig6/memory/{size}/{label}", 0.0,
                f"gib={bytes_fwd/2**30:.3f}")
        red_fp16 = 1 - rows["pquant"] / rows["fp16"]
        red_158 = 1 - rows["pquant"] / rows["bitnet158"]
        row(f"fig6/memory/{size}/reduction", 0.0,
            f"vs_fp16={red_fp16:.1%};vs_bitnet158={red_158:.1%}")
        out[size] = rows
    # N-invariance of read traffic (paper §4.5)
    cfg = get_config("pquant-1.3b", n_experts=8)
    pc = param_count(cfg)
    active_8bit = pc["n_8bit"] // 8  # one of 8 branches read per token
    mb = model_weight_bytes(pc["n_1bit"], pc["n_8bit"], pc["n_fp16"],
                            seq_active_8bit=active_8bit)
    row("fig6/read_invariance/N=8", 0.0,
        f"read_gib={mb['read_bytes']/2**30:.3f};stored_gib={mb['stored_bytes']/2**30:.3f}")
    return out


if __name__ == "__main__":
    run()
