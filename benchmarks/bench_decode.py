"""Decode-path benchmark: Python per-token loop vs the compiled engine,
fake-quant vs packed-integer weights.

Rows (``name,us_per_call,derived`` — us_per_call is per-TOKEN latency):
  decode/python_loop          legacy loop (jitted step + host sync per token)
  decode/engine               compiled prefill + lax.scan generation
  decode/engine_packed        same engine on quantize_params_for_serving
                              (packed=True) weights: decode steps run the
                              w1a8_gemv / decoupled_gemv kernel tier
  decode/engine_stream        chunked streaming variant
  decode/host_transfers       device->host transfers per engine call (== 1)
  decode/gemv_tier            ops decode tier (fused act-quant w1a8_gemv)
  decode/prefill_tier         same shape through the M-tiled prefill kernel

The engine rows quantify what moving the loop on-device buys; the packed
row what computing on stored integers buys over fake-quant float matmuls
(on CPU the kernels run in interpret mode, so that row is a wiring check
there, not a timing signal); the kernel rows what the decode-shaped GEMV
tier buys over padding decode rows into prefill tiles.  ``--smoke`` runs a
seconds-scale subset (no kernel micro-bench) so CI exercises the whole
path — including the packed engine — without TPU hardware.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def _bench_kernel_tiers(rows, row, time_fn, m=4, k=512, n=512):
    """Same decode shape through both ops tiers (TPU-meaningful numbers;
    interpret mode on CPU is correctness-only)."""
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    wp = jnp.asarray(rng.integers(0, 256, (k // 8, n)).astype(np.uint8))
    lam = jnp.asarray(np.float32(0.05))

    t_gemv = time_fn(
        lambda: ops._bit_linear_decode(x, wp, lam, jnp.float32), warmup=1
    )
    t_pref = time_fn(
        lambda: ops._bit_linear_prefill(x, wp, lam, jnp.float32), warmup=1
    )
    shape = f"m{m}_k{k}_n{n}"
    rows.append(row(f"decode/gemv_tier_{shape}", t_gemv,
                    f"speedup={t_pref / max(t_gemv, 1e-12):.2f}x"))
    rows.append(row(f"decode/prefill_tier_{shape}", t_pref, ""))


def run(smoke: bool = False, batch: int = 4, prompt_len: int = 16,
        new_tokens: int | None = None, iters: int | None = None):
    from benchmarks.common import row, time_fn, tiny_config
    from repro.models import api
    from repro.train.serve import BatchedServer, SamplerConfig

    new_tokens = new_tokens or (8 if smoke else 48)
    iters = iters or (1 if smoke else 3)
    cfg = tiny_config(d_model=64, d_ff=128, n_layers=2, vocab=256)
    params, axes = api.init_model(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(params, cfg, max_len=prompt_len + new_tokens + 1)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab_size
    ).astype(jnp.int32)
    scfg = SamplerConfig(temperature=0.0, top_k=0, max_new_tokens=new_tokens)
    toks_per_call = batch * new_tokens
    timed = lambda fn: time_fn(fn, warmup=1, iters=iters)  # us per call
    tok_s = lambda us: toks_per_call / (us * 1e-6)
    rows = []

    us_py = timed(lambda: server.generate_python_loop(prompts, scfg))
    rows.append(row("decode/python_loop", us_py / new_tokens,
                    f"tok_s={tok_s(us_py):.1f}"))

    us_en = timed(lambda: server.generate(prompts, scfg))
    rows.append(row(
        "decode/engine", us_en / new_tokens,
        f"tok_s={tok_s(us_en):.1f};speedup={us_py / us_en:.2f}x",
    ))

    from repro.train.quantized_serving import quantize_params_for_serving

    qparams, _ = quantize_params_for_serving(params, axes, cfg, packed=True)
    packed_server = BatchedServer(
        qparams, cfg, max_len=prompt_len + new_tokens + 1
    )
    us_pk = timed(lambda: packed_server.generate(prompts, scfg))
    rows.append(row(
        "decode/engine_packed", us_pk / new_tokens,
        f"tok_s={tok_s(us_pk):.1f};vs_fakequant={us_en / us_pk:.2f}x",
    ))

    us_st = timed(lambda: list(server.generate_stream(prompts, scfg, chunk=8)))
    rows.append(row("decode/engine_stream", us_st / new_tokens,
                    f"tok_s={tok_s(us_st):.1f}"))

    before = server.engine.host_transfers
    server.generate(prompts, scfg)
    rows.append(row("decode/host_transfers", 0.0,
                    f"per_call={server.engine.host_transfers - before}"))

    if not smoke:
        _bench_kernel_tiers(rows, row, time_fn)
    return rows


def main():
    # allow `python benchmarks/bench_decode.py` from the repo root (siblings
    # require `python -m benchmarks.run`)
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI subset")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=None)
    ap.add_argument("--iters", type=int, default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, batch=args.batch, prompt_len=args.prompt_len,
        new_tokens=args.new_tokens, iters=args.iters)


if __name__ == "__main__":
    main()
