"""Figure 4 / Table 5 — scaling the number of routable 8-bit branches N.

Paper claim: loss decreases monotonically-ish in N at constant *active*
parameters, surpassing the 2-bit baseline by N=4..8.
"""

import time

from benchmarks.common import final_nll, quick_train, row, tiny_config


def run(steps: int = 120) -> dict:
    results = {}
    for n in (1, 2, 4):
        t0 = time.perf_counter()
        hist, _ = quick_train(tiny_config("pquant", n_experts=n), steps=steps)
        us = (time.perf_counter() - t0) * 1e6 / max(len(hist), 1)
        results[n] = final_nll(hist)
        row(f"fig4/scaling/N={n}", us, f"nll={results[n]:.4f}")
    t0 = time.perf_counter()
    hist, _ = quick_train(tiny_config("bitnet158"), steps=steps)
    us = (time.perf_counter() - t0) * 1e6 / max(len(hist), 1)
    nll2 = final_nll(hist)
    row("fig4/scaling/bitnet158_ref", us, f"nll={nll2:.4f}")
    best = min(results.values())
    row("fig4/best_N_vs_2bit", 0.0, f"delta={nll2 - best:+.4f}")
    return {"pquant_by_n": results, "bitnet158": nll2}


if __name__ == "__main__":
    run()
