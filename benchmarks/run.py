"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Mapping (DESIGN.md §6):
  Table 2   -> bench_quality       main quality, 4 modes trained from scratch
  Fig 4/T5  -> bench_scaling       loss vs number of 8-bit branches N
  Table 3   -> bench_matched       matched-total-params comparison
  Fig 2/5a  -> bench_sensitivity   parameter-democratization scores
  Figure 6  -> bench_memory        weight bytes moved per forward
  Figure 8  -> bench_kernels       linear-op time across precisions
  Table 8   -> bench_step_time     QAT step-time overhead
  Figure 10 -> bench_stability     divergence/spike counts at hot LR
  §Roofline -> bench_roofline      dry-run roofline terms per cell
  §Decode   -> bench_decode        python loop vs compiled engine tok/s
  §Serving  -> bench_serving       lockstep vs continuous batching latency
"""

import argparse
import json
import pathlib
import platform
import sys
import time

# allow `python benchmarks/run.py` from the repo root (the benchmarks
# package is importable either way)
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single benchmark id")
    ap.add_argument("--steps", type=int, default=120,
                    help="training steps for the learning benchmarks")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI subset: the serving-path suites "
                         "(decode incl. packed weights, continuous "
                         "batching) plus the allocation-free memory rows")
    ap.add_argument("--out", default=None,
                    help="write a JSON results artifact to this path "
                         "(default: BENCH_serving.json under --smoke, so "
                         "CI tracks the serving perf trajectory per run)")
    args = ap.parse_args()

    from benchmarks import (
        bench_decode,
        bench_kernels,
        bench_matched,
        bench_memory,
        bench_quality,
        bench_roofline,
        bench_scaling,
        bench_sensitivity,
        bench_serving,
        bench_stability,
        bench_step_time,
    )

    suites = {
        "memory": lambda: bench_memory.run(),
        "kernels": lambda: bench_kernels.run(),
        "roofline": lambda: bench_roofline.run(),
        "step_time": lambda: bench_step_time.run(),
        "decode": lambda: bench_decode.run(),
        "serving": lambda: bench_serving.run(),
        "quality": lambda: bench_quality.run(steps=args.steps),
        "scaling": lambda: bench_scaling.run(steps=args.steps),
        "matched": lambda: bench_matched.run(steps=args.steps),
        "sensitivity": lambda: bench_sensitivity.run(steps=max(60, args.steps // 2)),
        "stability": lambda: bench_stability.run(steps=max(80, args.steps // 2)),
    }
    if args.smoke:
        # the smoke serving run also emits the engine's metrics snapshot
        # and JSONL request trace next to BENCH_serving.json, so CI can
        # schema-validate and archive the telemetry alongside the numbers
        suites = {
            "memory": lambda: bench_memory.run(),
            "decode": lambda: bench_decode.run(smoke=True),
            "serving": lambda: bench_serving.run(
                smoke=True,
                metrics_out="BENCH_serving_metrics.json",
                trace_out="BENCH_serving_trace.jsonl",
            ),
            # a short probe-instrumented pQuant train run; its metrics
            # snapshot + lifecycle trace are the training-side telemetry
            # artifacts CI validates and archives
            "stability": lambda: bench_stability.run(
                smoke=True,
                metrics_out="BENCH_train_metrics.json",
                trace_out="BENCH_train_trace.jsonl",
            ),
        }
    def jsonable(x):
        """Suites return CSV-row lists OR nested result dicts (e.g.
        bench_memory) — keep whichever structure intact in the artifact,
        stringifying only leaves json can't encode."""
        try:
            json.dumps(x)
            return x
        except TypeError:
            if isinstance(x, dict):
                return {str(k): jsonable(v) for k, v in x.items()}
            if isinstance(x, (list, tuple)):
                return [jsonable(v) for v in x]
            return str(x)

    print("name,us_per_call,derived")
    results: dict[str, dict] = {}
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001 — a failing suite shouldn't kill the run
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            rows = None
            results[name] = {"error": f"{type(e).__name__}:{e}"}
        dt = time.time() - t0
        if rows is not None:
            results[name] = {"rows": jsonable(rows), "seconds": round(dt, 2)}
        print(f"# suite {name} done in {dt:.1f}s", file=sys.stderr)

    out = args.out or ("BENCH_serving.json" if args.smoke else None)
    if out:
        payload = {
            "smoke": bool(args.smoke),
            "platform": platform.platform(),
            "python": platform.python_version(),
            "suites": results,
        }
        pathlib.Path(out).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"# wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
