"""Figures 2 & 5a — parameter democratization and its reversal by pQuant.

Trains tiny FP16 / BitNet / pQuant models, then measures the OBS
sensitivity landscape of the final FFN layer:
  * FP16: differentiated (low democratization score);
  * BitNet 1-bit: near-uniform (score -> 1) — the paper's pathology;
  * pQuant: differentiated again, with the 8-bit branch holding the
    concentrated high-sensitivity mass.
"""

import jax
import jax.numpy as jnp

from repro.core.sensitivity import (
    democratization_score,
    obs_sensitivity,
    top_fraction_mass,
)
from repro.core.quantization import binarize_weights, quantize_weights_int8
from benchmarks.common import quick_train, row, tiny_config


def _calib_inputs(cfg, d):
    return jax.random.normal(jax.random.PRNGKey(9), (2048, d)) * jnp.exp(
        0.5 * jax.random.normal(jax.random.PRNGKey(10), (d,))
    )


def run(steps: int = 80) -> dict:
    out = {}
    d = 64
    x = _calib_inputs(None, d)

    # FP16 reference
    _, tr = quick_train(tiny_config("none"), steps=steps)
    w_fp = tr.state.params["segments"][0]["b0"]["ffn"]["w1_up"][-1]
    s = obs_sensitivity(w_fp, x)
    out["fp16"] = float(democratization_score(s))
    row("fig2/democratization/fp16", 0.0,
        f"score={out['fp16']:.4f};top1%mass={float(top_fraction_mass(s)):.3f}")

    # BitNet: sensitivity of the weights the hardware actually uses (1-bit)
    _, tr = quick_train(tiny_config("bitnet"), steps=steps)
    w_bn = tr.state.params["segments"][0]["b0"]["ffn"]["w1_up"][-1]
    wq, _ = binarize_weights(w_bn)
    s = obs_sensitivity(wq, x)
    out["bitnet"] = float(democratization_score(s))
    row("fig2/democratization/bitnet_1bit", 0.0,
        f"score={out['bitnet']:.4f};top1%mass={float(top_fraction_mass(s)):.3f}")

    # pQuant: 1-bit branch vs 8-bit branch (paper Fig. 5a)
    _, tr = quick_train(tiny_config("pquant"), steps=steps)
    ffn = tr.state.params["segments"][0]["b0"]["ffn"]
    w1q, _ = binarize_weights(ffn["w1_up"][-1])
    s1 = obs_sensitivity(w1q, x)
    w8q, _ = quantize_weights_int8(ffn["w8_up"][-1][0])
    s8 = obs_sensitivity(w8q, x)
    out["pquant_1bit"] = float(democratization_score(s1))
    out["pquant_8bit"] = float(democratization_score(s8))
    row("fig5a/democratization/pquant_1bit", 0.0, f"score={out['pquant_1bit']:.4f}")
    row("fig5a/democratization/pquant_8bit", 0.0,
        f"score={out['pquant_8bit']:.4f};top1%mass={float(top_fraction_mass(s8)):.3f}")
    # the paper's qualitative ordering
    row("fig2/ordering_check", 0.0,
        f"bitnet_flatter_than_fp16={out['bitnet'] > out['fp16']};"
        f"pquant8_differentiated={out['pquant_8bit'] < out['bitnet']}")
    return out


if __name__ == "__main__":
    run()
