"""Batched serving example: prefill a batch of prompts and decode with
sampling — the paper's edge-inference scenario (W1A8 weights, KV cache).

    PYTHONPATH=src python examples/serve_lm.py [--ckpt results/train100m/ckpt]

Generation runs on the compiled decode engine (prefill + lax.scan + on-device
sampling, one host transfer).  ``--compare`` also times the legacy per-token
Python loop and prints the speedup; ``--stream`` prints tokens chunk by
chunk as the engine produces them; ``--continuous`` serves the same
prompts through the continuous-batching engine instead (ragged prompts,
per-request budgets/seeds, paged KV pool — each request's stream matches
the lockstep engine's for its seed); ``--packed`` serves the bit-packed
integer export, so every decode linear runs the W1A8 GEMV kernel tier.

Without --ckpt it serves a freshly initialised reduced model (tokens are
synthetic ids); with a checkpoint from train_lm.py it decodes that model.
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.registry import get_config, reduced
from repro.models import api
from repro.train.serve import BatchedServer, SamplerConfig
from repro.train.trainer import init_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pquant-100m")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--compare", action="store_true",
                    help="also time the legacy per-token Python loop")
    ap.add_argument("--stream", action="store_true",
                    help="stream tokens chunk by chunk")
    ap.add_argument("--stream-chunk", type=int, default=8)
    ap.add_argument("--continuous", action="store_true",
                    help="serve via the continuous-batching engine "
                         "(ragged prompts, paged KV pool)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable automatic prefix caching (--continuous "
                         "only): shared prompt prefixes are served from "
                         "cached KV blocks; every prompt is submitted "
                         "twice so the second pass demonstrates hits")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="token budget per step for chunked admission "
                         "prefill (--continuous only): long prompts "
                         "stream in as bounded slices instead of "
                         "stalling live decode streams")
    ap.add_argument("--packed", action="store_true",
                    help="export weights to the packed integer serving "
                         "layout first: decode runs the W1A8 GEMV kernel "
                         "tier on stored integers (paper Appendix A)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve on a (data, model) device mesh, e.g. 1x2: "
                         "packed weights shard N-major over the model "
                         "axis, paged KV pools shard over KV heads; the "
                         "scheduler and slot state stay replicated.  "
                         "Defaults to REPRO_MESH when set")
    args = ap.parse_args()

    from repro.launch.mesh import make_host_mesh, mesh_from_env

    if args.mesh:
        data, model = (int(v) for v in args.mesh.lower().split("x"))
        mesh = make_host_mesh(data=data, model=model)
    else:
        mesh = mesh_from_env()
    if mesh is not None:
        print(f"serving on mesh {dict(mesh.shape)}")

    cfg = get_config(args.arch)
    if args.reduced or not args.ckpt:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, dtype="float32")

    key = jax.random.PRNGKey(0)
    if args.ckpt:
        state, _ = init_train_state(key, cfg)
        restored = Checkpointer(args.ckpt).restore(state._asdict())
        params = restored["params"]
        print(f"restored checkpoint step {int(restored['opt'].step) if hasattr(restored['opt'], 'step') else '?'}")
    else:
        params, _ = api.init_model(key, cfg)
        print("serving a randomly initialised reduced model")

    if args.packed:
        from repro.train.quantized_serving import quantize_params_for_serving

        _, axes = api.params_shape_and_axes(cfg)
        params, _ = quantize_params_for_serving(params, axes, cfg,
                                                packed=True)
        print("serving the packed integer export (W1A8 kernel tier)")

    scfg = SamplerConfig(temperature=0.8, top_k=40,
                         max_new_tokens=args.new_tokens)

    if args.continuous:
        from repro.serve.scheduler import ContinuousBatchingEngine

        max_len = args.prompt_len + args.new_tokens
        max_len += (-max_len) % args.block_size
        num_slots = max(2, args.batch // 2)
        # the default pool is sized for full slot occupancy with zero
        # slack — a cache with no headroom evicts every parked block on
        # the next admission, so give the demo a pool that can retain
        nb = num_slots * -(-max_len // args.block_size)
        eng = ContinuousBatchingEngine(
            params, cfg, num_slots=num_slots, max_len=max_len,
            scfg=scfg, layout="paged", block_size=args.block_size,
            prefill_chunk=args.prefill_chunk, mesh=mesh,
            prefix_cache=args.prefix_cache,
            num_blocks=2 * nb if args.prefix_cache else None,
        )
        if args.prefill_chunk and eng.prefill_chunk is None:
            print("note: config is not chunk-safe; one-shot admission")
        if args.prefix_cache and not eng.prefix_cache:
            print("note: config declines prefix caching; running cold")
        rng = jax.random
        t0 = time.time()
        # with --prefix-cache every prompt goes in twice: the repeats
        # (same prompt+seed, fresh uid) hit the blocks the first pass
        # cached and must produce the identical stream
        rounds = 2 if args.prefix_cache else 1
        for r in range(rounds):
            for i in range(args.batch):
                # ragged prompts: each request its own length and seed
                s = max(1, args.prompt_len - i % 4)
                prompt = rng.randint(rng.PRNGKey(i), (s,), 3, cfg.vocab_size)
                eng.submit(prompt, max_new_tokens=args.new_tokens, seed=i,
                           uid=r * args.batch + i)
        finished = eng.run()
        dt = time.time() - t0
        total = sum(len(f.tokens) for f in finished)
        print(f"continuous batching: {len(finished)} requests, {total} "
              f"tokens in {dt:.1f}s ({total / dt:.1f} tok/s incl. compile); "
              f"pool free {eng.allocator.free_count}/{eng.num_blocks}, "
              f"{eng.preemptions} preemptions")
        if eng.prefix_cache:
            c = eng.snapshot()["counters"]
            streams = {}
            match = all(
                streams.setdefault(f.uid % args.batch, f.tokens.tolist())
                == f.tokens.tolist() for f in finished
            )
            print(f"prefix cache: {c['prefix_cache_hits_total']} hits / "
                  f"{c['prefix_cache_misses_total']} misses, "
                  f"{c['prefix_cache_hit_tokens_total']} tokens reused, "
                  f"{c['prefix_cache_cow_total']} CoW; repeat streams "
                  f"identical: {match}")
        for f in sorted(finished, key=lambda f: f.uid)[:4]:
            print(f"  request {f.uid} ({f.finish_reason}): "
                  f"{f.tokens.tolist()}")
        return

    server = BatchedServer(params, cfg,
                           max_len=args.prompt_len + args.new_tokens + 1,
                           mesh=mesh)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 3, cfg.vocab_size
    ).astype(jnp.int32)
    toks = args.batch * args.new_tokens

    if args.stream:
        t0 = time.time()
        chunks = []
        for i, chunk in enumerate(server.generate_stream(
                prompts, scfg, chunk=args.stream_chunk)):
            chunks.append(chunk)
            print(f"  chunk {i}: +{chunk.shape[1]} tokens "
                  f"({time.time() - t0:.1f}s in)")
        import numpy as np
        out = np.concatenate(chunks, axis=1)
    else:
        t0 = time.time()
        out = server.generate(prompts, scfg)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s batched, incl. prefill + compile)")
    for i, row in enumerate(out[: min(4, args.batch)]):
        print(f"  request {i}: {row.tolist()}")

    if args.compare:
        # warm both paths, then time steady-state generation
        server.generate(prompts, scfg)
        server.generate_python_loop(prompts, scfg)
        t0 = time.time()
        server.generate_python_loop(prompts, scfg)
        t_py = time.time() - t0
        t0 = time.time()
        server.generate(prompts, scfg)
        t_en = time.time() - t0
        print(f"python loop: {toks / t_py:.1f} tok/s | compiled engine: "
              f"{toks / t_en:.1f} tok/s | speedup {t_py / t_en:.2f}x")


if __name__ == "__main__":
    main()
