"""Quickstart: build a pQuant model, train it briefly, inspect the pieces.

    PYTHONPATH=src python examples/quickstart.py

Walks through the public API in ~2 minutes on CPU:
  1. a decoupled linear layer in isolation (the paper's core module);
  2. a small pQuant LM trained for 30 steps (two-phase schedule, STE);
  3. inference export: 1-bit weights packed 8/byte + the W1A8 kernel path;
  4. sensitivity: the democratization score before/after.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import QuantConfig, decoupled_ffn, init_decoupled_ffn
from repro.core.packing import export_bit_weight
from repro.core.sensitivity import democratization_score, obs_sensitivity
from repro.data.pipeline import DataConfig, SyntheticSource, host_batch
from repro.kernels import ops
from repro.train.trainer import Trainer, TrainerConfig

key = jax.random.PRNGKey(0)

# -- 1. the decoupled linear layer ------------------------------------------
print("== 1. decoupled FFN layer ==")
qc = QuantConfig(mode="pquant", r=32, num_experts=1, alpha_init=2.0, beta_init=0.2)
params, axes = init_decoupled_ffn(key, d_model=128, d_ff_1bit=256, r=32)
x = jax.random.normal(key, (4, 16, 128))
y, aux = decoupled_ffn(params, x, qc)
print(f"   in {x.shape} -> out {y.shape}; 1-bit trunk 256 wide, 8-bit branch 32 wide")

# -- 2. train a small pQuant LM ---------------------------------------------
print("== 2. train a pQuant LM for 30 steps ==")
cfg = ModelConfig(
    name="quickstart", family="decoder", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab_size=256, max_seq_len=64,
    quant=QuantConfig(mode="pquant", r=32),
)
src = SyntheticSource(cfg.vocab_size, seed=0)
dcfg = DataConfig(seq_len=32, global_batch=8)
data = ((s, host_batch(src, dcfg, s)) for s in range(31))
trainer = Trainer(cfg, TrainerConfig(total_steps=30, log_every=10), data)
hist = trainer.run()
print(f"   loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

# -- 3. inference export + W1A8 kernel --------------------------------------
print("== 3. pack 1-bit weights and run the W1A8 kernel ==")
w_latent = trainer.state.params["segments"][0]["b0"]["ffn"]["w1_up"][0]
pw = export_bit_weight(w_latent)
print(f"   latent {w_latent.shape} fp32 {w_latent.nbytes} B -> packed {pw.packed.nbytes} B "
      f"({w_latent.nbytes / pw.packed.nbytes:.0f}x smaller)")
h = jax.random.normal(key, (4, w_latent.shape[0])) * 0.2
y_kernel = ops.bit_linear_infer(h, pw.packed, pw.lam, out_dtype=jnp.float32)
y_ref = h @ pw.dequantize()
print(f"   kernel vs dequant-matmul max err: "
      f"{np.abs(np.asarray(y_kernel) - np.asarray(y_ref)).max():.4f}")

# -- 4. sensitivity ----------------------------------------------------------
print("== 4. parameter democratization ==")
calib = jax.random.normal(key, (1024, cfg.d_model))
from repro.core.quantization import binarize_weights

s_fp = democratization_score(obs_sensitivity(w_latent, calib))
s_1b = democratization_score(obs_sensitivity(binarize_weights(w_latent)[0], calib))
print(f"   democratization score: latent fp32 {float(s_fp):.4f} vs 1-bit {float(s_1b):.4f} "
      f"(1.0 = fully uniform — the paper's pathology)")
print("done.")
