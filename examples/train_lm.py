"""End-to-end training driver: pre-train a ~100M-parameter pQuant LM from
scratch (QAT-Scratch, paper §4) for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py                 # full run
    PYTHONPATH=src python examples/train_lm.py --smoke         # 20-step CI run

This is a thin, documented wrapper over the production launcher
(repro.launch.train): same config system, checkpointing, resume, and the
two-phase schedule.  Compare baselines by passing --quant-mode
{bitnet,bitnet158,none}.  Artifacts: results/train100m/{log,history}.
"""

import argparse
import pathlib
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="20-step CI variant")
    ap.add_argument("--quant-mode", default="pquant")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--out", default="results/train100m_example")
    args = ap.parse_args()

    pathlib.Path(args.out).mkdir(parents=True, exist_ok=True)
    argv = [
        "--arch", "pquant-100m",
        "--quant-mode", args.quant_mode,
        "--seq-len", "128",
        "--global-batch", "4",
        "--dtype", "float32",  # CPU-friendly; use bfloat16 on TPU
        "--ckpt-dir", f"{args.out}/ckpt",
        "--history-out", f"{args.out}/history.json",
        "--log-every", "10",
        # QAT health telemetry artifacts (repro.telemetry): per-step probes
        # in the history, lifecycle trace + metrics snapshot next to it
        "--probes",
        "--sensitivity-every", "50",
        "--trace-jsonl", f"{args.out}/train_trace.jsonl",
        "--metrics-out", f"{args.out}/train_metrics.json",
    ]
    if args.smoke:
        argv += ["--steps", "20", "--reduced"]
    else:
        argv += ["--steps", str(args.steps)]
    history = train_main(argv)
    steps = [h for h in history if "nll" in h and "event" not in h]
    if steps and steps[-1]["nll"] < steps[0]["nll"]:
        print("OK: loss decreased")
        return 0
    print("WARNING: loss did not decrease")
    return 1


if __name__ == "__main__":
    sys.exit(main())
