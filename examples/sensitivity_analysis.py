"""Reproduce the paper's sensitivity analysis (Figures 2 & 5a) at CPU scale.

    PYTHONPATH=src python examples/sensitivity_analysis.py

Trains three small models from scratch (FP16 / BitNet 1-bit / pQuant),
computes the OBS sensitivity landscape of an FFN weight matrix under a
calibration batch, and prints:
  * the democratization score (normalized sensitivity entropy, 1 = uniform);
  * top-1% sensitivity mass (how concentrated the important weights are);
  * an ASCII heat map of the max-pooled landscape (the paper's Figure 2).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import binarize_weights, quantize_weights_int8
from repro.core.sensitivity import (
    democratization_score,
    max_pool_2d,
    obs_sensitivity,
    top_fraction_mass,
)
from benchmarks.common import quick_train, tiny_config

SHADES = " .:-=+*#%@"


def ascii_heatmap(sens, rows=8, cols=32):
    pooled = np.log(np.asarray(max_pool_2d(sens, (rows, cols))) + 1e-12)
    lo, hi = pooled.min(), pooled.max()
    norm = (pooled - lo) / (hi - lo + 1e-9)
    for r in norm:
        print("   |" + "".join(SHADES[int(v * (len(SHADES) - 1))] for v in r) + "|")


def analyze(name, w, calib):
    s = obs_sensitivity(w, calib)
    print(f"-- {name}")
    print(f"   democratization score: {float(democratization_score(s)):.4f} (1.0 = uniform)")
    print(f"   top-1% sensitivity mass: {float(top_fraction_mass(s)):.3f}")
    ascii_heatmap(s)
    return float(democratization_score(s))


def main(steps=80):
    calib = jax.random.normal(jax.random.PRNGKey(9), (2048, 64)) * jnp.exp(
        0.5 * jax.random.normal(jax.random.PRNGKey(10), (64,))
    )
    print("training FP16 / BitNet / pQuant (~2 min)...")
    scores = {}

    _, tr = quick_train(tiny_config("none"), steps=steps)
    w = tr.state.params["segments"][0]["b0"]["ffn"]["w1_up"][-1]
    scores["fp16"] = analyze("FP16 final-FFN up-proj (differentiated)", w, calib)

    _, tr = quick_train(tiny_config("bitnet"), steps=steps)
    w = tr.state.params["segments"][0]["b0"]["ffn"]["w1_up"][-1]
    wq, _ = binarize_weights(w)
    scores["bitnet"] = analyze("BitNet 1-bit weights (democratized)", wq, calib)

    _, tr = quick_train(tiny_config("pquant"), steps=steps)
    ffn = tr.state.params["segments"][0]["b0"]["ffn"]
    w1q, _ = binarize_weights(ffn["w1_up"][-1])
    scores["pquant_1bit"] = analyze("pQuant 1-bit trunk", w1q, calib)
    w8q, _ = quantize_weights_int8(ffn["w8_up"][-1][0])
    scores["pquant_8bit"] = analyze("pQuant 8-bit branch (sensitive params)", w8q, calib)

    print("\nsummary (paper's qualitative ordering):")
    print(f"  BitNet more uniform than FP16:   {scores['bitnet'] > scores['fp16']}")
    print(f"  pQuant 8-bit branch differentiated vs BitNet: "
          f"{scores['pquant_8bit'] < scores['bitnet']}")


if __name__ == "__main__":
    main()
