"""Automatic prefix caching: bit-for-bit stream parity with the cold
engine and the lockstep DecodeEngine oracle (the acceptance criterion),
copy-on-write on fully-cached prompts, multi-turn chain extension over
generated tokens, LRU eviction under a tiny pool, the dense-layout /
unsafe-config gates, kernel-read-path parity, and the hit/miss/CoW
metrics + trace events."""

import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig
from repro.models import api
from repro.serve.engine import DecodeEngine, SamplerConfig
from repro.serve.scheduler import ContinuousBatchingEngine
from repro.serve.tracing import ListSink, RequestTracer

KEY = jax.random.PRNGKey(1)
QC = QuantConfig(mode="pquant", r=16, num_experts=1)
CFG = ModelConfig(name="t", family="decoder", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64, quant=QC)
SWA_CFG = ModelConfig(name="t2", family="decoder", n_layers=6, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64,
                      quant=QC, attn_type="swa", window_size=4,
                      global_every=3, rope_theta_local=1e3)
MAX_LEN = 48
BS = 8  # block size everywhere below
SCFG = SamplerConfig(temperature=0.7, top_k=10, max_new_tokens=6)


@pytest.fixture(scope="module")
def params():
    return api.init_model(KEY, CFG)[0]


@pytest.fixture(scope="module")
def reference(params):
    return DecodeEngine(params, CFG, MAX_LEN)


def _toks(seed, n):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, 64), np.int32
    )


# One 17-token shared system prefix (spans two full blocks + 1), plus
# per-request suffixes of ragged length — the canonical hit shape.
PREFIX = _toks(99, 17)
SUFFIXES = {0: 4, 1: 1, 2: 6, 3: 3}


def _shared_prompt(uid):
    return np.concatenate([PREFIX, _toks(200 + uid, SUFFIXES[uid])])


def _engine(params, *, prefix_cache=True, num_blocks=None, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("chunk", 4)
    eng = ContinuousBatchingEngine(
        params, CFG, max_len=MAX_LEN, scfg=SCFG, layout="paged",
        block_size=BS, prefix_cache=prefix_cache,
        num_blocks=num_blocks, **kw,
    )
    return eng


def _drained(eng):
    """The zero-leak drain invariant: with the cache warm, released
    blocks park on the LRU but still count as free."""
    return (
        eng.allocator.free_count == eng.num_blocks
        and eng.allocator.used_count == 0
        and eng.snapshot()["gauges"]["pool_blocks_used"] == 0
    )


@pytest.mark.parametrize("prefill_chunk", [None, 3])
def test_warm_hits_are_bit_for_bit(params, reference, prefill_chunk):
    """Acceptance: requests sharing a prompt prefix produce streams
    identical to the per-request DecodeEngine oracle on BOTH the one-shot
    and chunked admission paths, while later admissions actually hit the
    cache (hit counters advance and hit_tokens covers the shared
    blocks)."""
    want = {
        uid: reference.generate(
            jnp.asarray(_shared_prompt(uid)[None]), SCFG, seed=uid
        )[0]
        for uid in SUFFIXES
    }
    eng = _engine(params, prefill_chunk=prefill_chunk)
    for uid in SUFFIXES:
        eng.submit(_shared_prompt(uid), max_new_tokens=6, seed=uid, uid=uid)
    finished = eng.run()
    assert sorted(f.uid for f in finished) == sorted(SUFFIXES)
    for f in finished:
        np.testing.assert_array_equal(f.tokens, want[f.uid])
        assert f.finish_reason == "length"
    snap = eng.snapshot()["counters"]
    # 4 prompts x 2 full shared blocks; at least the later admissions hit
    assert snap["prefix_cache_hits_total"] >= 2
    assert snap["prefix_cache_misses_total"] >= 2
    assert snap["prefix_cache_hit_tokens_total"] >= 2 * BS
    assert _drained(eng)


@pytest.mark.parametrize("prefill_chunk", [None, 3])
def test_warm_engine_matches_cold_engine(params, prefill_chunk):
    """The cache is stream-invisible: the same submission trace through a
    prefix_cache engine and a cold engine yields identical tokens for
    every request."""
    outs = {}
    for pc in (False, True):
        eng = _engine(params, prefix_cache=pc, prefill_chunk=prefill_chunk)
        for uid in SUFFIXES:
            eng.submit(_shared_prompt(uid), max_new_tokens=6, seed=uid,
                       uid=uid)
        outs[pc] = {f.uid: f.tokens for f in eng.run()}
    assert sorted(outs[True]) == sorted(outs[False])
    for uid in outs[True]:
        np.testing.assert_array_equal(outs[True][uid], outs[False][uid])


@pytest.mark.parametrize("prefill_chunk", [None, 3])
def test_fully_cached_prompt_copies_on_write(params, reference,
                                             prefill_chunk):
    """A block-aligned prompt resubmitted verbatim is fully cached; the
    recompute of its final position would write inside the last shared
    block, so admission copies it to a private page first — and the
    repeat stream (different seed) still matches its own oracle while the
    first request's blocks stay pristine for a third hit."""
    prompt = _toks(7, 3 * BS)  # 24 tokens: exactly 3 full blocks
    want = {
        uid: reference.generate(jnp.asarray(prompt[None]), SCFG, seed=uid)[0]
        for uid in (0, 1, 2)
    }
    eng = _engine(params, prefill_chunk=prefill_chunk)
    for uid in (0, 1, 2):
        eng.submit(prompt, max_new_tokens=6, seed=uid, uid=uid)
    finished = eng.run()
    for f in finished:
        np.testing.assert_array_equal(f.tokens, want[f.uid])
    snap = eng.snapshot()["counters"]
    assert snap["prefix_cache_cow_total"] >= 1
    assert _drained(eng)


def test_multi_turn_chain_extends_over_generated_tokens(params):
    """On release the hash chain extends over *generated* tokens, so a
    follow-up prompt of (history + reply) hits blocks the previous turn
    decoded into — not just its prompt blocks."""
    prompt = _toks(3, 2 * BS - 2)  # 14 tokens
    eng = _engine(params, num_slots=1)
    eng.submit(prompt, max_new_tokens=12, seed=0, uid=0)
    (turn1,) = eng.run()
    # turn-2 prompt: the whole turn-1 conversation plus a new user turn
    history = np.concatenate([prompt, turn1.tokens]).astype(np.int32)
    assert len(history) >= 3 * BS  # decode extended past the prompt blocks
    turn2_prompt = np.concatenate([history, _toks(5, 3)])
    before = eng.snapshot()["counters"]["prefix_cache_hits_total"]
    eng.submit(turn2_prompt, max_new_tokens=4, seed=1, uid=1)
    (turn2,) = eng.run()
    hits = eng.snapshot()["counters"]["prefix_cache_hits_total"] - before
    assert hits >= 3  # history blocks, including decode-written ones
    assert turn2.finish_reason == "length"
    # oracle check: the follow-up matches a cold engine on the same prompt
    cold = _engine(params, prefix_cache=False, num_slots=1)
    cold.submit(turn2_prompt, max_new_tokens=4, seed=1, uid=1)
    (want2,) = cold.run()
    np.testing.assert_array_equal(turn2.tokens, want2.tokens)
    assert _drained(eng)


def test_eviction_under_tiny_pool_keeps_parity(params):
    """A pool too small to keep every finished prompt cached evicts
    least-recently-released blocks (counter advances, hash entries die)
    and every stream still matches the cold engine."""
    prompts = {uid: _toks(uid + 40, 11 + 3 * uid) for uid in range(5)}
    outs = {}
    for pc in (False, True):
        eng = _engine(params, prefix_cache=pc, num_slots=1, num_blocks=4)
        for uid, p in prompts.items():
            eng.submit(p, max_new_tokens=5, seed=uid, uid=uid)
        outs[pc] = {f.uid: f.tokens for f in eng.run()}
        if pc:
            snap = eng.snapshot()["counters"]
            assert snap["prefix_cache_evictions_total"] > 0
            assert _drained(eng)
    for uid in prompts:
        np.testing.assert_array_equal(outs[True][uid], outs[False][uid])


def test_dense_layout_rejected(params):
    with pytest.raises(ValueError, match="paged layout"):
        ContinuousBatchingEngine(
            params, CFG, num_slots=2, max_len=MAX_LEN, scfg=SCFG,
            layout="dense", chunk=4, prefix_cache=True,
        )


def test_unsafe_config_declines_to_cold_with_one_log(caplog):
    """Sliding-window mixers keep ring state outside the paged pool, so
    prefix_cache=True declines (runs cold) with one warning per config —
    and the engine still serves correctly."""
    from repro.serve import scheduler as sched

    sched._PREFIX_DECLINE_LOGGED.clear()
    params, _ = api.init_model(KEY, SWA_CFG)
    with caplog.at_level(logging.WARNING, logger=sched.__name__):
        engines = [
            ContinuousBatchingEngine(
                params, SWA_CFG, num_slots=2, max_len=24, scfg=SCFG,
                layout="paged", block_size=8, chunk=3, prefix_cache=True,
            )
            for _ in range(2)
        ]
    lines = [r for r in caplog.records if "prefix caching declined" in
             r.getMessage()]
    assert len(lines) == 1
    eng = engines[0]
    assert not eng.prefix_cache
    ref = DecodeEngine(params, SWA_CFG, 24)
    p = _toks(11, 9)
    want = ref.generate(jnp.asarray(p[None]), SCFG, seed=0)[0]
    eng.submit(p, max_new_tokens=6, seed=0, uid=0)
    (f,) = eng.run()
    np.testing.assert_array_equal(f.tokens, want)
    # cold admissions count as misses=0 hits=0: the cache never engaged
    snap = eng.snapshot()["counters"]
    assert snap["prefix_cache_hits_total"] == 0
    assert snap["prefix_cache_misses_total"] == 0


def test_parity_with_paged_attention_kernel(params, reference, monkeypatch):
    """Warm cache hits under the Pallas paged-attention read path: greedy
    streams still equal the DecodeEngine oracle (the kernel reads reused
    pages exactly as freshly-prefilled ones)."""
    from repro.kernels import ops

    monkeypatch.setenv("REPRO_PAGED_ATTN", "1")
    assert ops.paged_attention_enabled()
    scfg = SamplerConfig(temperature=0.0, max_new_tokens=4)
    want = {
        uid: reference.generate(
            jnp.asarray(_shared_prompt(uid)[None]), scfg, seed=uid
        )[0]
        for uid in (0, 1, 2)
    }
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=scfg,
        layout="paged", block_size=BS, chunk=2, prefix_cache=True,
    )
    for uid in (0, 1, 2):
        eng.submit(_shared_prompt(uid), max_new_tokens=4, seed=uid, uid=uid)
    finished = eng.run()
    assert eng.snapshot()["counters"]["prefix_cache_hits_total"] > 0
    for f in finished:
        np.testing.assert_array_equal(f.tokens, want[f.uid])
    assert _drained(eng)


def test_trace_events_and_metric_presence(params):
    """Hits and CoW land on the request timeline (``prefix_hit`` with
    block/token counts, ``block_cow`` with src/dst) and all five
    prefix-cache counters are schema-present in the snapshot even before
    anything fires."""
    sink = ListSink()
    eng = _engine(params, tracer=RequestTracer(sink))
    snap0 = eng.snapshot()["counters"]
    for name in ("prefix_cache_hits_total", "prefix_cache_misses_total",
                 "prefix_cache_hit_tokens_total", "prefix_cache_cow_total",
                 "prefix_cache_evictions_total"):
        assert snap0[name] == 0
    prompt = _toks(7, 2 * BS)
    for uid in (0, 1):
        eng.submit(prompt, max_new_tokens=4, seed=uid, uid=uid)
    eng.run()
    events = {r["event"] for r in sink.records}
    assert "prefix_hit" in events and "block_cow" in events
    hit = next(r for r in sink.records if r["event"] == "prefix_hit")
    assert hit["n_blocks"] >= 1 and hit["n_tokens"] >= BS - 1
    cow = next(r for r in sink.records if r["event"] == "block_cow")
    assert cow["src"] != cow["dst"]
