"""Optimizer, schedule, checkpoint, data pipeline, tokenizer tests."""

import os
import tempfile

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package"
)
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import (
    DataConfig,
    PrefetchIterator,
    SyntheticSource,
    TextFileSource,
    host_batch,
)
from repro.data.tokenizer import BPETokenizer, ByteTokenizer
from repro.optim.adamw import AdamWConfig, adamw_update, global_norm, init_adamw
from repro.optim.schedule import CosineSchedule, TwoPhaseSchedule, schedule_for_mode


class TestSchedule:
    def test_two_phase_shape(self):
        s = TwoPhaseSchedule(total_steps=1000, warmup_steps=50)
        # warmup rises
        assert float(s.lr(10)) < float(s.lr(49))
        # drop at midpoint (the paper's S-curve loss driver)
        assert float(s.lr(499)) > float(s.lr(501))
        # wd switches off in phase 2
        assert float(s.wd(100)) == pytest.approx(0.1)
        assert float(s.wd(600)) == 0.0

    def test_monotone_decay_within_phases(self):
        s = TwoPhaseSchedule(total_steps=1000, warmup_steps=50)
        lrs = [float(s.lr(t)) for t in range(51, 499, 50)]
        assert all(a >= b for a, b in zip(lrs, lrs[1:]))

    def test_cosine_for_fp16(self):
        s = schedule_for_mode("none", 1000)
        assert isinstance(s, CosineSchedule)
        assert float(s.wd(700)) == pytest.approx(0.1)

    def test_quant_modes_get_two_phase(self):
        for mode in ("pquant", "bitnet", "bitnet158"):
            assert isinstance(schedule_for_mode(mode, 100), TwoPhaseSchedule)


class TestAdamW:
    def _setup(self):
        params = {"w": jnp.ones((8, 8)), "norm_scale": jnp.ones(8),
                  "alpha": jnp.asarray(2.0)}
        return params, init_adamw(params)

    def test_descends_quadratic(self):
        params, state = self._setup()
        lr, wd = jnp.asarray(5e-2), jnp.asarray(0.0)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        l0 = float(loss(params))
        for _ in range(40):  # Adam moves ~lr per step from |w|=1
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(g, state, params, lr, wd)
        assert float(loss(params)) < l0 * 0.1

    def test_no_decay_on_scalars_and_norms(self):
        params, state = self._setup()
        zero_g = jax.tree.map(jnp.zeros_like, params)
        new, _, _ = adamw_update(zero_g, state, params, jnp.asarray(1e-2),
                                 jnp.asarray(0.5))
        # decayed: w; untouched by wd: norm_scale, alpha
        assert float(jnp.abs(new["w"] - params["w"]).sum()) > 0
        np.testing.assert_allclose(np.asarray(new["alpha"]), 2.0)
        np.testing.assert_allclose(np.asarray(new["norm_scale"]), 1.0)

    def test_clipping(self):
        params, state = self._setup()
        big_g = jax.tree.map(lambda p: jnp.full_like(p, 1e6), params)
        _, _, m = adamw_update(big_g, state, params, jnp.asarray(1e-3),
                               jnp.asarray(0.0), AdamWConfig(clip_norm=1.0))
        assert float(m["grad_norm"]) > 1.0  # reported pre-clip


class TestCheckpointer:
    def test_roundtrip_and_retention(self):
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d, keep=2)
            tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                    "b": {"c": jnp.ones(4, jnp.bfloat16)}}
            for s in (1, 2, 3):
                ck.save(s, tree, blocking=True)
            assert ck.all_steps() == [2, 3]  # keep=2 retention
            out = ck.restore(tree)
            np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
            assert out["b"]["c"].dtype == jnp.bfloat16

    def test_atomicity_no_tmp_left(self):
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(5, {"x": jnp.ones(3)}, blocking=True)
            assert not any(n.endswith(".tmp") for n in os.listdir(d))

    def test_async_save(self):
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(1, {"x": jnp.ones(3)}, blocking=False)
            ck.wait()
            assert ck.latest_step() == 1

    def test_shape_mismatch_rejected(self):
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(1, {"x": jnp.ones(3)}, blocking=True)
            with pytest.raises(AssertionError):
                ck.restore({"x": jnp.ones(4)})


class TestData:
    def test_determinism(self):
        src = SyntheticSource(256, seed=3)
        cfg = DataConfig(seq_len=32, global_batch=4)
        b1 = host_batch(src, cfg, 7)
        b2 = host_batch(src, cfg, 7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_host_shards_disjoint(self):
        src = SyntheticSource(256, seed=3)
        full = host_batch(src, DataConfig(seq_len=16, global_batch=4), 0)
        h0 = host_batch(src, DataConfig(seq_len=16, global_batch=4,
                                        host_count=2, host_index=0), 0)
        h1 = host_batch(src, DataConfig(seq_len=16, global_batch=4,
                                        host_count=2, host_index=1), 0)
        np.testing.assert_array_equal(
            np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"]
        )

    def test_labels_are_next_tokens(self):
        src = SyntheticSource(256, seed=0)
        b = host_batch(src, DataConfig(seq_len=16, global_batch=2), 0)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)

    def test_prefetch(self):
        src = SyntheticSource(64, seed=0)
        it = PrefetchIterator(src, DataConfig(seq_len=8, global_batch=2))
        steps = [next(it)[0] for _ in range(3)]
        it.close()
        assert steps == [0, 1, 2]

    def test_text_source(self, tmp_path):
        p = tmp_path / "corpus.txt"
        p.write_text("hello world, this is a tiny corpus for testing " * 20)
        src = TextFileSource([str(p)])
        b = host_batch(src, DataConfig(seq_len=16, global_batch=2), 0)
        assert (b["tokens"] >= 0).all()


class TestTokenizers:
    def test_byte_roundtrip(self):
        t = ByteTokenizer()
        s = "héllo wörld ☺"
        assert t.decode(t.encode(s)) == s

    @hypothesis.settings(max_examples=20, deadline=None)
    @hypothesis.given(st.text(min_size=0, max_size=64))
    def test_bpe_roundtrip_property(self, s):
        tok = BPETokenizer.train([s + " the quick brown fox " * 3], vocab_size=280)
        assert tok.decode(tok.encode(s)) == s

    def test_bpe_compresses(self):
        corpus = "the quick brown fox jumps over the lazy dog " * 50
        tok = BPETokenizer.train([corpus], vocab_size=400)
        byte_len = len(ByteTokenizer().encode(corpus))
        bpe_len = len(tok.encode(corpus))
        assert bpe_len < byte_len * 0.6

    def test_persistence(self, tmp_path):
        tok = BPETokenizer.train(["abcabcabc " * 10], vocab_size=270)
        path = str(tmp_path / "tok.json")
        tok.save(path)
        tok2 = BPETokenizer.load(path)
        assert tok2.encode("abcabc") == tok.encode("abcabc")
