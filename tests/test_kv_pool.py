"""Paged KV pool unit tests: allocator accounting (incl. a hypothesis
property test over random alloc/free/preemption traces), scatter/gather
roundtrips, masked writes, and the dense-view equivalence the attention
parity tests build on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import kv_pool


class TestBlockAllocator:
    def test_alloc_free_roundtrip(self):
        a = kv_pool.BlockAllocator(8)
        got = a.alloc(5)
        assert len(got) == 5 and len(set(got)) == 5
        assert a.free_count == 3
        a.free(got)
        assert a.free_count == 8

    def test_exhaustion_returns_none_without_side_effects(self):
        a = kv_pool.BlockAllocator(4)
        first = a.alloc(3)
        assert a.alloc(2) is None
        assert a.free_count == 1  # failed alloc took nothing
        a.free(first)
        assert a.free_count == 4

    def test_double_free_rejected(self):
        a = kv_pool.BlockAllocator(4)
        got = a.alloc(2)
        a.free(got)
        with pytest.raises(ValueError, match="double free"):
            a.free([got[0]])

    def test_foreign_id_rejected(self):
        a = kv_pool.BlockAllocator(4)
        with pytest.raises(ValueError, match="out of range"):
            a.free([99])

    @staticmethod
    def _check_alloc_trace(num_blocks: int, ops) -> None:
        """Invariant driver for one alloc/free/preemption trace: the
        allocator never double-allocates a live block, a failed alloc
        changes nothing, and ``free_count + outstanding == num_blocks``
        holds at every step (conservation — no block leaks, no block
        invented).  ``ops`` is a list of (kind, n, pick) int triples."""
        a = kv_pool.BlockAllocator(num_blocks)
        live: dict[int, list[int]] = {}  # request -> owned blocks
        next_uid = 0
        for kind, n, pick in ops:
            outstanding = sum(len(v) for v in live.values())
            assert a.free_count + outstanding == num_blocks
            if kind == 0:  # admission / per-chunk growth alloc
                got = a.alloc(n)
                if n > num_blocks - outstanding:
                    assert got is None  # exhaustion: and no state change
                    assert a.free_count == num_blocks - outstanding
                    continue
                assert got is not None and len(got) == n
                owned = {b for v in live.values() for b in v}
                # no double allocation: fresh ids only, all in range
                assert not (set(got) & owned)
                assert len(set(got)) == n
                assert all(0 <= b < num_blocks for b in got)
                if pick % 2 and live:  # growth of an existing request
                    live[sorted(live)[pick % len(live)]].extend(got)
                else:
                    live[next_uid] = list(got)
                    next_uid += 1
            elif kind == 1 and live:  # eviction / preemption (free all)
                uid = sorted(live)[pick % len(live)]
                a.free(live.pop(uid))
            elif kind == 2 and live:  # double free must be rejected
                uid = sorted(live)[pick % len(live)]
                blocks = live.pop(uid)
                a.free(blocks)
                if blocks:
                    with pytest.raises(ValueError, match="double free"):
                        a.free(blocks[:1])
        outstanding = sum(len(v) for v in live.values())
        assert a.free_count + outstanding == num_blocks

    def test_property_random_alloc_free_preempt_traces(self):
        """Hypothesis property test over arbitrary op interleavings (the
        shrinking search is what earns its keep on a counterexample)."""
        hypothesis = pytest.importorskip("hypothesis")
        st = hypothesis.strategies

        @hypothesis.given(
            num_blocks=st.integers(1, 24),
            ops=st.lists(
                st.tuples(
                    st.integers(0, 2), st.integers(0, 8), st.integers(0, 7)
                ),
                max_size=60,
            ),
        )
        @hypothesis.settings(deadline=None, max_examples=60)
        def run(num_blocks, ops):
            self._check_alloc_trace(num_blocks, ops)

        run()

    def test_fail_hook_forces_exhaustion_semantics(self):
        """The fault-injection seam: a firing hook makes ``alloc`` return
        None with NO state change (exactly the pool-exhausted contract);
        a quiet hook is invisible."""
        calls = iter([True, False])
        a = kv_pool.BlockAllocator(4, fail_hook=lambda: next(calls))
        assert a.alloc(2) is None  # forced failure
        assert a.free_count == 4  # took nothing
        got = a.alloc(2)  # hook quiet: normal alloc
        assert len(got) == 2 and a.free_count == 2
        a.free(got)
        assert a.free_count == 4

    def test_random_alloc_free_preempt_traces_seeded(self):
        """Seeded-random sweep through the same invariant driver so the
        property is exercised even where hypothesis isn't installed."""
        rng = np.random.default_rng(0)
        for _ in range(50):
            num_blocks = int(rng.integers(1, 25))
            ops = [
                (int(rng.integers(0, 3)), int(rng.integers(0, 9)),
                 int(rng.integers(0, 8)))
                for _ in range(int(rng.integers(0, 61)))
            ]
            self._check_alloc_trace(num_blocks, ops)


class TestPagedReadWrite:
    B, MB, BS, H, D, NB = 2, 3, 4, 2, 8, 7

    def _pool_and_table(self):
        pool = jnp.zeros((self.NB, self.BS, self.H, self.D), jnp.float32)
        # slot 0 owns blocks [1, 2, 3]; slot 1 owns [4, 5, 6]
        table = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
        return pool, table

    def test_write_read_roundtrip_position_order(self):
        pool, table = self._pool_and_table()
        vals = {}
        for p in range(self.MB * self.BS):
            v = jax.random.normal(
                jax.random.PRNGKey(p), (self.B, self.H, self.D)
            )
            pool = kv_pool.write(
                pool, table, jnp.full((self.B,), p, jnp.int32), v, None
            )
            vals[p] = np.asarray(v)
        dense = np.asarray(kv_pool.read(pool, table))
        assert dense.shape == (self.B, self.MB * self.BS, self.H, self.D)
        for p, v in vals.items():
            np.testing.assert_array_equal(dense[:, p], v)

    def test_inactive_slots_write_nothing(self):
        pool, table = self._pool_and_table()
        v = jnp.ones((self.B, self.H, self.D))
        pool2 = kv_pool.write(
            pool, table, jnp.zeros((self.B,), jnp.int32), v,
            jnp.asarray([True, False]),
        )
        dense = np.asarray(kv_pool.read(pool2, table))
        assert (dense[0, 0] == 1.0).all()
        assert (dense[1] == 0.0).all()  # inactive slot untouched

    def test_write_span_installs_dense_prefill_prefix(self):
        """One-shot admission install (the scheduler's _make_install_fn)
        is a batch-1 write_span of the prefilled dense cache, bounded to
        the prompt-covering pages — the pool holds the dense prefix
        element for element (scatter_prefill's old contract, now served
        by the one write path)."""
        pool, table = self._pool_and_table()
        nb = 2  # prompt covers two pages
        L = self.MB * self.BS  # the dense cache is full slot length
        dense = jax.random.normal(
            jax.random.PRNGKey(0), (1, L, self.H, self.D)
        )
        pool = kv_pool.write_span(
            pool, table[:1], jnp.zeros((1,), jnp.int32), dense, None,
            jnp.asarray([nb * self.BS], jnp.int32),
        )
        got = np.asarray(kv_pool.read(pool, table))[0]
        np.testing.assert_array_equal(
            got[: nb * self.BS], np.asarray(dense)[0, : nb * self.BS]
        )
        assert (got[nb * self.BS:] == 0.0).all()  # uncovered pages untouched

    def test_read_clamps_to_used_block_prefix(self):
        """``read(blocks=n)`` gathers only the first n table entries: same
        values on the covered prefix, and the short gather never touches
        the pool rows the dropped entries point at."""
        pool, table = self._pool_and_table()
        for p in range(self.BS + 1):  # spills into the second page
            v = jax.random.normal(
                jax.random.PRNGKey(p), (self.B, self.H, self.D)
            )
            pool = kv_pool.write(
                pool, table, jnp.full((self.B,), p, jnp.int32), v, None
            )
        full = np.asarray(kv_pool.read(pool, table))
        short = np.asarray(kv_pool.read(pool, table, blocks=2))
        assert short.shape == (self.B, 2 * self.BS, self.H, self.D)
        np.testing.assert_array_equal(short, full[:, : 2 * self.BS])
        # the clamp never returns an empty gather and caps at the table
        assert kv_pool.read(pool, table, blocks=0).shape[1] == self.BS
        assert (
            kv_pool.read(pool, table, blocks=99).shape[1]
            == self.MB * self.BS
        )

    def test_write_span_matches_token_loop(self):
        """The multi-token span scatter is elementwise the per-token
        ``write`` loop — chunked prefill's pages are bit-identical to what
        one-shot install would have produced."""
        pool, table = self._pool_and_table()
        t = 6  # crosses a page boundary (BS=4) at different offsets/slot
        pos = jnp.asarray([1, 3], jnp.int32)
        val = jax.random.normal(jax.random.PRNGKey(7), (self.B, t, self.H, self.D))
        got = kv_pool.write_span(pool, table, pos, val)
        want = pool
        for i in range(t):
            want = kv_pool.write(want, table, pos + i, val[:, i], None)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_write_span_masks_lengths_and_active(self):
        """Ragged final slices (``lengths``) and inactive slots write
        nothing — the pad tail of a chunked-prefill slice can never
        scribble into someone else's reclaimed page."""
        pool, table = self._pool_and_table()
        t = 4
        val = jnp.ones((self.B, t, self.H, self.D))
        got = kv_pool.write_span(
            pool, table, jnp.zeros((self.B,), jnp.int32), val,
            jnp.asarray([True, False]), jnp.asarray([2, 4], jnp.int32),
        )
        dense = np.asarray(kv_pool.read(got, table))
        assert (dense[0, :2] == 1.0).all()
        assert (dense[0, 2:] == 0.0).all()  # beyond lengths[0]
        assert (dense[1] == 0.0).all()  # inactive slot untouched

    def test_write_span_drops_positions_past_table(self):
        """Masked entries may run past the slot's table (padded slice at
        the end of a full slot): they are clipped + dropped, not wrapped
        into another slot's pages."""
        pool, table = self._pool_and_table()
        cap = self.MB * self.BS
        t = 3
        val = jnp.ones((self.B, t, self.H, self.D))
        got = kv_pool.write_span(
            pool, table, jnp.full((self.B,), cap - 1, jnp.int32), val,
            None, jnp.asarray([1, 1], jnp.int32),
        )
        dense = np.array(kv_pool.read(got, table))
        assert (dense[:, cap - 1] == 1.0).all()
        assert (np.asarray(got)[0] == 0.0).all()  # block 0 never touched
        dense[:, cap - 1] = 0.0
        assert (dense == 0.0).all()

    def test_blocks_for(self):
        assert kv_pool.blocks_for(1, 4) == 1
        assert kv_pool.blocks_for(4, 4) == 1
        assert kv_pool.blocks_for(5, 4) == 2

    def test_init_rejects_ragged_max_len(self):
        with pytest.raises(ValueError, match="multiple of block_size"):
            kv_pool.init_paged_attention_cache(2, 10, 2, 8, 4, 4, jnp.float32)
