"""Paged KV pool unit tests: allocator accounting, scatter/gather
roundtrips, masked writes, and the dense-view equivalence the attention
parity tests build on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import kv_pool


class TestBlockAllocator:
    def test_alloc_free_roundtrip(self):
        a = kv_pool.BlockAllocator(8)
        got = a.alloc(5)
        assert len(got) == 5 and len(set(got)) == 5
        assert a.free_count == 3
        a.free(got)
        assert a.free_count == 8

    def test_exhaustion_returns_none_without_side_effects(self):
        a = kv_pool.BlockAllocator(4)
        first = a.alloc(3)
        assert a.alloc(2) is None
        assert a.free_count == 1  # failed alloc took nothing
        a.free(first)
        assert a.free_count == 4

    def test_double_free_rejected(self):
        a = kv_pool.BlockAllocator(4)
        got = a.alloc(2)
        a.free(got)
        with pytest.raises(ValueError, match="double free"):
            a.free([got[0]])

    def test_foreign_id_rejected(self):
        a = kv_pool.BlockAllocator(4)
        with pytest.raises(ValueError, match="out of range"):
            a.free([99])


class TestPagedReadWrite:
    B, MB, BS, H, D, NB = 2, 3, 4, 2, 8, 7

    def _pool_and_table(self):
        pool = jnp.zeros((self.NB, self.BS, self.H, self.D), jnp.float32)
        # slot 0 owns blocks [1, 2, 3]; slot 1 owns [4, 5, 6]
        table = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
        return pool, table

    def test_write_read_roundtrip_position_order(self):
        pool, table = self._pool_and_table()
        vals = {}
        for p in range(self.MB * self.BS):
            v = jax.random.normal(
                jax.random.PRNGKey(p), (self.B, self.H, self.D)
            )
            pool = kv_pool.write(
                pool, table, jnp.full((self.B,), p, jnp.int32), v, None
            )
            vals[p] = np.asarray(v)
        dense = np.asarray(kv_pool.read(pool, table))
        assert dense.shape == (self.B, self.MB * self.BS, self.H, self.D)
        for p, v in vals.items():
            np.testing.assert_array_equal(dense[:, p], v)

    def test_inactive_slots_write_nothing(self):
        pool, table = self._pool_and_table()
        v = jnp.ones((self.B, self.H, self.D))
        pool2 = kv_pool.write(
            pool, table, jnp.zeros((self.B,), jnp.int32), v,
            jnp.asarray([True, False]),
        )
        dense = np.asarray(kv_pool.read(pool2, table))
        assert (dense[0, 0] == 1.0).all()
        assert (dense[1] == 0.0).all()  # inactive slot untouched

    def test_scatter_prefill_matches_dense_prefix(self):
        pool, table = self._pool_and_table()
        L = 2 * self.BS  # two pages of prompt
        dense = jax.random.normal(jax.random.PRNGKey(0), (L, self.H, self.D))
        pool = kv_pool.scatter_prefill(pool, dense, table[0, :2])
        got = np.asarray(kv_pool.read(pool, table))[0, :L]
        np.testing.assert_array_equal(got, np.asarray(dense))

    def test_write_span_matches_token_loop(self):
        """The multi-token span scatter is elementwise the per-token
        ``write`` loop — chunked prefill's pages are bit-identical to what
        one-shot install would have produced."""
        pool, table = self._pool_and_table()
        t = 6  # crosses a page boundary (BS=4) at different offsets/slot
        pos = jnp.asarray([1, 3], jnp.int32)
        val = jax.random.normal(jax.random.PRNGKey(7), (self.B, t, self.H, self.D))
        got = kv_pool.write_span(pool, table, pos, val)
        want = pool
        for i in range(t):
            want = kv_pool.write(want, table, pos + i, val[:, i], None)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_write_span_masks_lengths_and_active(self):
        """Ragged final slices (``lengths``) and inactive slots write
        nothing — the pad tail of a chunked-prefill slice can never
        scribble into someone else's reclaimed page."""
        pool, table = self._pool_and_table()
        t = 4
        val = jnp.ones((self.B, t, self.H, self.D))
        got = kv_pool.write_span(
            pool, table, jnp.zeros((self.B,), jnp.int32), val,
            jnp.asarray([True, False]), jnp.asarray([2, 4], jnp.int32),
        )
        dense = np.asarray(kv_pool.read(got, table))
        assert (dense[0, :2] == 1.0).all()
        assert (dense[0, 2:] == 0.0).all()  # beyond lengths[0]
        assert (dense[1] == 0.0).all()  # inactive slot untouched

    def test_write_span_drops_positions_past_table(self):
        """Masked entries may run past the slot's table (padded slice at
        the end of a full slot): they are clipped + dropped, not wrapped
        into another slot's pages."""
        pool, table = self._pool_and_table()
        cap = self.MB * self.BS
        t = 3
        val = jnp.ones((self.B, t, self.H, self.D))
        got = kv_pool.write_span(
            pool, table, jnp.full((self.B,), cap - 1, jnp.int32), val,
            None, jnp.asarray([1, 1], jnp.int32),
        )
        dense = np.array(kv_pool.read(got, table))
        assert (dense[:, cap - 1] == 1.0).all()
        assert (np.asarray(got)[0] == 0.0).all()  # block 0 never touched
        dense[:, cap - 1] = 0.0
        assert (dense == 0.0).all()

    def test_blocks_for(self):
        assert kv_pool.blocks_for(1, 4) == 1
        assert kv_pool.blocks_for(4, 4) == 1
        assert kv_pool.blocks_for(5, 4) == 2

    def test_init_rejects_ragged_max_len(self):
        with pytest.raises(ValueError, match="multiple of block_size"):
            kv_pool.init_paged_attention_cache(2, 10, 2, 8, 4, 4, jnp.float32)
