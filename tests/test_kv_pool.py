"""Paged KV pool unit tests: allocator accounting (incl. a hypothesis
property test over random alloc/free/preemption traces), scatter/gather
roundtrips, masked writes, and the dense-view equivalence the attention
parity tests build on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import kv_pool


class TestBlockAllocator:
    def test_alloc_free_roundtrip(self):
        a = kv_pool.BlockAllocator(8)
        got = a.alloc(5)
        assert len(got) == 5 and len(set(got)) == 5
        assert a.free_count == 3
        a.free(got)
        assert a.free_count == 8

    def test_exhaustion_returns_none_without_side_effects(self):
        a = kv_pool.BlockAllocator(4)
        first = a.alloc(3)
        assert a.alloc(2) is None
        assert a.free_count == 1  # failed alloc took nothing
        a.free(first)
        assert a.free_count == 4

    def test_double_free_rejected(self):
        a = kv_pool.BlockAllocator(4)
        got = a.alloc(2)
        a.free(got)
        with pytest.raises(ValueError, match="double free"):
            a.free([got[0]])

    def test_foreign_id_rejected(self):
        a = kv_pool.BlockAllocator(4)
        with pytest.raises(ValueError, match="out of range"):
            a.free([99])

    @staticmethod
    def _check_alloc_trace(num_blocks: int, ops) -> None:
        """Invariant driver for one alloc/free/preemption trace: the
        allocator never double-allocates a live block, a failed alloc
        changes nothing, and ``free_count + outstanding == num_blocks``
        holds at every step (conservation — no block leaks, no block
        invented).  ``ops`` is a list of (kind, n, pick) int triples."""
        a = kv_pool.BlockAllocator(num_blocks)
        live: dict[int, list[int]] = {}  # request -> owned blocks
        next_uid = 0
        for kind, n, pick in ops:
            outstanding = sum(len(v) for v in live.values())
            assert a.free_count + outstanding == num_blocks
            if kind == 0:  # admission / per-chunk growth alloc
                got = a.alloc(n)
                if n > num_blocks - outstanding:
                    assert got is None  # exhaustion: and no state change
                    assert a.free_count == num_blocks - outstanding
                    continue
                assert got is not None and len(got) == n
                owned = {b for v in live.values() for b in v}
                # no double allocation: fresh ids only, all in range
                assert not (set(got) & owned)
                assert len(set(got)) == n
                assert all(0 <= b < num_blocks for b in got)
                if pick % 2 and live:  # growth of an existing request
                    live[sorted(live)[pick % len(live)]].extend(got)
                else:
                    live[next_uid] = list(got)
                    next_uid += 1
            elif kind == 1 and live:  # eviction / preemption (free all)
                uid = sorted(live)[pick % len(live)]
                a.free(live.pop(uid))
            elif kind == 2 and live:  # double free must be rejected
                uid = sorted(live)[pick % len(live)]
                blocks = live.pop(uid)
                a.free(blocks)
                if blocks:
                    with pytest.raises(ValueError, match="double free"):
                        a.free(blocks[:1])
        outstanding = sum(len(v) for v in live.values())
        assert a.free_count + outstanding == num_blocks

    def test_property_random_alloc_free_preempt_traces(self):
        """Hypothesis property test over arbitrary op interleavings (the
        shrinking search is what earns its keep on a counterexample)."""
        hypothesis = pytest.importorskip("hypothesis")
        st = hypothesis.strategies

        @hypothesis.given(
            num_blocks=st.integers(1, 24),
            ops=st.lists(
                st.tuples(
                    st.integers(0, 2), st.integers(0, 8), st.integers(0, 7)
                ),
                max_size=60,
            ),
        )
        @hypothesis.settings(deadline=None, max_examples=60)
        def run(num_blocks, ops):
            self._check_alloc_trace(num_blocks, ops)

        run()

    def test_fail_hook_forces_exhaustion_semantics(self):
        """The fault-injection seam: a firing hook makes ``alloc`` return
        None with NO state change (exactly the pool-exhausted contract);
        a quiet hook is invisible."""
        calls = iter([True, False])
        a = kv_pool.BlockAllocator(4, fail_hook=lambda: next(calls))
        assert a.alloc(2) is None  # forced failure
        assert a.free_count == 4  # took nothing
        got = a.alloc(2)  # hook quiet: normal alloc
        assert len(got) == 2 and a.free_count == 2
        a.free(got)
        assert a.free_count == 4

    def test_random_alloc_free_preempt_traces_seeded(self):
        """Seeded-random sweep through the same invariant driver so the
        property is exercised even where hypothesis isn't installed."""
        rng = np.random.default_rng(0)
        for _ in range(50):
            num_blocks = int(rng.integers(1, 25))
            ops = [
                (int(rng.integers(0, 3)), int(rng.integers(0, 9)),
                 int(rng.integers(0, 8)))
                for _ in range(int(rng.integers(0, 61)))
            ]
            self._check_alloc_trace(num_blocks, ops)


class TestPrefixCacheAllocator:
    """Refcounts, the content-hash index and the LRU of cached blocks —
    the allocator surface automatic prefix caching runs on."""

    def test_unref_parks_registered_block_still_hittable(self):
        a = kv_pool.BlockAllocator(4)
        b = a.alloc(1)[0]
        assert a.register(b, 123)
        a.unref([b])
        # "free" means unreferenced: the block counts as allocatable AND
        # its content is still indexed
        assert a.free_count == 4 and a.used_count == 0
        assert a.lookup(123) == b

    def test_hit_ref_revives_cached_block_off_the_lru(self):
        a = kv_pool.BlockAllocator(2)
        b = a.alloc(1)[0]
        a.register(b, 7)
        a.unref([b])
        a.ref(b)  # admission hit
        assert a.refcount(b) == 1 and a.used_count == 1
        got = a.alloc(1)  # must come from the blank block, not evict b
        assert got != [b]
        assert a.alloc(1) is None  # pool genuinely full now
        a.unref([b] + got)
        assert a.free_count == 2 and a.lookup(7) == b

    def test_shared_block_refcounts_and_staged_release(self):
        a = kv_pool.BlockAllocator(4)
        b = a.alloc(1)[0]
        a.register(b, 9)
        a.ref(b)  # second owner
        a.ref(b)  # third owner
        assert a.refcount(b) == 3 and a.used_count == 1
        a.unref([b])
        a.unref([b])
        assert a.refcount(b) == 1  # still owned — not evictable
        blanks = a.alloc(3)
        assert b not in blanks
        a.unref([b])
        assert a.free_count == 1 and a.lookup(9) == b

    def test_lru_evicts_least_recently_released_and_drops_hash(self):
        a = kv_pool.BlockAllocator(2)
        b1 = a.alloc(1)
        a.register(b1[0], 111)
        b2 = a.alloc(1)
        a.register(b2[0], 222)
        a.unref(b1)
        a.unref(b2)  # release order: b1 is the older parkee
        got = a.alloc(1)
        assert got == b1  # LRU: least recently released goes first
        # the evicted block's identity died with it; the survivor's didn't
        assert a.lookup(111) is None
        assert a.lookup(222) == b2[0]

    def test_blank_blocks_allocated_before_cached(self):
        a = kv_pool.BlockAllocator(3)
        b = a.alloc(1)
        a.register(b[0], 1)
        a.unref(b)
        got = a.alloc(2)
        assert b[0] not in got  # blanks first: the cached block survives
        assert a.lookup(1) == b[0]

    def test_double_unref_rejected_via_refcount(self):
        a = kv_pool.BlockAllocator(4)
        got = a.alloc(2)
        a.unref(got)
        with pytest.raises(ValueError, match="double free"):
            a.unref([got[0]])
        # duplicates inside ONE call are caught too (and atomically:
        # validation precedes any mutation)
        b = a.alloc(1)[0]
        with pytest.raises(ValueError, match="double free"):
            a.unref([b, b])
        assert a.refcount(b) == 1

    def test_ref_of_blank_block_rejected(self):
        a = kv_pool.BlockAllocator(2)
        with pytest.raises(ValueError, match="blank"):
            a.ref(0)

    def test_register_requires_live_block_and_stable_hash(self):
        a = kv_pool.BlockAllocator(4)
        b1, b2 = a.alloc(2)
        with pytest.raises(ValueError, match="unreferenced"):
            a.register(3, 5)  # never allocated
        assert a.register(b1, 5)
        assert a.register(b1, 5)  # same (block, hash): idempotent
        with pytest.raises(ValueError, match="different hash"):
            a.register(b1, 6)
        # first writer wins: a duplicate content block stays private
        assert not a.register(b2, 5)
        assert a.lookup(5) == b1
        a.unref([b1, b2])
        # ... and recycles as blank (still allocatable, never indexed)
        assert a.free_count == 4 and a.lookup(5) == b1

    def test_metrics_guards_are_independent(self):
        """Satellite regression: ``alloc`` must count blocks even when the
        registry hands back no gauge — each instrument is guarded on its
        own, not nested under another's ``is not None``."""

        class _Counter:
            def __init__(self):
                self.value = 0

            def inc(self, n=1):
                self.value += n

        class _NoGaugeMetrics:
            def __init__(self):
                self.counters = {}

            def gauge(self, name):
                return None  # this registry has no gauges at all

            def counter(self, name):
                return self.counters.setdefault(name, _Counter())

        m = _NoGaugeMetrics()
        a = kv_pool.BlockAllocator(4, metrics=m)
        got = a.alloc(3)
        assert m.counters["block_allocs_total"].value == 3
        a.unref(got)
        assert a.alloc(9) is None
        assert m.counters["block_alloc_failures_total"].value == 1
        # eviction counting rides the same independent guard
        b = a.alloc(1)
        a.register(b[0], 42)
        a.unref(b)
        a.alloc(4)
        assert m.counters["prefix_cache_evictions_total"].value == 1

    def test_chain_hash_prefix_sensitivity(self):
        bs = 4
        t = list(range(16))
        h = kv_pool.prompt_block_hashes(t, bs)
        assert len(h) == 4
        t2 = list(t)
        t2[0] ^= 1
        h2 = kv_pool.prompt_block_hashes(t2, bs)
        # a first-block change reaches every descendant through the chain
        assert h2[0] != h[0] and h2[3] != h[3]
        t3 = list(t)
        t3[-1] ^= 1
        h3 = kv_pool.prompt_block_hashes(t3, bs)
        # a last-block change leaves the shared prefix ids alone
        assert h3[:3] == h[:3] and h3[3] != h[3]
        # the trailing partial block has no identity yet
        assert len(kv_pool.prompt_block_hashes(t[:15], bs)) == 3
        # host-stream identity: a numpy int32 stream hashes exactly like
        # python ints (what makes hits mesh/dtype-independent)
        assert kv_pool.prompt_block_hashes(np.asarray(t, np.int32), bs) == h

    def test_copy_block_duplicates_page_without_touching_source(self):
        pool = jax.random.normal(jax.random.PRNGKey(0), (4, 2, 2, 3))
        out = np.asarray(kv_pool.copy_block(pool, 1, 3))
        ref = np.asarray(pool)
        np.testing.assert_array_equal(out[3], ref[1])  # dst is the copy
        np.testing.assert_array_equal(out[1], ref[1])  # src untouched
        np.testing.assert_array_equal(out[:1], ref[:1])
        np.testing.assert_array_equal(out[2], ref[2])

    @staticmethod
    def _check_prefix_trace(num_blocks: int, block_size: int, ops) -> None:
        """Invariant driver for one admission/share/release trace over the
        refcounted allocator, mirroring the scheduler's hit-walk protocol
        (lookup -> ref hits -> alloc tail -> register misses).  Checked at
        every step:

        * conservation — ``free_count + #{blocks with refcount>0} ==
          num_blocks``, and the allocator's refcounts match the model's
          outstanding per-block owner counts exactly;
        * no eviction of referenced blocks — every block ``alloc`` hands
          out has model refcount 0;
        * hash-index liveness — every indexed hash maps to the block that
          was registered under it and that block is never blank/reclaimed;
        * failed allocs change no ownership;
        * double-unref raises exactly when the model refcount is 0.

        ``ops`` is a list of (kind, x, y) int triples.  Prompts come from
        a tiny family of 5 token streams so traces actually share
        prefixes."""
        a = kv_pool.BlockAllocator(num_blocks)
        live: dict[int, list[int]] = {}  # uid -> owned block ids (with dups)
        refs = [0] * num_blocks  # model refcounts
        content: dict[int, int] = {}  # block -> hash registered on it
        next_uid = 0

        def check():
            used = sum(1 for r in refs if r > 0)
            assert a.free_count + used == num_blocks, "conservation"
            assert a.used_count == used
            for b in range(num_blocks):
                assert a.refcount(b) == refs[b], f"refcount drift at {b}"
            for h, b in a._block_of.items():
                assert content.get(b) == h, "hash index points off-content"
                assert b not in a._blank, "hash index points at blank block"

        for kind, x, y in ops:
            check()
            if kind == 0:  # admission hit-walk
                length = 1 + x % (3 * block_size)
                fam = y % 5
                tokens = [fam * 1000 + i for i in range(length)]
                hashes = kv_pool.prompt_block_hashes(tokens, block_size)
                nb = kv_pool.blocks_for(length, block_size)
                hits: list[int] = []
                for h in hashes:
                    b = a.lookup(h)
                    if b is None:
                        break
                    hits.append(b)
                for b in hits:
                    a.ref(b)
                    refs[b] += 1
                got = a.alloc(nb - len(hits))
                if got is None:
                    a.unref(hits)
                    for b in hits:
                        refs[b] -= 1
                    continue
                for b in got:
                    assert refs[b] == 0, "alloc stole a referenced block"
                    content.pop(b, None)  # reclaimed: old identity is gone
                    refs[b] = 1
                blocks = hits + got
                for i in range(len(hits), len(hashes)):
                    if a.register(blocks[i], hashes[i]):
                        content[blocks[i]] = hashes[i]
                live[next_uid] = blocks
                next_uid += 1
            elif kind == 1 and live:  # release = unref (blocks stay cached)
                uid = sorted(live)[x % len(live)]
                blocks = live.pop(uid)
                a.unref(blocks)
                for b in blocks:
                    refs[b] -= 1
            elif kind == 2 and live:  # release + double-unref probe
                uid = sorted(live)[x % len(live)]
                blocks = live.pop(uid)
                a.unref(blocks)
                for b in blocks:
                    refs[b] -= 1
                dead = [b for b in blocks if refs[b] == 0]
                if dead:
                    with pytest.raises(ValueError, match="double free"):
                        a.unref(dead[:1])
                elif blocks:
                    # still shared by another request: unref is legal...
                    a.unref(blocks[:1])
                    refs[blocks[0]] -= 1
                    a.ref(blocks[0])  # ...and reversible
                    refs[blocks[0]] += 1
        check()
        for blocks in live.values():  # drain: the pool must reconcile
            a.unref(blocks)
            for b in blocks:
                refs[b] -= 1
        assert all(r == 0 for r in refs)
        assert a.free_count == num_blocks and a.used_count == 0
        check()

    def test_property_random_share_release_traces(self):
        """Hypothesis sweep over arbitrary admission/share/release
        interleavings of the refcount/LRU/hash invariants."""
        hypothesis = pytest.importorskip("hypothesis")
        st = hypothesis.strategies

        @hypothesis.given(
            num_blocks=st.integers(1, 24),
            block_size=st.sampled_from([1, 2, 4, 8]),
            ops=st.lists(
                st.tuples(
                    st.integers(0, 2), st.integers(0, 31), st.integers(0, 7)
                ),
                max_size=60,
            ),
        )
        @hypothesis.settings(deadline=None, max_examples=60)
        def run(num_blocks, block_size, ops):
            self._check_prefix_trace(num_blocks, block_size, ops)

        run()

    def test_random_share_release_traces_seeded(self):
        """Seeded fallback for the same property where hypothesis isn't
        installed."""
        rng = np.random.default_rng(1)
        for _ in range(50):
            num_blocks = int(rng.integers(1, 25))
            block_size = int(rng.choice([1, 2, 4, 8]))
            ops = [
                (int(rng.integers(0, 3)), int(rng.integers(0, 32)),
                 int(rng.integers(0, 8)))
                for _ in range(int(rng.integers(0, 61)))
            ]
            self._check_prefix_trace(num_blocks, block_size, ops)


class TestPagedReadWrite:
    B, MB, BS, H, D, NB = 2, 3, 4, 2, 8, 7

    def _pool_and_table(self):
        pool = jnp.zeros((self.NB, self.BS, self.H, self.D), jnp.float32)
        # slot 0 owns blocks [1, 2, 3]; slot 1 owns [4, 5, 6]
        table = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
        return pool, table

    def test_write_read_roundtrip_position_order(self):
        pool, table = self._pool_and_table()
        vals = {}
        for p in range(self.MB * self.BS):
            v = jax.random.normal(
                jax.random.PRNGKey(p), (self.B, self.H, self.D)
            )
            pool = kv_pool.write(
                pool, table, jnp.full((self.B,), p, jnp.int32), v, None
            )
            vals[p] = np.asarray(v)
        dense = np.asarray(kv_pool.read(pool, table))
        assert dense.shape == (self.B, self.MB * self.BS, self.H, self.D)
        for p, v in vals.items():
            np.testing.assert_array_equal(dense[:, p], v)

    def test_inactive_slots_write_nothing(self):
        pool, table = self._pool_and_table()
        v = jnp.ones((self.B, self.H, self.D))
        pool2 = kv_pool.write(
            pool, table, jnp.zeros((self.B,), jnp.int32), v,
            jnp.asarray([True, False]),
        )
        dense = np.asarray(kv_pool.read(pool2, table))
        assert (dense[0, 0] == 1.0).all()
        assert (dense[1] == 0.0).all()  # inactive slot untouched

    def test_write_span_installs_dense_prefill_prefix(self):
        """One-shot admission install (the scheduler's _make_install_fn)
        is a batch-1 write_span of the prefilled dense cache, bounded to
        the prompt-covering pages — the pool holds the dense prefix
        element for element (scatter_prefill's old contract, now served
        by the one write path)."""
        pool, table = self._pool_and_table()
        nb = 2  # prompt covers two pages
        L = self.MB * self.BS  # the dense cache is full slot length
        dense = jax.random.normal(
            jax.random.PRNGKey(0), (1, L, self.H, self.D)
        )
        pool = kv_pool.write_span(
            pool, table[:1], jnp.zeros((1,), jnp.int32), dense, None,
            jnp.asarray([nb * self.BS], jnp.int32),
        )
        got = np.asarray(kv_pool.read(pool, table))[0]
        np.testing.assert_array_equal(
            got[: nb * self.BS], np.asarray(dense)[0, : nb * self.BS]
        )
        assert (got[nb * self.BS:] == 0.0).all()  # uncovered pages untouched

    def test_read_clamps_to_used_block_prefix(self):
        """``read(blocks=n)`` gathers only the first n table entries: same
        values on the covered prefix, and the short gather never touches
        the pool rows the dropped entries point at."""
        pool, table = self._pool_and_table()
        for p in range(self.BS + 1):  # spills into the second page
            v = jax.random.normal(
                jax.random.PRNGKey(p), (self.B, self.H, self.D)
            )
            pool = kv_pool.write(
                pool, table, jnp.full((self.B,), p, jnp.int32), v, None
            )
        full = np.asarray(kv_pool.read(pool, table))
        short = np.asarray(kv_pool.read(pool, table, blocks=2))
        assert short.shape == (self.B, 2 * self.BS, self.H, self.D)
        np.testing.assert_array_equal(short, full[:, : 2 * self.BS])
        # the clamp never returns an empty gather and caps at the table
        assert kv_pool.read(pool, table, blocks=0).shape[1] == self.BS
        assert (
            kv_pool.read(pool, table, blocks=99).shape[1]
            == self.MB * self.BS
        )

    def test_write_span_matches_token_loop(self):
        """The multi-token span scatter is elementwise the per-token
        ``write`` loop — chunked prefill's pages are bit-identical to what
        one-shot install would have produced."""
        pool, table = self._pool_and_table()
        t = 6  # crosses a page boundary (BS=4) at different offsets/slot
        pos = jnp.asarray([1, 3], jnp.int32)
        val = jax.random.normal(jax.random.PRNGKey(7), (self.B, t, self.H, self.D))
        got = kv_pool.write_span(pool, table, pos, val)
        want = pool
        for i in range(t):
            want = kv_pool.write(want, table, pos + i, val[:, i], None)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_write_span_masks_lengths_and_active(self):
        """Ragged final slices (``lengths``) and inactive slots write
        nothing — the pad tail of a chunked-prefill slice can never
        scribble into someone else's reclaimed page."""
        pool, table = self._pool_and_table()
        t = 4
        val = jnp.ones((self.B, t, self.H, self.D))
        got = kv_pool.write_span(
            pool, table, jnp.zeros((self.B,), jnp.int32), val,
            jnp.asarray([True, False]), jnp.asarray([2, 4], jnp.int32),
        )
        dense = np.asarray(kv_pool.read(got, table))
        assert (dense[0, :2] == 1.0).all()
        assert (dense[0, 2:] == 0.0).all()  # beyond lengths[0]
        assert (dense[1] == 0.0).all()  # inactive slot untouched

    def test_write_span_drops_positions_past_table(self):
        """Masked entries may run past the slot's table (padded slice at
        the end of a full slot): they are clipped + dropped, not wrapped
        into another slot's pages."""
        pool, table = self._pool_and_table()
        cap = self.MB * self.BS
        t = 3
        val = jnp.ones((self.B, t, self.H, self.D))
        got = kv_pool.write_span(
            pool, table, jnp.full((self.B,), cap - 1, jnp.int32), val,
            None, jnp.asarray([1, 1], jnp.int32),
        )
        dense = np.array(kv_pool.read(got, table))
        assert (dense[:, cap - 1] == 1.0).all()
        assert (np.asarray(got)[0] == 0.0).all()  # block 0 never touched
        dense[:, cap - 1] = 0.0
        assert (dense == 0.0).all()

    def test_blocks_for(self):
        assert kv_pool.blocks_for(1, 4) == 1
        assert kv_pool.blocks_for(4, 4) == 1
        assert kv_pool.blocks_for(5, 4) == 2

    def test_init_rejects_ragged_max_len(self):
        with pytest.raises(ValueError, match="multiple of block_size"):
            kv_pool.init_paged_attention_cache(2, 10, 2, 8, 4, 4, jnp.float32)
