"""Chaos suite: graceful degradation under seeded random fault schedules.

Engine-level invariants, asserted under `FaultInjector.random` schedules
(allocator failures, forced preemptions, poisoned logits, delayed
arrivals) mixed with random deadlines and a bounded queue, in both cache
layouts and both admission modes:

* **termination** — ``run()`` returns (the watchdog turns any livelock
  into a SchedulerStall, which fails the test);
* **block conservation** — every pool block is back on the free list;
* **exactly-one-finish** — each submitted uid appears once, with a
  ``finish_reason`` from the taxonomy;
* **stream isolation** — requests finishing ``stop``/``length`` are
  bit-for-bit the fault-free oracle; ``deadline``/``error`` partials are
  strict prefixes of it; ``shed``/``rejected`` carry zero tokens.

The FaultInjector itself gets a hypothesis property suite (with a
seeded-numpy fallback mirroring the BlockAllocator suite) since its
replay determinism is what makes every chaos failure reproducible."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig
from repro.models import api
from repro.serve.engine import DecodeEngine, SamplerConfig
from repro.serve.faults import (
    AllocFailure,
    DelayArrival,
    FaultInjector,
    ForcePreempt,
    PoisonLogits,
)
from repro.serve.scheduler import FINISH_REASONS, ContinuousBatchingEngine

KEY = jax.random.PRNGKey(1)
QC = QuantConfig(mode="pquant", r=16, num_experts=1)
CFG = ModelConfig(name="t", family="decoder", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64, quant=QC)
MAX_LEN = 32
SCFG = SamplerConfig(temperature=0.9, top_k=12, max_new_tokens=8,
                     stop_tokens=(5,))

# uid -> (prompt seed-offset length, token budget)
REQS = {0: (5, 8), 1: (3, 6), 2: (7, 4), 3: (4, 8), 4: (6, 5), 5: (9, 7)}


@pytest.fixture(scope="module")
def params():
    return api.init_model(KEY, CFG)[0]


@pytest.fixture(scope="module")
def oracle(params):
    """uid -> the fault-free stream: the full budget-shaped lockstep
    stream truncated at (and including) the first stop token — exactly
    what the continuous engine emits for an unfaulted request (the parity
    suite's contract)."""
    ref = DecodeEngine(params, CFG, MAX_LEN)
    out = {}
    for uid, (n, budget) in REQS.items():
        scfg = dataclasses.replace(
            SCFG, max_new_tokens=budget, stop_tokens=()
        )
        full = np.asarray(
            ref.generate(jnp.asarray(_prompt(uid)[None]), scfg, seed=uid)[0]
        )
        stop = np.isin(full, SCFG.stop_tokens).nonzero()[0]
        out[uid] = full[: stop[0] + 1] if stop.size else full
    return out


def _prompt(uid):
    n = REQS[uid][0]
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(uid + 10), (n,), 0, 64),
        np.int32,
    )


def _check_chaos_run(params, oracle, layout, prefill_chunk, seed):
    """One seeded chaos episode through the full invariant battery."""
    inj = FaultInjector.random(
        seed, list(REQS), n_faults=8, max_step=10, max_alloc=24,
        max_gen=6, max_delay=3.0,
    )
    rng = np.random.default_rng(seed + 1000)
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=SCFG,
        layout=layout, block_size=8, num_blocks=5, chunk=4,
        prefill_chunk=prefill_chunk, faults=inj,
        max_queue=4,
        overload_policy="reject" if seed % 2 else "shed_oldest",
        watchdog_steps=64,
    )
    for uid, (n, budget) in REQS.items():
        eng.submit(
            _prompt(uid), max_new_tokens=budget, seed=uid, uid=uid,
            arrival=float(rng.uniform(0.0, 4.0)),
            deadline=(
                float(rng.uniform(6.0, 40.0))
                if rng.integers(0, 3) == 0 else None
            ),
            ttft_budget=(
                float(rng.uniform(2.0, 10.0))
                if rng.integers(0, 4) == 0 else None
            ),
        )
    finished = eng.run()  # termination (watchdog would raise on livelock)

    # exactly one finish per submitted request, valid reason
    assert sorted(f.uid for f in finished) == sorted(REQS)
    for f in finished:
        assert f.finish_reason in FINISH_REASONS, f.finish_reason
        want = oracle[f.uid]
        got = np.asarray(f.tokens)
        if f.finish_reason in ("stop", "length"):
            # unaffected streams: bit-for-bit the fault-free run
            np.testing.assert_array_equal(got, want)
        elif f.finish_reason in ("deadline", "error"):
            # partials are prefixes of the deterministic stream
            assert len(got) <= len(want)
            np.testing.assert_array_equal(got, want[: len(got)])
        else:  # shed / rejected: never started
            assert len(got) == 0

    # block conservation: everything back on the free list
    if eng.allocator is not None:
        assert eng.allocator.free_count == eng.num_blocks
    assert eng._live() == [] and not eng._queue

    # telemetry conservation: every submission is accounted for by
    # exactly one finish-reason counter, whatever faults fired
    snap = eng.snapshot()
    fbr = eng.finished_by_reason
    assert set(fbr) == set(FINISH_REASONS)
    assert sum(fbr.values()) == len(REQS) == len(finished)
    assert snap["counters"]["requests_submitted_total"] == len(REQS)
    # and the pool-utilization gauge agrees with the drained free list
    if eng.allocator is not None:
        assert snap["gauges"]["pool_blocks_used"] == 0
    return eng, inj


CHAOS_CASES = [
    ("dense", None, 0),
    ("paged", None, 1),
    ("paged", 3, 2),
    ("dense", 3, 3),
    ("paged", None, 4),
]


@pytest.mark.parametrize("layout,prefill_chunk,seed", CHAOS_CASES)
def test_chaos_invariants_under_random_fault_schedules(
    params, oracle, layout, prefill_chunk, seed
):
    eng, inj = _check_chaos_run(params, oracle, layout, prefill_chunk, seed)
    # the schedule replays: same seed -> identical fired-fault counts
    replay = FaultInjector.random(
        seed, list(REQS), n_faults=8, max_step=10, max_alloc=24,
        max_gen=6, max_delay=3.0,
    )
    assert replay.faults == inj.faults


def test_chaos_fired_faults_still_isolate_streams(params, oracle):
    """A hand-built schedule where every fault kind demonstrably fires:
    the targeted stream alone degrades; everything else stays exact."""
    inj = FaultInjector([
        AllocFailure(2),
        ForcePreempt(step=2, uid=None),
        PoisonLogits(uid=3, gen_index=2),
        DelayArrival(uid=1, delay=2.5),
    ])
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=SCFG,
        layout="paged", block_size=8, num_blocks=5, chunk=4,
        faults=inj, watchdog_steps=64,
    )
    for uid in REQS:
        eng.submit(_prompt(uid), max_new_tokens=REQS[uid][1], seed=uid,
                   uid=uid)
    finished = {f.uid: f for f in eng.run()}
    assert sorted(finished) == sorted(REQS)
    assert finished[3].finish_reason == "error"
    np.testing.assert_array_equal(
        np.asarray(finished[3].tokens), oracle[3][:2]
    )
    for uid in REQS:
        if uid == 3:
            continue
        # preemption + alloc failure + delay are invisible in the output
        assert finished[uid].finish_reason in ("stop", "length")
        np.testing.assert_array_equal(
            np.asarray(finished[uid].tokens), oracle[uid]
        )
    assert inj.injected["poison_logits"] == 1
    assert inj.injected["force_preempt"] == 1
    assert eng.allocator.free_count == eng.num_blocks


# ---------------------------------------------------------------------------
# Chaos over SHARED prefix-cache blocks
# ---------------------------------------------------------------------------

_SHARED_PREFIX = np.asarray(
    jax.random.randint(jax.random.PRNGKey(77), (17,), 0, 64), np.int32
)


def _shared_prompt(uid):
    """17-token shared prefix (two full blocks) + a ragged private tail."""
    tail = np.asarray(
        jax.random.randint(
            jax.random.PRNGKey(300 + uid), (1 + uid % 4,), 0, 64
        ),
        np.int32,
    )
    return np.concatenate([_SHARED_PREFIX, tail])


@pytest.fixture(scope="module")
def shared_oracle(params):
    """Fault-free lockstep streams for the shared-prefix prompts."""
    ref = DecodeEngine(params, CFG, MAX_LEN)
    out = {}
    for uid, (_, budget) in REQS.items():
        scfg = dataclasses.replace(
            SCFG, max_new_tokens=budget, stop_tokens=()
        )
        full = np.asarray(
            ref.generate(
                jnp.asarray(_shared_prompt(uid)[None]), scfg, seed=uid
            )[0]
        )
        stop = np.isin(full, SCFG.stop_tokens).nonzero()[0]
        out[uid] = full[: stop[0] + 1] if stop.size else full
    return out


@pytest.mark.parametrize("prefill_chunk,seed", [(None, 0), (3, 1),
                                                (None, 2)])
def test_chaos_alloc_and_preempt_over_shared_blocks(params, shared_oracle,
                                                    prefill_chunk, seed):
    """Allocation failures and forced preemptions fire while other slots
    hold references into the victims' blocks (every prompt shares a
    two-block prefix, so after the first admission every hit-walk shares
    pages).  A preempted request's restart may re-hit the cached prefix;
    an alloc-denied admission must unref its hits without disturbing the
    sharers.  Invariants: exactly-one-finish, stream isolation against
    the fault-free oracle, and zero-leak drain — released shared blocks
    park on the LRU but the pool reconciles to fully free with
    ``pool_blocks_used == 0``."""
    inj = FaultInjector.random(
        seed + 50, list(REQS), n_faults=8, max_step=10, max_alloc=24,
        kinds=("alloc", "preempt"),
    )
    assert all(
        isinstance(f, (AllocFailure, ForcePreempt)) for f in inj.faults
    )
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=SCFG,
        layout="paged", block_size=8, num_blocks=6, chunk=4,
        prefill_chunk=prefill_chunk, prefix_cache=True, faults=inj,
        watchdog_steps=96,
    )
    for uid, (_, budget) in REQS.items():
        eng.submit(_shared_prompt(uid), max_new_tokens=budget, seed=uid,
                   uid=uid)
    finished = eng.run()

    assert sorted(f.uid for f in finished) == sorted(REQS)
    for f in finished:
        assert f.finish_reason in FINISH_REASONS, f.finish_reason
        want = shared_oracle[f.uid]
        got = np.asarray(f.tokens)
        if f.finish_reason in ("stop", "length"):
            np.testing.assert_array_equal(got, want)  # stream isolation
        elif f.finish_reason in ("deadline", "error"):
            np.testing.assert_array_equal(got, want[: len(got)])
        else:
            assert len(got) == 0

    # the cache actually engaged: later admissions hit the shared blocks
    snap = eng.snapshot()
    assert snap["counters"]["prefix_cache_hits_total"] > 0
    # zero-leak drain with a warm cache: every block unreferenced, parked
    # or blank, and the utilization gauge agrees
    assert eng.allocator.free_count == eng.num_blocks
    assert eng.allocator.used_count == 0
    assert snap["gauges"]["pool_blocks_used"] == 0
    assert eng._live() == [] and not eng._queue


def test_injector_kinds_restriction():
    """``kinds`` restricts the drawn fault kinds, validates unknown
    names, and the default tuple reproduces the unrestricted schedule bit
    for bit (the chaos suite's historical seeds stay meaningful)."""
    uids = [0, 1, 2]
    a = FaultInjector.random(3, uids, n_faults=12,
                             kinds=("alloc", "preempt"))
    assert a.faults  # 12 draws from 2 kinds: never empty
    assert all(
        isinstance(f, (AllocFailure, ForcePreempt)) for f in a.faults
    )
    b = FaultInjector.random(3, uids, n_faults=12)
    c = FaultInjector.random(3, uids, n_faults=12,
                             kinds=FaultInjector.KINDS)
    assert b.faults == c.faults
    with pytest.raises(ValueError, match="unknown fault kinds"):
        FaultInjector.random(0, uids, kinds=("alloc", "meteor"))
    with pytest.raises(ValueError, match="at least one"):
        FaultInjector.random(0, uids, kinds=())


# ---------------------------------------------------------------------------
# FaultInjector replay determinism (hypothesis + seeded fallback)
# ---------------------------------------------------------------------------


def _check_injector_schedule(seed, n_faults):
    """Invariant driver: a schedule replays bit-for-bit, hooks fire
    exactly per schedule, and poisons are consumed exactly once."""
    uids = [0, 1, 2, 3]
    a = FaultInjector.random(seed, uids, n_faults=n_faults)
    b = FaultInjector.random(seed, uids, n_faults=n_faults)
    assert a.faults == b.faults  # replay determinism

    fail_at = {f.index for f in a.faults if isinstance(f, AllocFailure)}
    fired = {i for i in range(64) if a.on_alloc()}
    assert fired == {i for i in fail_at if i < 64}
    assert a.injected["alloc_failure"] == len(fired)

    by_uid: dict[int, list[int]] = {}
    for f in a.faults:
        if isinstance(f, PoisonLogits):
            assert f.gen_index >= 1  # decode steps only
            by_uid.setdefault(f.uid, []).append(f.gen_index)
    for uid, gens in by_uid.items():
        for g in sorted(gens):  # pending gens are consumed in order
            # window starting past g: not consumed (restart determinism)
            assert a.poison_rel_step(uid, g + 1, 4) is None
            # in-window: consumed exactly once, correct relative step
            ngen = max(1, g - 2)
            assert a.poison_rel_step(uid, ngen, 8) == g - ngen
        # all consumed: nothing left to fire for this uid
        assert a.poison_rel_step(uid, 1, 10 ** 6) is None
    assert a.injected["poison_logits"] == sum(
        len(v) for v in by_uid.values()
    )

    delays = {}
    for f in a.faults:
        if isinstance(f, DelayArrival):
            delays[f.uid] = delays.get(f.uid, 0.0) + f.delay
    for uid in uids:
        assert a.arrival_delay(uid) == delays.get(uid, 0.0)

    steps = {}
    for f in a.faults:
        if isinstance(f, ForcePreempt):
            steps.setdefault(f.step, []).append(f.uid)
    for s in range(16):
        assert a.preempt_uids(s) == steps.get(s, [])


def test_injector_property_schedules():
    hypothesis = pytest.importorskip("hypothesis")
    st = hypothesis.strategies

    @hypothesis.given(seed=st.integers(0, 2 ** 31 - 1),
                      n_faults=st.integers(0, 12))
    @hypothesis.settings(deadline=None, max_examples=80)
    def run(seed, n_faults):
        _check_injector_schedule(seed, n_faults)

    run()


def test_injector_schedules_seeded():
    """Seeded sweep through the same driver so the property holds even
    where hypothesis isn't installed."""
    rng = np.random.default_rng(0)
    for _ in range(40):
        _check_injector_schedule(
            int(rng.integers(0, 2 ** 31)), int(rng.integers(0, 13))
        )


def test_injector_rejects_prefill_gen_index():
    with pytest.raises(ValueError, match="gen_index >= 1"):
        FaultInjector([PoisonLogits(uid=0, gen_index=0)])
