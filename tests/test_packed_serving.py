"""End-to-end packed-serving tests: the model forward on
``quantize_params_for_serving(packed=True)`` weights must (a) actually
execute the W1A8 kernel tier (no dequantize-then-float-matmul fallback on
the 1-bit backbone), (b) stay within tolerance of the latent fake-quant
oracle through the full serving stack (DecodeEngine, ContinuousBatching,
MoE), and (c) round-trip every export layout (packed / stacked-packed /
non-byte-aligned INT8 fallback) through ``_dequant_stored``."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.packing import unpack_signs
from repro.core.quantization import (
    QuantConfig,
    _dequant_stored,
    quantize_act_int8,
    quantize_activations_int8,
)
from repro.models import api
from repro.serve.engine import DecodeEngine, SamplerConfig
from repro.serve.scheduler import ContinuousBatchingEngine
from repro.train.quantized_serving import (
    _binarize_export,
    quantize_params_for_serving,
)

KEY = jax.random.PRNGKey(1)
QC = QuantConfig(mode="pquant", r=16, num_experts=1)
CFG = ModelConfig(name="t", family="decoder", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64, quant=QC)
MOE_CFG = ModelConfig(name="tmoe", family="decoder", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=48, vocab_size=64,
                      quant=QC, moe=True, n_routed_experts=4, moe_top_k=2,
                      n_shared_experts=1, d_ff_expert=16, first_k_dense=1,
                      moe_capacity_factor=4.0)
MAX_LEN = 24
GREEDY = SamplerConfig(temperature=0.0, top_k=0, max_new_tokens=6)


def _packed_params(cfg, key=KEY):
    params, axes = api.init_model(key, cfg)
    qparams, _ = quantize_params_for_serving(params, axes, cfg, packed=True)
    return params, qparams


@pytest.fixture(scope="module")
def dense_params():
    return _packed_params(CFG)


def _prompt(seed, n):
    return jax.random.randint(jax.random.PRNGKey(seed), (1, n), 0, 64).astype(
        jnp.int32
    )


# ---------------------------------------------------------------------------
# The acceptance criterion: packed decode executes the GEMV kernel tier
# ---------------------------------------------------------------------------


def test_decode_step_executes_gemv_tier(dense_params, monkeypatch):
    """A packed-exported decode step runs w1a8_gemv / decoupled_gemv, and the
    1-bit backbone never takes the `_dequant_stored` float-matmul fallback."""
    from repro.core import quantization
    from repro.kernels import ops

    _, qparams = dense_params
    calls = {"gemv": 0, "decoupled": 0}
    orig_gemv, orig_dec = ops.w1a8_gemv, ops.decoupled_gemv

    def count_gemv(*a, **k):
        calls["gemv"] += 1
        return orig_gemv(*a, **k)

    def count_dec(*a, **k):
        calls["decoupled"] += 1
        return orig_dec(*a, **k)

    orig_deq = quantization._dequant_stored

    def no_packed_fallback(w):
        assert "packed" not in w, (
            "_dequant_stored float fallback on a packed 1-bit weight"
        )
        return orig_deq(w)

    monkeypatch.setattr(ops, "w1a8_gemv", count_gemv)
    monkeypatch.setattr(ops, "decoupled_gemv", count_dec)
    monkeypatch.setattr(quantization, "_dequant_stored", no_packed_fallback)

    toks = _prompt(3, 5)
    _, caches = api.prefill(qparams, {"tokens": toks}, CFG, MAX_LEN)
    logits, _ = api.decode_step(
        qparams, toks[:, :1], caches, jnp.asarray(5, jnp.int32), CFG
    )
    assert jnp.isfinite(logits).all()
    # decode rows (M = 1 <= DECODE_M_MAX): attention projections go through
    # w1a8_gemv, the decoupled FFN's fused first GEMMs through decoupled_gemv
    assert calls["gemv"] > 0
    assert calls["decoupled"] > 0


# ---------------------------------------------------------------------------
# Packed vs fake-quant oracle parity through the engines
# ---------------------------------------------------------------------------


def test_decode_engine_generate_parity(dense_params):
    """Greedy generate on the packed export matches the latent fake-quant
    model token-for-token (same quantization grid; integer-vs-float
    accumulation differs only at float rounding)."""
    params, qparams = dense_params
    prompts = _prompt(7, 6)
    want = DecodeEngine(params, CFG, MAX_LEN).generate(prompts, GREEDY)
    got = DecodeEngine(qparams, CFG, MAX_LEN).generate(prompts, GREEDY)
    np.testing.assert_array_equal(got, want)


def test_decode_logits_parity_teacher_forced(dense_params):
    """Step-by-step decode logits stay within tolerance of the fake-quant
    oracle (robust to argmax ties, unlike token comparison)."""
    params, qparams = dense_params
    toks = jax.random.randint(KEY, (2, 8), 0, 64).astype(jnp.int32)
    lg_f, c_f = api.prefill(params, {"tokens": toks[:, :4]}, CFG, 16)
    lg_q, c_q = api.prefill(qparams, {"tokens": toks[:, :4]}, CFG, 16)
    errs = [np.abs(np.asarray(lg_f) - np.asarray(lg_q)).max()]
    for t in range(4, 8):
        pos = jnp.asarray(t, jnp.int32)
        lg_f, c_f = api.decode_step(params, toks[:, t:t + 1], c_f, pos, CFG)
        lg_q, c_q = api.decode_step(qparams, toks[:, t:t + 1], c_q, pos, CFG)
        errs.append(np.abs(np.asarray(lg_f) - np.asarray(lg_q)).max())
    assert max(errs) < 1e-3, errs


@pytest.mark.parametrize("prefill_chunk", [None, 3])
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_continuous_batching_packed_parity(dense_params, layout,
                                           prefill_chunk):
    """Every request's stream on the packed model is bit-for-bit the packed
    DecodeEngine's batch-1 stream, in both cache layouts AND under chunked
    admission prefill (multi-token forward_chunk slices, incl. a ragged
    masked final slice, through the W1A8 prefill-tier kernels) — the
    engine-tier self-consistency half of the acceptance criterion."""
    _, qparams = dense_params
    scfg = SamplerConfig(temperature=0.7, top_k=10, max_new_tokens=5)
    ref = DecodeEngine(qparams, CFG, MAX_LEN)
    eng = ContinuousBatchingEngine(
        qparams, CFG, num_slots=2, max_len=MAX_LEN, scfg=scfg,
        layout=layout, block_size=8, chunk=4, prefill_chunk=prefill_chunk,
    )
    assert eng.prefill_chunk == prefill_chunk
    prompts = {0: 5, 1: 3, 2: 6}
    for uid, n in prompts.items():
        eng.submit(np.asarray(_prompt(uid + 20, n)[0]), max_new_tokens=5,
                   seed=uid, uid=uid)
    finished = eng.run()
    assert sorted(f.uid for f in finished) == sorted(prompts)
    for f in finished:
        want = ref.generate(_prompt(f.uid + 20, prompts[f.uid]), scfg,
                            seed=f.uid)[0]
        np.testing.assert_array_equal(f.tokens, want)


def test_moe_packed_parity():
    """MoE: routed experts are per-slice packed; shared-expert decoupled FFN
    takes the fused kernel path.  Prefill + decode stay within tolerance."""
    params, qparams = _packed_params(MOE_CFG)
    toks = jax.random.randint(KEY, (2, 6), 0, 64).astype(jnp.int32)
    lg_f, c_f = api.prefill(params, {"tokens": toks[:, :4]}, MOE_CFG, 12)
    lg_q, c_q = api.prefill(qparams, {"tokens": toks[:, :4]}, MOE_CFG, 12)
    errs = [np.abs(np.asarray(lg_f) - np.asarray(lg_q)).max()]
    for t in range(4, 6):
        pos = jnp.asarray(t, jnp.int32)
        lg_f, c_f = api.decode_step(params, toks[:, t:t + 1], c_f, pos, MOE_CFG)
        lg_q, c_q = api.decode_step(qparams, toks[:, t:t + 1], c_q, pos, MOE_CFG)
        errs.append(np.abs(np.asarray(lg_f) - np.asarray(lg_q)).max())
    assert max(errs) < 1e-3, errs


def test_moe_packed_generate():
    """The packed MoE model generates through the compiled engine."""
    _, qparams = _packed_params(MOE_CFG)
    out = DecodeEngine(qparams, MOE_CFG, 16).generate(_prompt(5, 4), GREEDY)
    assert out.shape == (1, GREEDY.max_new_tokens)
    assert (out >= 0).all() and (out < 64).all()


# ---------------------------------------------------------------------------
# Export layout round-trips
# ---------------------------------------------------------------------------


def _latent_signs_deq(w):
    red = tuple(range(max(0, w.ndim - 2), w.ndim))
    mu = jnp.mean(w, axis=red, keepdims=True)
    lam = jnp.mean(jnp.abs(w), axis=red, keepdims=True) + 1e-5
    return jnp.where(w - mu >= 0, 1.0, -1.0) * lam


def test_export_roundtrip_stacked_packed():
    """Stacked (expert / layer-scanned) weights pack per slice and
    round-trip through _dequant_stored."""
    w = jax.random.normal(KEY, (3, 16, 8))
    q = _binarize_export(w, packed=True)
    assert "packed" in q and q["packed"].shape == (3, 2, 8)
    assert q["scale"].shape == (3, 1, 1)
    np.testing.assert_allclose(
        np.asarray(_dequant_stored(q)), np.asarray(_latent_signs_deq(w)),
        rtol=1e-6,
    )
    # the packed bits decode to the latent signs per slice
    signs = unpack_signs(q["packed"])
    assert signs.shape == w.shape


def test_export_non_byte_aligned_warns_and_roundtrips():
    """K % 8 != 0 cannot bit-pack: the export warns explicitly and falls
    back to unpacked INT8 signs that still round-trip."""
    w = jax.random.normal(KEY, (12, 8))  # K = 12
    with pytest.warns(UserWarning, match="not a multiple of 8"):
        q = _binarize_export(w, packed=True)
    assert "q" in q and "packed" not in q
    np.testing.assert_allclose(
        np.asarray(_dequant_stored(q)), np.asarray(_latent_signs_deq(w)),
        rtol=1e-6,
    )


def test_export_2d_packed_roundtrip():
    w = jax.random.normal(KEY, (16, 8))
    q = _binarize_export(w, packed=True)
    assert "packed" in q and q["packed"].shape == (2, 8)
    np.testing.assert_allclose(
        np.asarray(_dequant_stored(q)), np.asarray(_latent_signs_deq(w)),
        rtol=1e-6,
    )


# ---------------------------------------------------------------------------
# One act-quant source of truth
# ---------------------------------------------------------------------------


def test_act_quant_single_source_of_truth_bf16():
    """The fake-quant and runtime-integer activation quantizers share one
    scale (f32 amax): in bf16 they used to disagree (input-dtype amax vs
    f32 amax), which drifted packed-vs-fake-quant parity."""
    x = (jax.random.normal(KEY, (4, 64)) * 3).astype(jnp.bfloat16)
    xq, gamma_fake = quantize_activations_int8(x)
    q_int, gamma_int = quantize_act_int8(x)
    np.testing.assert_array_equal(
        np.asarray(gamma_fake[..., 0]), np.asarray(gamma_int)
    )
    assert gamma_fake.dtype == jnp.float32  # f32 amax, not input-dtype amax
    assert xq.dtype == x.dtype
    # in f32 the fake-quant grid points are exactly the kernel's integers
    xf = jax.random.normal(jax.random.PRNGKey(2), (4, 64)) * 3
    xqf, gf = quantize_activations_int8(xf)
    qf, _ = quantize_act_int8(xf)
    np.testing.assert_allclose(
        np.asarray(xqf * gf), np.asarray(qf, np.float32), atol=1e-4
    )


def test_bitnet_mode_packed_parity():
    """r = 0 (no 8-bit branch): the packed FFN goes through
    _branch1_apply's packed arm — the one copy of the packed 1-bit trunk
    sequence — and still matches the fake-quant oracle."""
    cfg = ModelConfig(name="tb", family="decoder", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64,
                      quant=QuantConfig(mode="bitnet", r=0))
    params, qparams = _packed_params(cfg)
    toks = jax.random.randint(KEY, (2, 6), 0, 64).astype(jnp.int32)
    lf, _ = api.forward(params, {"tokens": toks}, cfg)
    lq, _ = api.forward(qparams, {"tokens": toks}, cfg)
    assert np.abs(np.asarray(lf) - np.asarray(lq)).max() < 1e-3


def test_moe_einsum_dispatch_packed_parity():
    """The grouped (einsum-dispatch) expert path has its own packed arm
    ((G, E, C, D) slicing); parity must hold there too."""
    cfg = ModelConfig(name="tmoe2", family="decoder", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=48, vocab_size=64,
                      quant=QC, moe=True, n_routed_experts=4, moe_top_k=2,
                      n_shared_experts=1, d_ff_expert=16, first_k_dense=1,
                      moe_capacity_factor=4.0, moe_dispatch="einsum",
                      moe_group_size=4)
    params, qparams = _packed_params(cfg)
    toks = jax.random.randint(KEY, (2, 4), 0, 64).astype(jnp.int32)
    lf, _ = api.forward(params, {"tokens": toks}, cfg)
    lq, _ = api.forward(qparams, {"tokens": toks}, cfg)
    assert np.abs(np.asarray(lf) - np.asarray(lq)).max() < 1e-3


def test_ssm_decoupled_proj_packed_parity():
    """SSM family (decoupled_proj adaptation): the packed trunk + INT8
    bottleneck run on integers; forward stays within tolerance."""
    cfg = ModelConfig(name="ts", family="ssm", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=64,
                      quant=QC, ssm_state=8, ssm_headdim=8, ssm_chunk=4,
                      glu=False)
    params, qparams = _packed_params(cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, 64).astype(jnp.int32)
    lf, _ = api.forward(params, {"tokens": toks}, cfg)
    lq, _ = api.forward(qparams, {"tokens": toks}, cfg)
    assert np.abs(np.asarray(lf) - np.asarray(lq)).max() < 1e-3
