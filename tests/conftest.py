import os

# Tests must see a single CPU device (the 512-device override is strictly
# scoped to launch/dryrun.py per the assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
