import os

# Tests must see a single CPU device (the 512-device override is strictly
# scoped to launch/dryrun.py per the assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Keep test runs hermetic: no reads/writes of the user-level decode-tile
# autotune cache (tests that exercise persistence re-enable it against a
# tmpdir, see test_tile_cache.py).
os.environ.setdefault("REPRO_TILE_CACHE", "0")

import jax

jax.config.update("jax_enable_x64", False)
