"""Observability tier: metrics registry semantics (bucketing, quantile
bounds, snapshot schema, Prometheus export), request-trace span ordering,
clock injection (ManualClock drives the engine with zero real sleeps),
compatibility aliases over the registry, the tile-cache stats collector,
profiler capture via REPRO_PROFILE_DIR — and the load-bearing contract:
attaching metrics/tracing changes NO compiled program (byte-identical
lowering, asserted below)."""

import json
import math
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig
from repro.kernels import tile_cache
from repro.models import api
from repro.serve.engine import SamplerConfig
from repro.serve.metrics import (
    Counter,
    Gauge,
    Histogram,
    ManualClock,
    MetricsRegistry,
    MonotonicClock,
    resolve_clock,
    validate_snapshot,
)
from repro.serve.scheduler import FINISH_REASONS, ContinuousBatchingEngine
from repro.serve.tracing import (
    JsonlSink,
    ListSink,
    RequestTracer,
    maybe_profile,
)

QC = QuantConfig(mode="pquant", r=16, num_experts=1)
CFG = ModelConfig(name="t", family="decoder", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64, quant=QC)
MAX_LEN = 32
SCFG = SamplerConfig(temperature=0.7, top_k=10, max_new_tokens=5)


@pytest.fixture(scope="module")
def params():
    return api.init_model(jax.random.PRNGKey(1), CFG)[0]


def _prompt(seed, n=6):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, 64), np.int32
    )


def _engine(params, **kw):
    kw.setdefault("layout", "paged")
    kw.setdefault("block_size", 8)
    kw.setdefault("chunk", 4)
    return ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=SCFG, **kw
    )


# ---------------------------------------------------------------------------
# histogram semantics
# ---------------------------------------------------------------------------


class TestHistogram:
    def test_bucketing_edges_inclusive_upper(self):
        h = Histogram("h", buckets=(1.0, 2.0, 4.0))
        for x in (0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 5.0, 100.0):
            h.observe(x)
        # bucket i covers (edge[i-1], edge[i]]; the last is overflow
        assert h.counts == [2, 2, 2, 2]
        assert h.count == 8
        assert h.sum == pytest.approx(sum((0.5, 1.0, 1.5, 2.0, 3.0, 4.0,
                                           5.0, 100.0)))

    def test_quantile_bounds_bracket_exact_percentile(self):
        h = Histogram("h")
        rng = np.random.default_rng(0)
        xs = rng.exponential(0.05, size=500)
        for x in xs:
            h.observe(float(x))
        for q in (0.5, 0.95, 0.99):
            lo, hi = h.quantile_bounds(q)
            exact = float(np.quantile(xs, q, method="inverted_cdf"))
            assert lo < exact <= hi
            # the interpolated quantile stays inside the same bucket
            assert lo <= h.quantile(q) <= hi

    def test_overflow_bucket_reports_inf(self):
        h = Histogram("h", buckets=(1.0,))
        h.observe(10.0)
        assert h.quantile_bounds(0.5) == (1.0, math.inf)
        assert h.quantile(0.5) == 1.0  # clamped to the last finite edge

    def test_empty_histogram(self):
        h = Histogram("h")
        with pytest.raises(ValueError, match="empty"):
            h.quantile(0.5)
        assert h.to_dict()["p50"] is None

    def test_rejects_bad_buckets(self):
        with pytest.raises(ValueError):
            Histogram("h", buckets=())
        with pytest.raises(ValueError):
            Histogram("h", buckets=(2.0, 1.0))

    def test_memory_is_bounded(self):
        h = Histogram("h")
        n_counts = len(h.counts)
        for i in range(10_000):
            h.observe(i * 1e-3)
        assert len(h.counts) == n_counts  # no per-observation state


# ---------------------------------------------------------------------------
# registry: get-or-create, snapshot schema, Prometheus export
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_get_or_create_and_kind_conflict(self):
        m = MetricsRegistry()
        c = m.counter("a_total")
        assert m.counter("a_total") is c
        assert m.counter("a_total", reason="x") is not c  # distinct labels
        with pytest.raises(TypeError, match="already registered"):
            m.gauge("a_total")

    def test_family_by_label(self):
        m = MetricsRegistry()
        m.counter("fin_total", reason="stop").inc(2)
        m.counter("fin_total", reason="shed").inc()
        fam = m.family("fin_total")
        assert {dict(k)["reason"] for k in fam} == {"stop", "shed"}

    def test_snapshot_json_round_trip_validates(self):
        m = MetricsRegistry()
        m.counter("c_total").inc(3)
        m.gauge("g").set(7)
        m.histogram("h_seconds").observe(0.01)
        m.counter("fin_total", reason="stop").inc()
        m.register_collector(lambda: {"extra_stat": 1.5})
        snap = json.loads(json.dumps(m.snapshot()))
        validate_snapshot(snap)
        assert snap["counters"]["c_total"] == 3
        assert snap["counters"]['fin_total{reason="stop"}'] == 1
        assert snap["gauges"]["g"] == 7
        assert snap["histograms"]["h_seconds"]["count"] == 1
        assert snap["collected"]["extra_stat"] == 1.5

    def test_validate_snapshot_rejects_drift(self):
        m = MetricsRegistry()
        snap = m.snapshot()
        bad = dict(snap)
        del bad["gauges"]
        with pytest.raises(AssertionError, match="gauges"):
            validate_snapshot(bad)
        bad = json.loads(json.dumps(snap))
        bad["counters"]["x"] = "nope"
        with pytest.raises(AssertionError, match="number"):
            validate_snapshot(bad)

    def test_prometheus_text(self):
        m = MetricsRegistry()
        m.counter("req_total", reason="stop").inc(2)
        h = m.histogram("lat_seconds", buckets=(1.0, 2.0))
        h.observe(0.5)
        h.observe(1.5)
        h.observe(9.0)
        text = m.prometheus_text()
        assert "# TYPE req_total counter" in text
        assert 'req_total{reason="stop"} 2' in text
        assert "# TYPE lat_seconds histogram" in text
        # cumulative bucket counts, then the +Inf total
        assert 'lat_seconds_bucket{le="1.0"} 1' in text
        assert 'lat_seconds_bucket{le="2.0"} 2' in text
        assert 'lat_seconds_bucket{le="+Inf"} 3' in text
        assert "lat_seconds_count 3" in text

    def test_reset_zeroes_everything(self):
        m = MetricsRegistry()
        m.counter("c_total").inc(5)
        m.gauge("g").set(2)
        m.histogram("h_seconds").observe(1.0)
        m.reset()
        snap = m.snapshot()
        assert snap["counters"]["c_total"] == 0
        assert snap["gauges"]["g"] == 0
        assert snap["histograms"]["h_seconds"]["count"] == 0


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------


class TestClocks:
    def test_resolve_none_is_virtual(self):
        now, sleep = resolve_clock(None)
        assert now is None
        sleep(5.0)  # no-op, returns instantly

    def test_resolve_bare_callable(self):
        now, sleep = resolve_clock(lambda: 3.5)
        assert now() == 3.5
        assert sleep is time.sleep

    def test_resolve_clock_object(self):
        c = ManualClock(start=2.0)
        now, sleep = resolve_clock(c)
        assert now() == 2.0
        sleep(1.5)  # routed to the clock's own sleep: virtual, recorded
        assert now() == 3.5 and c.sleeps == [1.5]
        with pytest.raises(TypeError):
            resolve_clock(object())

    def test_manual_clock_sleeps_virtually(self):
        c = ManualClock(start=1.0)
        c.sleep(2.5)
        c.advance(0.5)
        assert c.now() == 4.0
        assert c.sleeps == [2.5]

    def test_monotonic_clock_runs_forward(self):
        c = MonotonicClock()
        a = c.now()
        b = c.now()
        assert 0.0 <= a <= b


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


class TestEngineMetrics:
    def test_manual_clock_drives_waits_without_real_sleep(self, params):
        clock = ManualClock()
        eng = _engine(params, clock=clock)
        eng.submit(_prompt(0), max_new_tokens=4, seed=0, uid=0, arrival=0.0)
        eng.submit(_prompt(1), max_new_tokens=4, seed=1, uid=1, arrival=50.0)
        fins = eng.run()
        assert sorted(f.uid for f in fins) == [0, 1]
        # the drive loop waited for uid 1's arrival on the FAKE clock
        assert clock.sleeps, "drive loop never consulted the injected clock"
        assert clock.now() >= 50.0
        by_uid = {f.uid: f for f in fins}
        assert by_uid[1].first_token_at >= 50.0
        # engine-computed latency histograms live on the same timeline
        snap = eng.snapshot()
        assert snap["histograms"]["ttft_seconds"]["count"] == 2
        assert snap["histograms"]["request_latency_seconds"]["count"] == 2
        assert snap["counters"]["requests_submitted_total"] == 2
        assert eng.finished_by_reason["stop"] + \
            eng.finished_by_reason["length"] == 2

    def test_trace_span_ordering(self, params):
        sink = ListSink()
        eng = _engine(params, prefill_chunk=2,
                      tracer=RequestTracer(sink))
        eng.submit(_prompt(2), max_new_tokens=4, seed=2, uid=7)
        fins = eng.run()
        assert len(fins) == 1
        evs = sink.records
        assert evs, "tracer attached but nothing emitted"
        # timestamps are nondecreasing on the one engine clock
        ts = [e["t"] for e in evs]
        assert ts == sorted(ts)
        kinds = [e["event"] for e in evs]
        for k in ("submitted", "block_alloc", "admitted", "prefill_chunk",
                  "first_token", "finished", "block_free"):
            assert k in kinds, f"missing lifecycle event {k!r}"
        order = [
            kinds.index("submitted"), kinds.index("admitted"),
            kinds.index("first_token"), kinds.index("finished"),
        ]
        assert order == sorted(order)
        assert kinds.index("block_alloc") < kinds.index("admitted")
        assert kinds.index("prefill_chunk") < kinds.index("first_token")
        fin = next(e for e in evs if e["event"] == "finished")
        assert fin["uid"] == 7 and fin["reason"] in FINISH_REASONS
        assert eng.tracer.events == len(evs)

    def test_jsonl_sink_round_trip(self, params, tmp_path):
        path = tmp_path / "trace.jsonl"
        eng = _engine(params, tracer=RequestTracer(JsonlSink(path)))
        eng.submit(_prompt(3), max_new_tokens=3, seed=3, uid=1)
        eng.run()
        eng.tracer.close()
        evs = [json.loads(line) for line in path.read_text().splitlines()]
        assert evs and all("t" in e and "event" in e for e in evs)
        assert any(e["event"] == "finished" for e in evs)

    def test_compat_aliases_are_registry_backed(self, params):
        eng = _engine(params)
        assert eng.shed_requests == 0
        eng.metrics.counter("shed_requests_total").inc(2)
        assert eng.shed_requests == 2
        eng.shed_requests = 0  # legacy bench reset form
        assert eng.metrics.counter("shed_requests_total").value == 0
        eng.host_transfers = 9
        assert eng.metrics.counter("host_transfers_total").value == 9

    def test_tile_cache_stats_ride_the_snapshot(self, params):
        tile_cache.reset_stats()
        tile_cache.record_hit()
        tile_cache.record_miss()
        tile_cache.record_sweep_ms(4.0)
        eng = _engine(params)
        col = eng.snapshot()["collected"]
        assert col["tile_cache_hits"] == 1
        assert col["tile_cache_misses"] == 1
        assert col["tile_cache_sweeps"] == 1
        assert col["tile_cache_sweep_ms"] == pytest.approx(4.0)
        tile_cache.reset_stats()

    def test_disabled_observability_lowers_byte_identical(self, params):
        """The hard contract: metrics + tracer attached vs absent must
        produce the SAME compiled decode-chunk program — all
        instrumentation is host-side at chunk boundaries, and the
        profiler annotations are applied unconditionally."""
        bare = _engine(params)
        instrumented = _engine(
            params, metrics=MetricsRegistry(),
            tracer=RequestTracer(ListSink()), clock=ManualClock(),
        )
        low = [
            e._chunk_fn.lower(e.params, e._caches, e._state).as_text()
            for e in (bare, instrumented)
        ]
        assert low[0] == low[1]


# ---------------------------------------------------------------------------
# profiler capture
# ---------------------------------------------------------------------------


class TestProfile:
    def test_env_unset_is_noop(self, monkeypatch):
        monkeypatch.delenv("REPRO_PROFILE_DIR", raising=False)
        with maybe_profile("t"):
            pass  # no trace started, nothing written anywhere

    def test_profile_dir_produces_trace(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path))
        with maybe_profile("t"):
            with maybe_profile("inner"):  # re-entrant bracket no-ops
                jnp.dot(jnp.ones((8, 8)), jnp.ones((8, 8))).block_until_ready()
        files = [p for p in pathlib.Path(tmp_path).rglob("*") if p.is_file()]
        assert files, "REPRO_PROFILE_DIR set but no trace captured"
