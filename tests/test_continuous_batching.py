"""Continuous-batching engine: per-request parity with the lockstep
DecodeEngine (the acceptance criterion), dense-vs-paged interchangeability,
admission/eviction under a scripted arrival trace, stop-token truncation,
and block-reclamation accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig
from repro.models import api
from repro.serve.engine import DecodeEngine, SamplerConfig
from repro.serve.scheduler import ContinuousBatchingEngine

KEY = jax.random.PRNGKey(1)
QC = QuantConfig(mode="pquant", r=16, num_experts=1)
CFG = ModelConfig(name="t", family="decoder", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64, quant=QC)
SWA_CFG = ModelConfig(name="t2", family="decoder", n_layers=6, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64,
                      quant=QC, attn_type="swa", window_size=4,
                      global_every=3, rope_theta_local=1e3)
MAX_LEN = 32


@pytest.fixture(scope="module")
def params():
    return api.init_model(KEY, CFG)[0]


@pytest.fixture(scope="module")
def reference(params):
    return DecodeEngine(params, CFG, MAX_LEN)


def _prompt(seed, n):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, 64), np.int32
    )


PROMPTS = {0: 5, 1: 3, 2: 7, 3: 4}  # uid -> ragged prompt length
SCFG = SamplerConfig(temperature=0.7, top_k=10, max_new_tokens=6)


@pytest.fixture(scope="module")
def want(reference):
    """Per-request oracle: DecodeEngine on the batch-1 prompt with the
    request's own seed."""
    return {
        uid: reference.generate(
            jnp.asarray(_prompt(uid + 10, n)[None]), SCFG, seed=uid
        )[0]
        for uid, n in PROMPTS.items()
    }


@pytest.mark.parametrize("prefill_chunk", [None, 3])
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_per_request_parity_with_lockstep_engine(params, want, layout,
                                                 prefill_chunk):
    """Acceptance: identical token stream per prompt/seed, ragged prompts,
    fewer slots than requests, both cache layouts — with one-shot AND
    token-budget chunked admission prefill (prompts of length 5 and 7 span
    multiple 3-token slices).  Chunked prefill also compiles exactly ONE
    program per (budget, layout): slice padding + masking absorb every
    prompt length."""
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=SCFG,
        layout=layout, block_size=8, chunk=4, prefill_chunk=prefill_chunk,
    )
    assert eng.prefill_chunk == prefill_chunk  # CFG is chunk-safe
    for uid, n in PROMPTS.items():
        eng.submit(_prompt(uid + 10, n), max_new_tokens=6, seed=uid, uid=uid)
    finished = eng.run()
    assert sorted(f.uid for f in finished) == sorted(PROMPTS)
    for f in finished:
        np.testing.assert_array_equal(f.tokens, want[f.uid])
        assert f.finish_reason == "length"
        assert f.first_token_at >= f.admitted_at
    if prefill_chunk is not None:
        # one trace per (budget, layout), NOT per prompt length
        assert eng._prefill_chunk._cache_size() == 1


def test_paged_matches_dense_bit_for_bit(params):
    """The two cache layouts are interchangeable adapters: same tokens."""
    outs = {}
    for layout in ("dense", "paged"):
        eng = ContinuousBatchingEngine(
            params, CFG, num_slots=3, max_len=MAX_LEN, scfg=SCFG,
            layout=layout, block_size=8, chunk=4,
        )
        for uid, n in PROMPTS.items():
            eng.submit(_prompt(uid + 10, n), max_new_tokens=6, seed=uid,
                       uid=uid)
        outs[layout] = {f.uid: f.tokens for f in eng.run()}
    for uid in PROMPTS:
        np.testing.assert_array_equal(outs["dense"][uid], outs["paged"][uid])


@pytest.mark.parametrize("prefill_chunk", [None, 4])
def test_parity_sliding_window_global_mix(prefill_chunk):
    """Stacked scan segments with ring caches (sliding window) next to
    paged global layers — the ring semantics must survive per-slot pos,
    and chunked prefill (which the old bucketing could NOT serve: the ring
    would fold pad tokens into the window) must reproduce the streams via
    its sequential in-chunk ring path (prompt 9 spans three slices and
    wraps the window-4 rings)."""
    params, _ = api.init_model(KEY, SWA_CFG)
    ref = DecodeEngine(params, SWA_CFG, 24)
    scfg = SamplerConfig(temperature=0.7, top_k=10, max_new_tokens=8)
    eng = ContinuousBatchingEngine(
        params, SWA_CFG, num_slots=2, max_len=24, scfg=scfg,
        layout="paged", block_size=8, chunk=3, prefill_chunk=prefill_chunk,
    )
    assert eng.prefill_chunk == prefill_chunk  # ring configs ARE chunk-safe
    lens = {0: 6, 1: 4, 2: 9}
    for uid, n in lens.items():
        eng.submit(_prompt(uid, n), max_new_tokens=8, seed=uid, uid=uid)
    finished = eng.run()
    assert sorted(f.uid for f in finished) == sorted(lens)
    for f in finished:
        expect = ref.generate(
            jnp.asarray(_prompt(f.uid, lens[f.uid])[None]), scfg, seed=f.uid
        )[0]
        np.testing.assert_array_equal(f.tokens, expect)


def test_admission_eviction_under_arrival_trace(params, want):
    """Scripted arrivals (virtual chunk-tick clock): late requests wait in
    the queue, get admitted as slots free up, and everyone still matches
    the oracle."""
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=SCFG,
        layout="paged", block_size=8, chunk=2,
    )
    arrivals = {0: 0.0, 1: 0.0, 2: 1.0, 3: 5.0}
    for uid, n in PROMPTS.items():
        eng.submit(_prompt(uid + 10, n), max_new_tokens=6, seed=uid,
                   uid=uid, arrival=arrivals[uid])
    order = []
    finished = []
    while eng._queue or eng._live():
        done = eng.step()
        finished.extend(done)
        order.extend(f.uid for f in done)
    # no more than num_slots ever in flight, and all requests completed
    assert sorted(order) == sorted(PROMPTS)
    # the early arrivals finish before the tick-5 straggler
    assert order.index(3) > order.index(0)
    assert order.index(3) > order.index(1)
    for f in finished:
        np.testing.assert_array_equal(f.tokens, want[f.uid])
        assert f.admitted_at >= arrivals[f.uid]


def test_stop_token_truncation(params, reference):
    """Device-side stop mask: the stream is the lockstep stream truncated
    at (and including) the first stop token; the slot frees early."""
    greedy = SamplerConfig(temperature=0.0, max_new_tokens=10)
    prompt = _prompt(99, 5)
    full = reference.generate(jnp.asarray(prompt[None]), greedy, seed=0)[0]
    stop = int(full[2])
    scfg = SamplerConfig(temperature=0.0, max_new_tokens=10,
                         stop_tokens=(stop,))
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=1, max_len=MAX_LEN, scfg=scfg,
        layout="paged", block_size=8, chunk=4,
    )
    eng.submit(prompt, max_new_tokens=10, seed=0, uid=0)
    (f,) = eng.run()
    cut = int(np.where(full == stop)[0][0])
    np.testing.assert_array_equal(f.tokens, full[: cut + 1])
    assert f.finish_reason == "stop"
    assert eng.allocator.free_count == eng.num_blocks


@pytest.mark.parametrize("prefill_chunk", [None, 3])
def test_no_leaked_blocks_after_full_trace(params, prefill_chunk):
    """Reclamation accounting: a constrained pool forces waiting +
    preemption (under chunked prefill possibly of a mid-prefill victim),
    and after the trace every block is back on the free list."""
    scfg = SamplerConfig(temperature=0.7, top_k=10, max_new_tokens=12)
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=scfg,
        layout="paged", block_size=8, num_blocks=4, chunk=4,
        prefill_chunk=prefill_chunk,
    )
    ref = DecodeEngine(params, CFG, MAX_LEN)
    lens = {0: 7, 1: 3, 2: 5}
    for uid, n in lens.items():
        eng.submit(_prompt(uid + 50, n), max_new_tokens=12, seed=uid, uid=uid)
    finished = eng.run()
    assert sorted(f.uid for f in finished) == sorted(lens)
    for f in finished:  # preemption/restart must not change any stream
        expect = ref.generate(
            jnp.asarray(_prompt(f.uid + 50, lens[f.uid])[None]), scfg,
            seed=f.uid,
        )[0]
        np.testing.assert_array_equal(f.tokens, expect)
    assert eng.allocator.free_count == eng.num_blocks


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_chunked_preemption_mid_prefill_restarts_deterministically(
    params, want, layout
):
    """Preempting a victim while its prompt is still spanning prefill
    chunks discards the partial prefix; re-admission restarts the chunked
    prefill from scratch, so the stream is unchanged — in both cache
    layouts (the paged pool additionally reclaims the partial prompt's
    blocks)."""
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=SCFG,
        layout=layout, block_size=8, chunk=4, prefill_chunk=3,
    )
    # uid 2's prompt (7 tokens) needs three 3-token slices
    for uid in (2, 0):
        eng.submit(_prompt(uid + 10, PROMPTS[uid]), max_new_tokens=6,
                   seed=uid, uid=uid)
    eng.step()  # admits both; exactly one slice of uid 2 has landed
    victim = next(
        rs for rs in eng._live()
        if 0 < rs.prefilled < len(rs.request.prompt)
    )
    assert victim.request.uid == 2 and victim.n_generated == 0
    eng._preempt(victim)
    finished = eng.run()
    assert eng.preemptions == 1
    assert sorted(f.uid for f in finished) == [0, 2]
    for f in finished:
        np.testing.assert_array_equal(f.tokens, want[f.uid])
    if layout == "paged":
        assert eng.allocator.free_count == eng.num_blocks


def test_immediate_finish_budget_one(params, reference):
    """budget=1 finishes at admission (the prefill-sampled token) without
    ever occupying a slot or holding blocks."""
    scfg = SamplerConfig(temperature=0.0, max_new_tokens=1)
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=1, max_len=MAX_LEN, scfg=scfg,
        layout="paged", block_size=8, chunk=4,
    )
    prompt = _prompt(7, 4)
    eng.submit(prompt, max_new_tokens=1, seed=0, uid=0)
    (f,) = eng.run()
    want = reference.generate(jnp.asarray(prompt[None]), scfg, seed=0)[0]
    np.testing.assert_array_equal(f.tokens, want)
    assert eng.allocator.free_count == eng.num_blocks


def test_submit_validation(params):
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=1, max_len=16, scfg=SCFG,
        layout="paged", block_size=8, chunk=4,
    )
    with pytest.raises(ValueError, match="slot capacity"):
        eng.submit(_prompt(0, 10), max_new_tokens=10)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.asarray([], np.int32), max_new_tokens=2)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(_prompt(0, 4), max_new_tokens=0)  # 0 must not mean default


def _assign_tables(caches, table):
    """Give every paged layer the same block-table assignment."""
    def fix(seg):
        return {
            k: (dict(c, table=jnp.broadcast_to(table, c["table"].shape))
                if isinstance(c, dict) and "table" in c else c)
            for k, c in seg.items()
        }
    return [fix(seg) for seg in caches]


def test_api_paged_init_cache_is_a_drop_in_adapter(params):
    """The public ``api.init_cache(layout="paged")`` entry point: decoding
    from scratch over it is bit-for-bit the dense-layout decode (same
    logits, per-slot positions and active masks), and its tree structure
    matches what the engine builds internally."""
    b, max_len, bs = 2, 16, 8
    dense, _ = api.init_cache(CFG, b, max_len, jnp.float32)
    paged, _ = api.init_cache(CFG, b, max_len, jnp.float32, layout="paged",
                              block_size=bs)
    # slot 0 owns blocks [0, 1]; slot 1 owns [2, 3]
    paged = _assign_tables(paged, jnp.asarray([[0, 1], [2, 3]], jnp.int32))
    active = jnp.asarray([True, True])
    for t in range(4):
        tok = jax.random.randint(jax.random.PRNGKey(t), (b, 1), 0, 64)
        pos = jnp.full((b,), t, jnp.int32)
        ld, dense = api.decode_step(params, tok, dense, pos, CFG, active)
        lp, paged = api.decode_step(params, tok, paged, pos, CFG, active)
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))
    # and the engine's internal big-cache tree has the same structure
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=b, max_len=max_len, scfg=SCFG,
        layout="paged", block_size=bs, chunk=2,
    )
    api_tree, _ = api.init_cache(
        CFG, b, max_len, jnp.float32, layout="paged", block_size=bs,
        num_blocks=eng.num_blocks,
    )
    assert (jax.tree.structure(api_tree)
            == jax.tree.structure(eng._caches))
    assert jax.tree.map(jnp.shape, api_tree) == jax.tree.map(
        jnp.shape, eng._caches
    )


def test_auto_uids_never_recycle(params):
    scfg = SamplerConfig(temperature=0.0, max_new_tokens=2)
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=1, max_len=16, scfg=scfg,
        layout="dense", chunk=2,
    )
    a = eng.submit(_prompt(1, 3))
    eng.run()
    b = eng.submit(_prompt(2, 3))  # queue drained: counter must not reset
    eng.run()
    assert a != b


def test_chunk_fn_donates_cache_and_state_buffers(params):
    """The compiled decode chunk aliases its cache-tree and slot-state
    inputs to outputs (donate_argnums): without the aliasing XLA copies
    the full KV pool every chunk.  Asserted on the lowering so the
    invariant holds on backends where we can't watch allocations."""
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=SCFG,
        layout="paged", block_size=8, chunk=2,
    )
    txt = eng._chunk_fn.lower(
        eng.params, eng._caches, eng._state
    ).as_text()
    n_alias = txt.count("tf.aliasing_output")
    n_cache_leaves = len(jax.tree.leaves(eng._caches))
    n_state_leaves = len(jax.tree.leaves(eng._state))
    # every cache and state leaf is donated; params never are
    assert n_alias == n_cache_leaves + n_state_leaves, txt[:500]


def test_engine_stream_chunk_donates_caches(params, reference):
    """DecodeEngine's streaming chunk donates the cache tree too."""
    scfg = SamplerConfig(temperature=0.0, max_new_tokens=4)
    prompts = jnp.asarray(_prompt(1, 4)[None])
    tok, caches, pos, key = reference._prefill_fn(scfg)(
        reference.params, {"tokens": prompts},
        jnp.asarray(0, jnp.int32), jax.random.PRNGKey(0),
    )
    done = jnp.zeros(tok.shape, bool)
    txt = reference._chunk_fn(scfg, 2).lower(
        reference.params, tok, caches, pos, key, done
    ).as_text()
    assert txt.count("tf.aliasing_output") == len(jax.tree.leaves(caches))


def test_bucketed_admission_reuses_prefill_traces(params, want):
    """Ragged prompt lengths share power-of-two padded prefill traces
    (lengths 5, 3, 7, 4 -> buckets 8, 4, 8, 4: two traces, not four) with
    unchanged per-request streams — admission no longer retraces per
    distinct prompt length."""
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=SCFG,
        layout="paged", block_size=8, chunk=4,
    )
    assert eng._prefill_bucketed is not None  # CFG is bucket-safe
    for uid, n in PROMPTS.items():
        eng.submit(_prompt(uid + 10, n), max_new_tokens=6, seed=uid, uid=uid)
    finished = eng.run()
    assert eng._prefill_bucketed._cache_size() == 2
    for f in finished:
        np.testing.assert_array_equal(f.tokens, want[f.uid])


def test_bucketing_disabled_where_parity_unsafe():
    """Ring caches (sliding-window layers) would fold pad tokens into the
    window; those configs keep the exact-length prefill path."""
    from repro.serve.scheduler import _bucketed_prefill_safe

    assert _bucketed_prefill_safe(CFG, MAX_LEN)
    assert not _bucketed_prefill_safe(SWA_CFG, 24)
    moe = ModelConfig(name="m", family="decoder", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=48, vocab_size=64,
                      quant=QC, moe=True, n_routed_experts=2, moe_top_k=1,
                      d_ff_expert=16, first_k_dense=1)
    assert not _bucketed_prefill_safe(moe, MAX_LEN)


def test_chunked_prefill_gating_and_fallback(params):
    """Chunked prefill covers every attention-family config INCLUDING
    ring-cache sliding windows (its in-chunk ring path is sequential, so
    slice boundaries change nothing) — wider than bucketing.  Recurrent
    and MoE configs fall back to one-shot admission: slicing would
    re-associate their recurrences / change routing capacity."""
    from repro.serve.scheduler import _chunked_prefill_safe

    assert _chunked_prefill_safe(CFG)
    assert _chunked_prefill_safe(SWA_CFG)  # ring-safe (unlike bucketing)
    moe = ModelConfig(name="m", family="decoder", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=48, vocab_size=64,
                      quant=QC, moe=True, n_routed_experts=2, moe_top_k=1,
                      d_ff_expert=16, first_k_dense=1)
    ssm = ModelConfig(name="s", family="ssm", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=64,
                      quant=QC, ssm_state=8, ssm_headdim=8, ssm_chunk=4,
                      glu=False)
    assert not _chunked_prefill_safe(moe)
    assert not _chunked_prefill_safe(ssm)
    # requesting chunked prefill on an unsafe config falls back cleanly
    sparams, _ = api.init_model(KEY, ssm)
    eng = ContinuousBatchingEngine(
        sparams, ssm, num_slots=1, max_len=16, scfg=SCFG, layout="dense",
        chunk=2, prefill_chunk=4,
    )
    assert eng.prefill_chunk is None and eng._prefill_chunk is None


@pytest.mark.parametrize("prefill_chunk", [None, 3])
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_parity_with_paged_attention_kernel_enabled(params, reference,
                                                    layout, prefill_chunk,
                                                    monkeypatch):
    """The paged-attention kernel serves the paged layout end to end
    (decode chunks, chunked-prefill slices, one-shot installs) and every
    request's stream still equals ``DecodeEngine.generate`` — greedy
    sampling, so the kernel's float-rounding-level logit differences
    (online softmax vs the oracle's dense softmax) must not move any
    argmax over the whole trace.  The dense layout rides along: with the
    kernel enabled it has nothing paged to walk and must stay bit-for-bit
    on the dense path."""
    from repro.kernels import ops

    monkeypatch.setenv("REPRO_PAGED_ATTN", "1")
    assert ops.paged_attention_enabled()
    scfg = SamplerConfig(temperature=0.0, max_new_tokens=4)
    want = {
        uid: reference.generate(
            jnp.asarray(_prompt(uid + 10, n)[None]), scfg, seed=uid
        )[0]
        for uid, n in list(PROMPTS.items())[:3]
    }
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=scfg,
        layout=layout, block_size=8, chunk=2, prefill_chunk=prefill_chunk,
    )
    for uid, n in list(PROMPTS.items())[:3]:
        eng.submit(_prompt(uid + 10, n), max_new_tokens=4, seed=uid, uid=uid)
    finished = eng.run()
    assert sorted(f.uid for f in finished) == sorted(want)
    for f in finished:
        np.testing.assert_array_equal(f.tokens, want[f.uid])
    if layout == "paged":
        assert eng.allocator.free_count == eng.num_blocks


def test_chunked_prefill_decline_logs_once_per_config(params, caplog):
    """An unsafe config requesting chunked prefill logs the one-shot
    fallback ONCE per config — building more engines (or serving more
    requests) on the same config adds no lines; a different config gets
    its own line."""
    import logging

    from repro.serve import scheduler as sched

    ssm = ModelConfig(name="s", family="ssm", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=64,
                      quant=QC, ssm_state=8, ssm_headdim=8, ssm_chunk=4,
                      glu=False)
    sparams, _ = api.init_model(KEY, ssm)
    sched._CHUNK_DECLINE_LOGGED.clear()
    with caplog.at_level(logging.WARNING, logger="repro.serve.scheduler"):
        for _ in range(3):  # same config, three engines: one line
            eng = ContinuousBatchingEngine(
                sparams, ssm, num_slots=1, max_len=16, scfg=SCFG,
                layout="dense", chunk=2, prefill_chunk=4,
            )
            assert eng.prefill_chunk is None
    declines = [r for r in caplog.records if "declined" in r.message]
    assert len(declines) == 1
    # a config that accepts chunked prefill logs nothing
    caplog.clear()
    with caplog.at_level(logging.WARNING, logger="repro.serve.scheduler"):
        ContinuousBatchingEngine(
            params, CFG, num_slots=1, max_len=MAX_LEN, scfg=SCFG,
            layout="dense", chunk=2, prefill_chunk=4,
        )
    assert not [r for r in caplog.records if "declined" in r.message]


def test_chunked_prefill_budget_one_finishes_at_final_slice(params,
                                                           reference):
    """budget=1 under chunked prefill: the final slice's sampled token
    finishes the request; the slot and its blocks free immediately."""
    scfg = SamplerConfig(temperature=0.0, max_new_tokens=1)
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=1, max_len=MAX_LEN, scfg=scfg,
        layout="paged", block_size=8, chunk=4, prefill_chunk=2,
    )
    prompt = _prompt(7, 5)
    eng.submit(prompt, max_new_tokens=1, seed=0, uid=0)
    (f,) = eng.run()
    expect = reference.generate(jnp.asarray(prompt[None]), scfg, seed=0)[0]
    np.testing.assert_array_equal(f.tokens, expect)
    assert eng.allocator.free_count == eng.num_blocks
    assert all(rs is None for rs in eng._slots)
