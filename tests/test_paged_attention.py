"""Paged-attention kernel subsystem: the Pallas block-table kernel against
the gather+SDPA reference (decode, chunked prefill, one-shot prefill; GQA
and MQA; multiple block sizes), the ops-level dispatch gates, autotune
persistence, and the model-stack routing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref, tile_cache
from repro.kernels.paged_attention import paged_attention

KEY = jax.random.PRNGKey(0)


def _setup(b, hkv, d, bs, mb, nb=None, dtype=jnp.float32, seed=0):
    """Random pools + a scattered (non-identity, per-slot disjoint) block
    table — position order in the table must be what the kernel walks,
    not pool order."""
    rng = np.random.default_rng(seed)
    nb = nb or (b * mb + 3)
    kpool = jnp.asarray(rng.standard_normal((nb, bs, hkv, d)), dtype)
    vpool = jnp.asarray(rng.standard_normal((nb, bs, hkv, d)), dtype)
    table = jnp.asarray(
        rng.permutation(nb)[: b * mb].reshape(b, mb), jnp.int32
    )
    return kpool, vpool, table


def _q(b, t, hq, d, dtype=jnp.float32, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((b, t, hq, d)), dtype)


@pytest.mark.parametrize("hq,hkv", [(4, 2), (4, 1), (2, 2)])  # GQA/MQA/MHA
@pytest.mark.parametrize("bs", [8, 16])
def test_decode_shape_matches_reference(hq, hkv, bs):
    """T=1 decode at ragged per-slot positions: kernel == gather+SDPA
    reference at fp32 accumulation, for GQA, MQA and MHA groupings and
    two block sizes."""
    b, d, mb = 3, 16, 4
    kpool, vpool, table = _setup(b, hkv, d, bs, mb)
    q = _q(b, 1, hq, d)
    start = jnp.asarray([0, bs + 3, mb * bs - 1], jnp.int32)
    got = paged_attention(
        q, kpool, vpool, table, start, start + 1, interpret=True
    )
    want = ref.paged_attention_ref(q, kpool, vpool, table, start, start + 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)


@pytest.mark.parametrize("hq,hkv", [(4, 2), (4, 1)])
@pytest.mark.parametrize("bs", [8, 16])
@pytest.mark.parametrize("pages", [1, 2, 4])
def test_chunk_matches_reference_across_page_tiles(hq, hkv, bs, pages):
    """T>1 chunk against a resident prefix: the causal in-chunk mask and
    the prefix mask both hold for every pages-per-step tiling (the
    autotune knob must never change results)."""
    b, t, d, mb = 2, 5, 8, 4
    kpool, vpool, table = _setup(b, hkv, d, bs, mb)
    q = _q(b, t, hq, d)
    start = jnp.asarray([3, bs - 2], jnp.int32)  # one slot straddles a page
    got = paged_attention(
        q, kpool, vpool, table, start, start + t, pages=pages,
        interpret=True,
    )
    want = ref.paged_attention_ref(q, kpool, vpool, table, start, start + t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)


def test_one_shot_prefill_from_empty_cache():
    """T = S from position 0 (one-shot prefill): every query attends only
    its in-chunk causal predecessors."""
    b, t, hq, hkv, d, bs, mb = 2, 12, 4, 2, 16, 8, 2
    kpool, vpool, table = _setup(b, hkv, d, bs, mb)
    q = _q(b, t, hq, d)
    start = jnp.zeros((b,), jnp.int32)
    got = paged_attention(
        q, kpool, vpool, table, start, start + t, pages=2, interpret=True
    )
    want = ref.paged_attention_ref(q, kpool, vpool, table, start, start + t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)


def test_kv_lens_bounds_the_page_walk():
    """Pages past a slot's resident length are skipped (their index map
    clamps to the last used page) — results must not depend on garbage in
    the unreached pages: poisoning them with NaN stays invisible."""
    b, t, hq, hkv, d, bs, mb = 2, 1, 4, 2, 8, 8, 4
    kpool, vpool, table = _setup(b, hkv, d, bs, mb)
    start = jnp.asarray([2, bs + 1], jnp.int32)
    lens = start + t
    # poison every page beyond each slot's used prefix
    used = [int(-(-int(l) // bs)) for l in lens]
    kp, vp = np.array(kpool), np.array(vpool)
    for s in range(b):
        for pg in range(used[s], mb):
            kp[np.asarray(table)[s, pg]] = np.nan
            vp[np.asarray(table)[s, pg]] = np.nan
    q = _q(b, t, hq, d)
    got = paged_attention(
        q, jnp.asarray(kp), jnp.asarray(vp), table, start, lens,
        interpret=True,
    )
    want = ref.paged_attention_ref(q, kpool, vpool, table, start, lens)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)


def test_bf16_pools_fp32_accumulation():
    """bf16 pools/queries accumulate in f32 in-kernel: the kernel tracks
    the f32 reference to bf16-input rounding, not bf16-accumulation
    error."""
    b, t, hq, hkv, d, bs, mb = 2, 3, 4, 2, 16, 8, 3
    kpool, vpool, table = _setup(b, hkv, d, bs, mb, dtype=jnp.bfloat16)
    q = _q(b, t, hq, d, dtype=jnp.bfloat16)
    start = jnp.asarray([1, 7], jnp.int32)
    got = paged_attention(
        q, kpool, vpool, table, start, start + t, interpret=True
    )
    assert got.dtype == jnp.bfloat16
    want = ref.paged_attention_ref(
        q.astype(jnp.float32),
        kpool.astype(jnp.float32),
        vpool.astype(jnp.float32),
        table,
        start,
        start + t,
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), atol=0.02, rtol=0.02
    )


# ---------------------------------------------------------------------------
# ops-level dispatch / autotune
# ---------------------------------------------------------------------------


def test_ops_wrapper_dispatch_and_gates(monkeypatch):
    b, t, hq, hkv, d, bs, mb = 2, 1, 4, 2, 8, 8, 2
    kpool, vpool, table = _setup(b, hkv, d, bs, mb)
    q = _q(b, t, hq, d)
    start = jnp.asarray([0, 5], jnp.int32)
    got = ops.paged_attention(q, kpool, vpool, table, start, start + 1)
    want = ref.paged_attention_ref(q, kpool, vpool, table, start, start + 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)
    # support gate: GQA must divide, block/head_dim must be 8-aligned
    assert ops.paged_attention_supported(8, 16, 4, 2)
    assert not ops.paged_attention_supported(4, 16, 4, 2)  # block % 8
    assert not ops.paged_attention_supported(8, 12, 4, 2)  # head_dim % 8
    assert not ops.paged_attention_supported(8, 16, 4, 3)  # Hq % Hkv
    # enable gate: env forces beat the backend default
    monkeypatch.setenv("REPRO_PAGED_ATTN", "1")
    assert ops.paged_attention_enabled()
    monkeypatch.setenv("REPRO_PAGED_ATTN", "0")
    assert not ops.paged_attention_enabled()
    monkeypatch.delenv("REPRO_PAGED_ATTN")
    assert ops.paged_attention_enabled() == ops.on_tpu()


def test_paged_tiles_heuristic_prefers_dividing_candidates():
    assert ops.paged_tiles(1, 4, 2, 16, 8, 8) == 8
    assert ops.paged_tiles(1, 4, 2, 16, 8, 6) == 2
    assert ops.paged_tiles(1, 4, 2, 16, 8, 3) == 1


def test_sweep_paged_tiles_persists_per_backend(tmp_path, monkeypatch):
    """The paged-attention autotune family rides the same per-backend JSON
    as the GEMV tables: a swept winner survives a (simulated) process
    restart under its (T, Hq, Hkv, D, block, max_blocks) signature."""
    monkeypatch.setenv("REPRO_TILE_CACHE", "1")
    monkeypatch.setenv("REPRO_TILE_CACHE_DIR", str(tmp_path))
    saved = dict(ops._DECODE_TILE_CACHE)
    saved_loaded = ops._TILE_CACHE_LOADED
    ops._DECODE_TILE_CACHE.clear()
    ops._TILE_CACHE_LOADED = False
    try:
        t, hq, hkv, d, bs, mb = 1, 4, 2, 8, 8, 4
        best = ops.sweep_paged_tiles(
            t, hq, hkv, d, bs, mb, candidates=(1, 2), warmup=0, iters=1
        )
        assert best in (1, 2)
        key = ("paged_attn", t, hq, hkv, d, bs, mb)
        assert tile_cache.load("cpu")[key] == (best,)
        # simulated restart: the persisted winner answers paged_tiles
        ops._DECODE_TILE_CACHE.clear()
        ops._TILE_CACHE_LOADED = False
        assert ops.paged_tiles(t, hq, hkv, d, bs, mb) == best
        # GEMV keys coexist in the same file
        tile_cache.store("cpu", {("w1a8_gemv", 8, 64, 32): (16, 32)})
        loaded = tile_cache.load("cpu")
        assert loaded[key] == (best,)
        assert loaded[("w1a8_gemv", 8, 64, 32)] == (16, 32)
    finally:
        ops._DECODE_TILE_CACHE.clear()
        ops._DECODE_TILE_CACHE.update(saved)
        ops._TILE_CACHE_LOADED = saved_loaded


# ---------------------------------------------------------------------------
# model-stack routing
# ---------------------------------------------------------------------------


def _tiny_cfg():
    from repro.configs.base import ModelConfig
    from repro.core.quantization import QuantConfig

    return ModelConfig(
        name="pa", family="decoder", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=48, vocab_size=64,
        quant=QuantConfig(mode="pquant", r=16, num_experts=1),
    )


def _paged_caches(cfg, b, max_len, bs):
    from repro.models import api

    caches, _ = api.init_cache(
        cfg, b, max_len, jnp.float32, layout="paged", block_size=bs
    )
    mb = max_len // bs
    table = jnp.arange(b * mb, dtype=jnp.int32).reshape(b, mb)

    def fix(seg):
        return {
            k: (dict(c, table=jnp.broadcast_to(table, c["table"].shape))
                if isinstance(c, dict) and "table" in c else c)
            for k, c in seg.items()
        }

    return [fix(seg) for seg in caches]


def test_model_paged_branches_route_through_kernel(monkeypatch):
    """attention_chunk / the decode fast path produce (allclose) the same
    logits with the kernel forced on as with the gather+SDPA fallback —
    chunked prefill, ragged final slices and decode all ride the one
    kernel."""
    from repro.models import api

    cfg = _tiny_cfg()
    params, _ = api.init_model(KEY, cfg)
    b, max_len, bs = 2, 16, 8
    outs = {}
    for env in ("0", "1"):
        monkeypatch.setenv("REPRO_PAGED_ATTN", env)
        caches = _paged_caches(cfg, b, max_len, bs)
        active = jnp.asarray([True, True])
        got = []
        # chunked prefill: a full slice then a ragged one
        tok = jax.random.randint(KEY, (b, 4), 0, 64)
        l, caches = api.forward_chunk(
            params, tok, caches, jnp.zeros((b,), jnp.int32), cfg,
            active=active,
        )
        got.append(l)
        tok = jax.random.randint(jax.random.PRNGKey(9), (b, 4), 0, 64)
        l, caches = api.forward_chunk(
            params, tok, caches, jnp.full((b,), 4, jnp.int32), cfg,
            active=active, lengths=jnp.asarray([4, 2], jnp.int32),
            logits_at=jnp.asarray([3, 1], jnp.int32),
        )
        got.append(l)
        # decode fast path at ragged per-slot positions
        pos = jnp.asarray([8, 6], jnp.int32)
        for t in range(3):
            tok = jax.random.randint(jax.random.PRNGKey(20 + t), (b, 1), 0, 64)
            l, caches = api.decode_step(params, tok, caches, pos + t, cfg,
                                        active)
            got.append(l)
        outs[env] = [np.asarray(x) for x in got]
    for off, on in zip(outs["0"], outs["1"]):
        np.testing.assert_allclose(off, on, atol=3e-5)


def test_unsupported_block_size_falls_back(monkeypatch):
    """block_size 4 fails the support gate: forcing the kernel on must
    quietly keep the gather path (bitwise the fallback result)."""
    from repro.models import api

    cfg = _tiny_cfg()
    params, _ = api.init_model(KEY, cfg)
    b, max_len, bs = 2, 16, 4
    outs = {}
    for env in ("0", "1"):
        monkeypatch.setenv("REPRO_PAGED_ATTN", env)
        caches = _paged_caches(cfg, b, max_len, bs)
        tok = jax.random.randint(KEY, (b, 1), 0, 64)
        l, _ = api.decode_step(
            params, tok, caches, jnp.zeros((b,), jnp.int32), cfg,
            jnp.asarray([True, True]),
        )
        outs[env] = np.asarray(l)
    np.testing.assert_array_equal(outs["0"], outs["1"])
