"""Tensor-parallel serving: the 1-device mesh must be bit-for-bit the
meshless engine (both cache layouts, one-shot and chunked admission
prefill, greedy decode), N-major shards of packed weights must round-trip
through pack/unpack with the replicated per-tensor scales, and a forced
2-device CPU mesh must reproduce the single-device token streams (the
column-parallel design never splits a K reduction, so even multi-device
decode is token-exact on these sizes)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import packing
from repro.core.quantization import QuantConfig
from repro.distributed import sharding as sh
from repro.launch.mesh import make_host_mesh, mesh_from_env
from repro.models import api
from repro.serve.engine import DecodeEngine, SamplerConfig, serving_overrides
from repro.serve.scheduler import ContinuousBatchingEngine
from repro.train.quantized_serving import quantize_params_for_serving

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

QC = QuantConfig(mode="pquant", r=16, num_experts=1)
CFG = ModelConfig(name="t", family="decoder", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64, quant=QC)
MAX_LEN = 32
GREEDY = SamplerConfig(temperature=0.0, max_new_tokens=6)


@pytest.fixture(scope="module")
def params():
    return api.init_model(jax.random.PRNGKey(1), CFG)[0]


@pytest.fixture(scope="module")
def qparams(params):
    _, axes = api.params_shape_and_axes(CFG)
    return quantize_params_for_serving(params, axes, CFG, packed=True)[0]


def _prompt(seed, n=5):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, 64), np.int32
    )


class TestNMajorRoundTrip:
    """Sharding a packed weight N-major (last axis) with the replicated
    per-tensor scale must reconstruct the unsharded dequantization — this
    is the invariant that makes column-parallel serving exact."""

    def test_bit_packed_shards_roundtrip(self):
        w = np.asarray(
            jax.random.normal(jax.random.PRNGKey(0), (32, 48), jnp.float32)
        )
        exp = packing.export_bit_weight(jnp.asarray(w))
        full = np.asarray(exp.dequantize())
        for ws in (2, 4):  # every shard dequantizes with the SAME lam
            shards = np.split(np.asarray(exp.packed), ws, axis=-1)
            got = np.concatenate(
                [
                    np.asarray(packing.unpack_signs(jnp.asarray(s)))
                    * float(exp.lam)
                    for s in shards
                ],
                axis=-1,
            )
            np.testing.assert_array_equal(got, full)

    def test_int8_shards_roundtrip(self):
        w = np.asarray(
            jax.random.normal(jax.random.PRNGKey(1), (32, 48), jnp.float32)
        )
        exp = packing.export_int8_weight(jnp.asarray(w))
        full = np.asarray(exp.dequantize())
        shards = np.split(np.asarray(exp.q), 4, axis=-1)
        got = np.concatenate(
            [s.astype(np.float32) / float(exp.scale) for s in shards], axis=-1
        )
        np.testing.assert_array_equal(got, full)

    def test_nmajor_axis_gates_on_divisibility(self):
        mesh = make_host_mesh(1, 1)
        with sh.sharding_rules(mesh, None):
            # size-1 axis -> no island, 1-device lowering stays identical
            assert sh.nmajor_axis(48, "ffn") is None
        assert sh.nmajor_axis(48, "ffn") is None  # no active mesh


class TestServingOverrides:
    def test_indivisible_heads_replicate(self):
        mesh = make_host_mesh(1, 1)
        odd = ModelConfig(name="o", family="decoder", n_layers=1, d_model=30,
                          n_heads=3, n_kv_heads=3, d_ff=48, vocab_size=64,
                          quant=QC)
        ov = serving_overrides(odd, mesh)
        # model axis is size 1 here, so no relaxation is needed
        assert "kv_heads" not in ov and ov["batch"] is None

    def test_mesh_env_parsing(self, monkeypatch):
        monkeypatch.setenv("REPRO_MESH", "1,1")
        assert dict(mesh_from_env().shape) == {"data": 1, "model": 1}
        monkeypatch.setenv("REPRO_MESH", "bogus")
        with pytest.raises(ValueError):
            mesh_from_env()
        monkeypatch.delenv("REPRO_MESH")
        assert mesh_from_env() is None

    def test_oversubscribed_mesh_raises(self):
        with pytest.raises(ValueError):
            make_host_mesh(data=jax.device_count() + 1, model=2)


class TestOneDeviceMeshParity:
    """Acceptance: mesh=(1,1) is bit-for-bit the meshless engine."""

    @pytest.mark.parametrize("packed", [False, True])
    def test_decode_engine_bitwise(self, params, qparams, packed):
        p = qparams if packed else params
        mesh = make_host_mesh(1, 1)
        ref = DecodeEngine(p, CFG, MAX_LEN)
        got = DecodeEngine(p, CFG, MAX_LEN, mesh=mesh)
        prompt = jnp.asarray(_prompt(7)[None])
        np.testing.assert_array_equal(
            got.generate(prompt, GREEDY, seed=0),
            ref.generate(prompt, GREEDY, seed=0),
        )
        # sampled decode shares the PRNG stream (replicated), so it must
        # match too
        scfg = SamplerConfig(temperature=0.7, top_k=10, max_new_tokens=6)
        np.testing.assert_array_equal(
            got.generate(prompt, scfg, seed=3),
            ref.generate(prompt, scfg, seed=3),
        )

    def test_decode_engine_stream_bitwise(self, qparams):
        mesh = make_host_mesh(1, 1)
        ref = DecodeEngine(qparams, CFG, MAX_LEN)
        got = DecodeEngine(qparams, CFG, MAX_LEN, mesh=mesh)
        prompt = jnp.asarray(_prompt(9)[None])
        a = np.concatenate(
            list(ref.generate_stream(prompt, GREEDY, chunk=3, seed=0)), axis=1
        )
        b = np.concatenate(
            list(got.generate_stream(prompt, GREEDY, chunk=3, seed=0)), axis=1
        )
        np.testing.assert_array_equal(b, a)

    @pytest.mark.parametrize("prefill_chunk", [None, 3])
    @pytest.mark.parametrize("layout", ["dense", "paged"])
    def test_continuous_bitwise(self, qparams, layout, prefill_chunk):
        mesh = make_host_mesh(1, 1)
        kw = dict(num_slots=2, max_len=MAX_LEN, scfg=GREEDY, layout=layout,
                  block_size=8, chunk=4, prefill_chunk=prefill_chunk)
        ref = ContinuousBatchingEngine(qparams, CFG, **kw)
        got = ContinuousBatchingEngine(qparams, CFG, mesh=mesh, **kw)
        for eng in (ref, got):
            for uid, n in ((0, 5), (1, 7)):
                eng.submit(_prompt(uid + 10, n), max_new_tokens=6,
                           seed=uid, uid=uid)
        want = {f.uid: f.tokens for f in ref.run()}
        have = {f.uid: f.tokens for f in got.run()}
        assert want.keys() == have.keys()
        for uid in want:
            np.testing.assert_array_equal(have[uid], want[uid])

    def test_mesh_gauges_exported(self, qparams):
        eng = ContinuousBatchingEngine(
            qparams, CFG, num_slots=2, max_len=MAX_LEN, scfg=GREEDY,
            layout="paged", block_size=8, chunk=4, mesh=make_host_mesh(1, 1),
        )
        snap = eng.metrics.snapshot()
        assert snap["gauges"]["mesh_data_parallelism"] == 1.0
        assert snap["gauges"]["mesh_model_parallelism"] == 1.0


@pytest.mark.slow
class TestMultiDevice:
    """Forced 2-device CPU mesh: weights genuinely shard, kernel islands
    agree with the unsharded kernels, and token streams match the
    single-device engines."""

    def _run(self, code: str) -> dict:
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        env["PYTHONPATH"] = REPO_SRC
        env.pop("JAX_PLATFORMS", None)
        out = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(code)],
            capture_output=True, text=True, env=env, timeout=600,
        )
        assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
        return json.loads(out.stdout.strip().splitlines()[-1])

    def test_two_device_parity(self):
        res = self._run("""
            import json
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs.base import ModelConfig
            from repro.core.quantization import QuantConfig
            from repro.distributed import sharding as sh
            from repro.kernels import ops
            from repro.launch.mesh import make_host_mesh
            from repro.models import api
            from repro.serve.engine import DecodeEngine, SamplerConfig
            from repro.serve.scheduler import ContinuousBatchingEngine
            from repro.train.quantized_serving import (
                quantize_params_for_serving,
            )

            assert jax.device_count() == 2
            qc = QuantConfig(mode="pquant", r=16, num_experts=1)
            cfg = ModelConfig(name="t", family="decoder", n_layers=2,
                              d_model=32, n_heads=4, n_kv_heads=2, d_ff=48,
                              vocab_size=64, quant=qc)
            params, axes = api.init_model(jax.random.PRNGKey(1), cfg)
            qp, _ = quantize_params_for_serving(params, axes, cfg,
                                                packed=True)
            mesh = make_host_mesh(1, 2)
            scfg = SamplerConfig(temperature=0.0, max_new_tokens=6)
            prompt = np.asarray(jax.random.randint(
                jax.random.PRNGKey(7), (5,), 0, 64), np.int32)

            # kernel islands vs unsharded kernels
            x = jax.random.normal(jax.random.PRNGKey(2), (4, 32),
                                  jnp.float32)
            from repro.core.packing import export_bit_weight
            exp = export_bit_weight(
                jax.random.normal(jax.random.PRNGKey(3), (32, 48),
                                  jnp.float32))
            lam = exp.lam.reshape(1, 1)
            with sh.sharding_rules(mesh, None):
                a = ops.bit_linear_infer(x, exp.packed, lam)
                b = ops.bit_linear_infer_nshard(x, exp.packed, lam, "model")
            island_ok = bool(np.allclose(np.asarray(a), np.asarray(b),
                                         atol=1e-5))

            ref = DecodeEngine(qp, cfg, 32)
            eng = DecodeEngine(qp, cfg, 32, mesh=mesh)
            n_sharded = sum(
                1 for leaf in jax.tree_util.tree_leaves(eng.params)
                if any(s is not None
                       for s in getattr(leaf.sharding, "spec", ()))
            )
            a = ref.generate(jnp.asarray(prompt[None]), scfg, seed=0)
            b = eng.generate(jnp.asarray(prompt[None]), scfg, seed=0)
            decode_ok = bool(np.array_equal(a, b))

            kw = dict(num_slots=2, max_len=32, scfg=scfg, layout="paged",
                      block_size=8, chunk=4, prefill_chunk=3)
            e0 = ContinuousBatchingEngine(qp, cfg, **kw)
            e1 = ContinuousBatchingEngine(qp, cfg, mesh=mesh, **kw)
            for e in (e0, e1):
                e.submit(prompt, max_new_tokens=6, seed=0, uid=0)
            f0 = {f.uid: f.tokens for f in e0.run()}
            f1 = {f.uid: f.tokens for f in e1.run()}
            cb_ok = all(np.array_equal(f0[u], f1[u]) for u in f0)
            pool_sharded = sum(
                1 for leaf in jax.tree_util.tree_leaves(e1._caches)
                if any(s is not None
                       for s in getattr(leaf.sharding, "spec", ()))
            )
            print(json.dumps({
                "island_ok": island_ok, "decode_ok": decode_ok,
                "cb_ok": bool(cb_ok), "n_sharded": n_sharded,
                "pool_sharded": pool_sharded,
            }))
        """)
        assert res["island_ok"], "nshard kernel island diverged"
        assert res["decode_ok"], "2-device DecodeEngine tokens diverged"
        assert res["cb_ok"], "2-device continuous engine tokens diverged"
        assert res["n_sharded"] > 0, "no weight leaf actually sharded"
        assert res["pool_sharded"] > 0, "no KV pool leaf actually sharded"
