"""Bit-packing roundtrip + export invariants."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package"
)
import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from repro.core.packing import (
    export_bit_weight,
    export_int8_weight,
    model_weight_bytes,
    pack_signs,
    unpack_signs,
)


class TestPacking:
    @hypothesis.settings(max_examples=25, deadline=None)
    @hypothesis.given(
        hnp.arrays(
            np.int8,
            st.tuples(
                st.integers(1, 16).map(lambda k: k * 8), st.integers(1, 24)
            ),
            elements=st.sampled_from([-1, 1]),
        )
    )
    def test_roundtrip(self, signs):
        packed = pack_signs(jnp.asarray(signs))
        assert packed.dtype == jnp.uint8
        assert packed.shape == (signs.shape[0] // 8, signs.shape[1])
        out = unpack_signs(packed)
        np.testing.assert_array_equal(np.asarray(out), signs)

    def test_sixteen_x_compression(self):
        k, n = 1024, 512
        signs = np.where(np.random.default_rng(0).random((k, n)) > 0.5, 1, -1)
        packed = pack_signs(jnp.asarray(signs.astype(np.int8)))
        assert packed.size == k * n // 8  # 1/16 of fp16 bytes

    def test_export_dequant_error(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.standard_normal((256, 64)).astype(np.float32) * 0.02)
        pw = export_bit_weight(w)
        deq = np.asarray(pw.dequantize())
        # dequantized weight is the AbsMean binarization of w
        lam = float(jnp.mean(jnp.abs(w)))
        np.testing.assert_allclose(np.abs(deq), lam, rtol=1e-5)
        mu = float(jnp.mean(w))
        np.testing.assert_array_equal(
            np.sign(deq), np.where(np.asarray(w) - mu >= 0, 1.0, -1.0)
        )

    def test_export_int8(self):
        w = jnp.asarray(np.random.default_rng(1).standard_normal((64, 64)) * 0.1)
        pw = export_int8_weight(w)
        err = np.abs(np.asarray(pw.dequantize()) - np.asarray(w)).max()
        assert err <= float(1.0 / pw.scale) * 0.51 + 1e-6

    def test_memory_model_top1_read_invariance(self):
        """paper §4.5: read bytes constant in N (only one branch active)."""
        base = model_weight_bytes(1_000_000, 50_000, 10_000, seq_active_8bit=50_000)
        grown = model_weight_bytes(1_000_000, 8 * 50_000, 10_000, seq_active_8bit=50_000)
        assert base["read_bytes"] == grown["read_bytes"]
        assert grown["stored_bytes"] > base["stored_bytes"]
