"""Unit + property tests for the quantizers (paper Eq. 3-9)."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package"
)
import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import (
    INT8_QMAX,
    QuantConfig,
    binarize_weights,
    binarize_weights_channelwise,
    binarize_weights_grouped,
    binarize_weights_stacked,
    effective_bits,
    fake_quant_linear_weights,
    quantize_activations_int8,
    quantize_weights_int8,
    ste_round,
    ste_sign,
    ternarize_weights,
)

SETTINGS = hypothesis.settings(max_examples=30, deadline=None)

floats_2d = hnp.arrays(
    np.float32,
    hnp.array_shapes(min_dims=2, max_dims=2, min_side=2, max_side=32),
    elements=st.floats(-10, 10, width=32, allow_nan=False),
)


class TestBinarize:
    def test_two_level(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        wq, lam = binarize_weights(w)
        vals = np.unique(np.asarray(wq))
        assert len(vals) <= 2
        np.testing.assert_allclose(np.abs(vals), float(lam), rtol=1e-6)

    def test_lambda_is_absmean(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 16))
        _, lam = binarize_weights(w)
        np.testing.assert_allclose(
            float(lam), float(jnp.mean(jnp.abs(w))), rtol=1e-4
        )

    def test_sign_follows_centered_weight(self):
        w = jnp.asarray([[3.0, -1.0], [0.5, -2.5]])
        wq, lam = binarize_weights(w)
        mu = float(jnp.mean(w))
        expect = np.where(np.asarray(w) - mu >= 0, 1.0, -1.0) * float(lam)
        np.testing.assert_allclose(np.asarray(wq), expect, rtol=1e-6)

    @SETTINGS
    @hypothesis.given(floats_2d)
    def test_property_levels_and_scale(self, w):
        hypothesis.assume(np.abs(w).sum() > 1e-3)
        wq, lam = binarize_weights(jnp.asarray(w))
        wq = np.asarray(wq)
        assert np.all(np.isfinite(wq))
        # exactly +-lambda
        np.testing.assert_allclose(np.abs(wq), float(lam), rtol=1e-5)

    def test_ste_gradient_is_identity_like(self):
        w = jax.random.normal(jax.random.PRNGKey(2), (8, 8))
        g = jax.grad(lambda w: jnp.sum(binarize_weights(w)[0] * 3.0))(w)
        # d/dw [ste(sign)*lam] ~ contributions from both sign (identity) and
        # lam (mean |w|) paths; must be finite and nonzero
        assert np.isfinite(np.asarray(g)).all()
        assert float(jnp.abs(g).sum()) > 0

    def test_stacked_matches_per_slice(self):
        w = jax.random.normal(jax.random.PRNGKey(3), (4, 16, 8))
        wq_st, lam_st = binarize_weights_stacked(w)
        for i in range(4):
            wq_i, lam_i = binarize_weights(w[i])
            np.testing.assert_allclose(
                np.asarray(wq_st[i]), np.asarray(wq_i), rtol=1e-6
            )

    def test_grouped_shapes_and_levels(self):
        w = jax.random.normal(jax.random.PRNGKey(4), (8, 64))
        wq, lam = binarize_weights_grouped(w, group_size=16)
        assert wq.shape == w.shape
        assert lam.shape == (8, 4)

    def test_channelwise(self):
        w = jax.random.normal(jax.random.PRNGKey(5), (32, 8))
        wq, lam = binarize_weights_channelwise(w)
        assert lam.shape == (8,)
        for j in range(8):
            col = np.unique(np.abs(np.asarray(wq[:, j])))
            np.testing.assert_allclose(col, float(lam[j]), rtol=1e-5)


class TestTernary:
    def test_three_levels(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
        wq, lam = ternarize_weights(w)
        vals = np.unique(np.round(np.asarray(wq / lam)).astype(int))
        assert set(vals.tolist()) <= {-1, 0, 1}

    def test_zero_preserved(self):
        w = jnp.zeros((4, 4))
        wq, _ = ternarize_weights(w)
        np.testing.assert_array_equal(np.asarray(wq), 0.0)


class TestActivationQuant:
    def test_grid_alignment(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 128)) * 3
        xq, gamma = quantize_activations_int8(x)  # gamma keeps dims: (4, 1)
        # dequantized values land on the int8 grid
        grid = np.asarray(xq * gamma)
        np.testing.assert_allclose(grid, np.round(grid), atol=1e-3)
        assert np.abs(grid).max() <= INT8_QMAX + 1e-3

    def test_per_token_scale(self):
        x = jnp.stack([jnp.ones(16) * 0.1, jnp.ones(16) * 100.0])
        _, gamma = quantize_activations_int8(x)
        assert float(gamma[0, 0]) > float(gamma[1, 0])

    @SETTINGS
    @hypothesis.given(floats_2d)
    def test_property_bounded_error(self, x):
        hypothesis.assume(np.abs(x).max() > 1e-3)
        xj = jnp.asarray(x)
        xq, gamma = quantize_activations_int8(xj)
        # max error bounded by half a quantization step per token
        step = 1.0 / np.asarray(gamma)  # (M, 1)
        err = np.abs(np.asarray(xq) - x)
        assert (err <= 0.51 * step + 1e-5).all()

    def test_idempotent(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
        xq, _ = quantize_activations_int8(x)
        xqq, _ = quantize_activations_int8(xq)
        np.testing.assert_allclose(np.asarray(xq), np.asarray(xqq), atol=1e-2)


class TestSTE:
    def test_ste_round_grad(self):
        g = jax.grad(lambda x: jnp.sum(ste_round(x * 2.0)))(jnp.ones(4))
        np.testing.assert_allclose(np.asarray(g), 2.0)

    def test_ste_sign_values(self):
        x = jnp.asarray([-1.5, 0.0, 2.0])
        np.testing.assert_array_equal(np.asarray(ste_sign(x)), [-1.0, 1.0, 1.0])


class TestConfig:
    def test_mode_dispatch(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (16, 16))
        assert np.allclose(
            np.asarray(fake_quant_linear_weights(w, QuantConfig(mode="none"))),
            np.asarray(w),
        )
        w1 = fake_quant_linear_weights(w, QuantConfig(mode="bitnet"))
        assert len(np.unique(np.asarray(w1))) <= 2
        w158 = fake_quant_linear_weights(w, QuantConfig(mode="bitnet158"))
        assert len(np.unique(np.asarray(w158))) <= 3

    def test_effective_bits_matches_paper_scale(self):
        # paper: ~95% 1-bit + ~5% 8-bit linear weights -> ~1.35 bits
        bits = effective_bits(950, 50, 0)
        assert 1.2 < bits < 1.5

    def test_int8_weight_quant(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (16, 16))
        wq, scale = quantize_weights_int8(w)
        q = np.asarray(wq * scale)
        np.testing.assert_allclose(q, np.round(q), atol=1e-3)
