"""Tier-1 smoke for the decode benchmark: the whole python-loop-vs-engine
comparison runs (CPU, tiny config) and reports both paths."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def test_bench_decode_smoke(capsys):
    from benchmarks import bench_decode

    rows = bench_decode.run(smoke=True, batch=2, prompt_len=4, new_tokens=4)
    names = [r.split(",")[0] for r in rows]
    assert "decode/python_loop" in names
    assert "decode/engine" in names
    assert "decode/engine_stream" in names
    # the engine row carries a tokens/sec figure for both paths
    by_name = dict(zip(names, rows))
    assert "tok_s=" in by_name["decode/python_loop"]
    assert "tok_s=" in by_name["decode/engine"]
    # compiled engine does exactly one device->host transfer per call
    assert by_name["decode/host_transfers"].endswith("per_call=1")
