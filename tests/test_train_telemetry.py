"""Training-side observability (PR 10): the on-device QAT health probes,
the Trainer's metrics/trace/heartbeat wiring, and the load-bearing
contract inherited from the serving stack — telemetry disabled must
lower the SAME compiled train_step, byte for byte."""

import json
import os
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.quantization import (
    EPS,
    INT8_QMAX,
    QuantConfig,
    quantize_activations_int8,
)
from repro.data.pipeline import DataConfig, SyntheticSource, host_batch
from repro.telemetry import probes
from repro.telemetry.metrics import ManualClock, MetricsRegistry, validate_snapshot
from repro.telemetry.tracing import JsonlSink, ListSink, TrainTracer
from repro.train.trainer import (
    Trainer,
    TrainerConfig,
    _write_atomic,
    init_train_state,
    make_train_step,
)

QC = QuantConfig(mode="pquant", r=16, num_experts=1)
CFG = ModelConfig(name="t", family="decoder", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64, quant=QC)


def _data_iter(cfg, steps, seq=16, batch=4, seed=0):
    src = SyntheticSource(cfg.vocab_size, seed=seed)
    dcfg = DataConfig(seq_len=seq, global_batch=batch, seed=seed)
    for s in range(steps + 1):
        yield s, host_batch(src, dcfg, s)


def _batch(cfg, seq=16, batch=4):
    src = SyntheticSource(cfg.vocab_size, seed=0)
    raw = host_batch(src, DataConfig(seq_len=seq, global_batch=batch), 0)
    return {k: jnp.asarray(v) for k, v in raw.items()}


# ---------------------------------------------------------------------------
# the invariant: telemetry off => byte-identical lowering
# ---------------------------------------------------------------------------


class TestByteIdenticalLowering:
    def test_trainer_with_telemetry_lowers_identically(self, tmp_path):
        """Registry + tracer + history streaming attached, probes=False:
        the compiled train_step must equal a bare build's, byte for byte
        (all of that instrumentation is host-side)."""
        state, _ = init_train_state(jax.random.PRNGKey(0), CFG)
        batch = _batch(CFG)
        bare = jax.jit(make_train_step(CFG, 10), donate_argnums=(0,))
        tcfg = TrainerConfig(
            total_steps=10, probes=False,
            trace_path=str(tmp_path / "t.jsonl"),
            history_path=str(tmp_path / "h.jsonl"),
        )
        tr = Trainer(CFG, tcfg, _data_iter(CFG, 0),
                     metrics=MetricsRegistry(),
                     tracer=TrainTracer(ListSink()))
        low_bare = bare.lower(state, batch).as_text()
        low_tr = tr.step_fn.lower(state, batch).as_text()
        assert low_bare == low_tr

    def test_probe_flag_defaults_off(self):
        step_default = jax.jit(make_train_step(CFG, 10), donate_argnums=(0,))
        step_off = jax.jit(make_train_step(CFG, 10, probes=False),
                           donate_argnums=(0,))
        state, _ = init_train_state(jax.random.PRNGKey(0), CFG)
        batch = _batch(CFG)
        assert (step_default.lower(state, batch).as_text()
                == step_off.lower(state, batch).as_text())


# ---------------------------------------------------------------------------
# probe correctness on hand-built weights
# ---------------------------------------------------------------------------


class TestParamProbes:
    def test_sign_flip_rate_known_counts(self):
        # mixer leaf (family attn): per-slice mean is 0; flipping the sign
        # of every element flips every centered sign -> rate 1.0
        w_old = jnp.asarray([[1.0, -1.0], [1.0, -1.0]])
        tree_old = {"mixer": {"w": w_old}}
        tree_new = {"mixer": {"w": -w_old}}
        grads = {"mixer": {"w": jnp.zeros_like(w_old)}}
        out = probes.train_step_probes(tree_old, tree_new, grads)
        assert float(out["qat_flip_attn"]) == 1.0
        # |w| unchanged -> AbsMean scale drift exactly 0
        assert float(out["qat_scale_drift_absmean"]) == 0.0

    def test_partial_flip_and_branch_split(self):
        w1_old = jnp.asarray([[1.0, -1.0], [1.0, -1.0]])
        w1_new = jnp.asarray([[1.0, -1.0], [-1.0, 1.0]])  # 2 of 4 flip
        # 8-bit branch halves uniformly: signs keep, amax 2 -> 1
        w8_old = jnp.asarray([[2.0, 1.0], [0.5, 2.0]])
        w8_new = w8_old / 2.0
        g1 = jnp.asarray([[3.0, 4.0], [0.0, 0.0]])  # ||g1|| = 5
        g8 = jnp.asarray([[2.0, 2.0], [2.0, 2.0]])  # ||g8|| = 4
        old = {"ffn": {"w1_up": w1_old, "w8_up": w8_old}}
        new = {"ffn": {"w1_up": w1_new, "w8_up": w8_new}}
        grads = {"ffn": {"w1_up": g1, "w8_up": g8}}
        out = probes.train_step_probes(old, new, grads)
        assert float(out["qat_flip_ffn1"]) == 0.5
        assert float(out["qat_flip_ffn8"]) == 0.0
        np.testing.assert_allclose(
            float(out["qat_scale_drift_absmax"]), 1.0 / (2.0 + EPS), rtol=1e-6
        )
        np.testing.assert_allclose(float(out["qat_gnorm_ffn1"]), 5.0)
        np.testing.assert_allclose(float(out["qat_gnorm_ffn8"]), 4.0)
        np.testing.assert_allclose(
            float(out["qat_gnorm_share8"]), 16.0 / (16.0 + 25.0), rtol=1e-6
        )

    def test_int8_weight_clip_fraction(self):
        # amax = 1.0 -> scale = 127/(1+EPS); the two 1.0 entries round to
        # 127 (clip), 0.5 -> 63 and 0.25 -> 32 stay inside the grid
        w8 = jnp.asarray([[1.0, 0.5], [0.25, 1.0]])
        tree = {"ffn": {"w8_up": w8}}
        zeros = {"ffn": {"w8_up": jnp.zeros_like(w8)}}
        out = probes.train_step_probes(tree, tree, zeros)
        assert float(out["qat_clip_w8"]) == 0.5

    def test_norm_and_router_leaves_are_skipped(self):
        tree = {
            "ffn_norm": {"scale": jnp.ones((4, 4))},
            "ffn": {"subln": {"scale": jnp.ones((4, 4))},
                    "router": {"w": jnp.ones((4, 4))}},
        }
        out = probes.train_step_probes(tree, tree, tree)
        assert out == {}

    def test_family_classification(self):
        cases = {
            "segments/0/b0/mixer/wq/w": "attn",
            "segments/0/b0/ffn/w1_up": "ffn1",
            "segments/0/b0/ffn/w8_down": "ffn8",
            "embed/table": "embed",
            "segments/0/b0/ffn/router/w": None,
            "segments/0/b0/ffn/subln/scale": None,
            "final_norm/scale": None,
        }
        for key, fam in cases.items():
            assert probes.family_of(key) == fam, key


class TestForwardTaps:
    def test_activation_clip_tap(self):
        # per-token AbsMax: amax = 4 -> the three 4.0s hit the 127 rail
        x = jnp.asarray([[4.0, 4.0, 4.0, 1.0]])
        with probes.collect():
            quantize_activations_int8(x)
            out = probes.summaries()
        np.testing.assert_allclose(float(out["qat_clip_act"]), 0.75, rtol=1e-6)

    def test_taps_are_silent_outside_collect(self):
        x = jnp.asarray([[4.0, 4.0]])
        quantize_activations_int8(x)  # no ambient collector: no recording
        assert not probes.active()
        assert probes.summaries() == {}

    def test_branch_share_ratio(self):
        with probes.collect():
            probes.add("branch1_sq", 3.0)
            probes.add("branch8_sq", 1.0)
            out = probes.summaries()
        np.testing.assert_allclose(float(out["qat_branch_share8"]), 0.25)

    def test_weighted_mean_across_tap_sites(self):
        with probes.collect():
            probes.add_mean("clip_act", 1.0, 1.0)
            probes.add_mean("clip_act", 0.0, 3.0)
            out = probes.summaries()
        np.testing.assert_allclose(float(out["qat_clip_act"]), 0.25)

    def test_scan_discipline_round_trip(self):
        """Records inside a scan body leave as ys and re-merge summed;
        pre-scan records are held aside, not broadcast per iteration."""
        with probes.collect():
            probes.add("pre", 1.0)
            with probes.scan_scope():
                def body(c, x):
                    probes.add("inner", x)
                    return c, probes.scan_drain()
                _, ys = jax.lax.scan(body, 0.0, jnp.asarray([1.0, 2.0, 3.0]))
                probes.scan_merge(ys)
            probes.add_mean("clip_act", 0.5, 2.0)
            c = probes._COLLECTOR
            assert float(c.sums["pre"]) == 1.0
            assert float(c.sums["inner"]) == 6.0


# ---------------------------------------------------------------------------
# TrainTracer / atomic heartbeat
# ---------------------------------------------------------------------------


class TestTrainTracer:
    def test_jsonl_round_trip_on_manual_clock(self, tmp_path):
        path = tmp_path / "trace.jsonl"
        clock = ManualClock(start=5.0)
        tracer = TrainTracer(JsonlSink(path), clock=clock)
        tracer.emit("run_start", step=0, arch="t", total_steps=3)
        clock.advance(1.0)
        tracer.emit("step", step=1, loss=2.5, skipme=None)
        tracer.emit("run_end", step=3, recoveries=0)
        tracer.close()
        evs = [json.loads(l) for l in path.read_text().splitlines()]
        assert [e["event"] for e in evs] == ["run_start", "step", "run_end"]
        assert [e["t"] for e in evs] == [5.0, 6.0, 6.0]
        assert evs[0]["arch"] == "t" and evs[0]["total_steps"] == 3
        assert evs[1]["step"] == 1 and "skipme" not in evs[1]  # None dropped
        assert tracer.events == 3


class TestAtomicWrite:
    def test_heartbeat_replaces_atomically(self, tmp_path):
        path = str(tmp_path / "hb")
        _write_atomic(path, "7")
        _write_atomic(path, "8")
        assert open(path).read() == "8"
        leftovers = [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
        assert leftovers == []


# ---------------------------------------------------------------------------
# end-to-end: instrumented Trainer run
# ---------------------------------------------------------------------------


class TestInstrumentedRun:
    def test_probes_trace_history_heartbeat(self, tmp_path, monkeypatch):
        trace = tmp_path / "trace.jsonl"
        hist_path = tmp_path / "history.jsonl"
        hb = tmp_path / "heartbeat"
        tcfg = TrainerConfig(
            total_steps=3, log_every=10, ckpt_every=10**9,
            probes=True, sensitivity_every=2,
            trace_path=str(trace), history_path=str(hist_path),
            heartbeat_path=str(hb),
        )
        tr = Trainer(CFG, tcfg, _data_iter(CFG, 3))
        returned = tr.run()
        # history streamed to JSONL, not held on the host
        assert returned == [] and tr.history == []
        hist = [json.loads(l) for l in hist_path.read_text().splitlines()]
        assert [h["step"] for h in hist] == [0, 1, 2]
        for h in hist:
            for k in ("qat_clip_act", "qat_branch_share8", "qat_flip_attn",
                      "qat_flip_ffn1", "qat_clip_w8", "qat_gnorm_share8",
                      "qat_scale_drift_absmean", "qat_scale_drift_absmax"):
                assert k in h, k
                assert np.isfinite(h[k]), k
            assert 0.0 <= h["qat_clip_act"] <= 1.0
            assert 0.0 <= h["qat_branch_share8"] <= 1.0
        # democratization snapshot at the sensitivity_every cadence only
        assert "demo_score_ffn1" in hist[0] and "demo_score_ffn1" in hist[2]
        assert "demo_score_ffn1" not in hist[1]
        # lifecycle trace: run bracket + one record per step + heartbeat
        evs = [json.loads(l) for l in trace.read_text().splitlines()]
        kinds = [e["event"] for e in evs]
        assert kinds[0] == "run_start" and kinds[-1] == "run_end"
        assert kinds.count("step") == 3
        assert "heartbeat" in kinds  # step 0 hits log_every
        ts = [e["t"] for e in evs]
        assert ts == sorted(ts)
        # crash-atomic heartbeat file holds the last completed step
        assert hb.read_text() == "2"
        assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
        # metrics snapshot: CI schema + the run's counters/gauges
        snap = json.loads(json.dumps(tr.snapshot()))
        validate_snapshot(snap)
        assert snap["counters"]["train_steps_total"] == 3
        assert snap["histograms"]["train_step_seconds"]["count"] == 3
        assert snap["gauges"]["train_step"] == 2
        assert np.isfinite(snap["gauges"]["train_loss"])
        assert "qat_clip_act" in snap["gauges"]
        assert "demo_score_ffn1" in snap["gauges"]
        text = tr.metrics.prometheus_text()
        assert "train_steps_total 3" in text

    def test_recovery_recorded_in_history_trace_metrics(self, tmp_path):
        trace = tmp_path / "trace.jsonl"
        with tempfile.TemporaryDirectory() as d:
            tcfg = TrainerConfig(total_steps=12, ckpt_every=5, ckpt_dir=d,
                                 log_every=1000, trace_path=str(trace))
            tr = Trainer(CFG, tcfg, _data_iter(CFG, 20))
            orig = tr.step_fn
            hits = {"n": 0}

            def poisoned(state, batch):
                state, m = orig(state, batch)
                hits["n"] += 1
                if hits["n"] == 8:  # past the (async) step-5 checkpoint
                    m = dict(m)
                    m["loss"] = jnp.asarray(float("nan"))
                return state, m

            tr.step_fn = poisoned
            hist = tr.run()
            assert tr.recoveries == 1
            recs = [h for h in hist if h.get("event") == "recovery"]
            assert len(recs) == 1
            assert recs[0]["from_step"] == 6 and recs[0]["recoveries"] == 1
            evs = [json.loads(l) for l in trace.read_text().splitlines()]
            kinds = [e["event"] for e in evs]
            assert "restore" in kinds and "recovery" in kinds
            rec_ev = next(e for e in evs if e["event"] == "recovery")
            assert rec_ev["from_step"] == 6 and rec_ev["recoveries"] == 1
            snap = tr.snapshot()
            assert snap["counters"]["train_recoveries_total"] == 1
            assert snap["counters"]["train_restores_total"] == 1
            assert snap["counters"]["train_checkpoints_total"] >= 2

    def test_probe_metrics_finite_for_baselines(self):
        """bitnet (no 8-bit branch) and fp (no quantizers) emit their
        reduced probe sets without error."""
        for mode, expect, absent in (
            ("bitnet", ("qat_flip_ffn1", "qat_clip_act"), ("qat_clip_w8",)),
            ("none", ("qat_flip_ffn1",), ("qat_clip_act", "qat_clip_w8")),
        ):
            qc = QuantConfig(mode=mode, r=0, num_experts=1)
            cfg = ModelConfig(name=f"t-{mode}", family="decoder", n_layers=1,
                              d_model=32, n_heads=4, n_kv_heads=2, d_ff=48,
                              vocab_size=64, quant=qc)
            state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
            step = jax.jit(make_train_step(cfg, 10, probes=True))
            _, metrics = step(state, _batch(cfg))
            for k in expect:
                assert k in metrics and np.isfinite(float(metrics[k])), (mode, k)
            for k in absent:
                assert k not in metrics, (mode, k)


# ---------------------------------------------------------------------------
# smoke artifacts (the pair CI validates and archives)
# ---------------------------------------------------------------------------


class TestBenchArtifacts:
    def test_stability_smoke_emits_validated_artifacts(self, tmp_path):
        from benchmarks import bench_stability

        metrics_out = tmp_path / "BENCH_train_metrics.json"
        trace_out = tmp_path / "BENCH_train_trace.jsonl"
        out = bench_stability.run(steps=4, smoke=True,
                                  metrics_out=str(metrics_out),
                                  trace_out=str(trace_out))
        assert set(out) == {"bitnet", "pquant"}
        snap = json.load(open(metrics_out))
        validate_snapshot(snap)
        assert snap["counters"]["train_steps_total"] > 0
        assert any(k.startswith("qat_") for k in snap["gauges"])
        evs = [json.loads(l) for l in trace_out.read_text().splitlines()]
        kinds = {e["event"] for e in evs}
        assert {"run_start", "step", "run_end"} <= kinds
