"""End-to-end system behaviour: the paper's qualitative claims at CPU scale
plus trainer fault-tolerance paths."""

import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.configs.registry import get_config, reduced
from repro.core.quantization import QuantConfig
from repro.data.pipeline import DataConfig, SyntheticSource, host_batch
from repro.train.trainer import (
    Trainer,
    TrainerConfig,
    init_train_state,
    make_train_step,
)


def _tiny(quant_mode="pquant", n_experts=1, **kw):
    qc = QuantConfig(
        mode=quant_mode,
        r=16 if quant_mode == "pquant" else 0,
        num_experts=n_experts,
    )
    base = dict(
        name=f"tiny-{quant_mode}", family="decoder", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256, quant=qc,
        max_seq_len=64,
    )
    base.update(kw)
    return ModelConfig(**base)


def _data_iter(cfg, steps, seq=32, batch=8, seed=0):
    src = SyntheticSource(cfg.vocab_size, seed=seed)
    dcfg = DataConfig(seq_len=seq, global_batch=batch, seed=seed)
    for s in range(steps + 1):
        yield s, host_batch(src, dcfg, s)


def _train(cfg, steps=60, **tkw):
    tcfg = TrainerConfig(total_steps=steps, log_every=1000, ckpt_every=10**9, **tkw)
    tr = Trainer(cfg, tcfg, _data_iter(cfg, steps))
    hist = tr.run()
    return hist, tr


class TestLearning:
    def test_pquant_learns(self):
        hist, _ = _train(_tiny("pquant"))
        first = np.mean([h["nll"] for h in hist[:5]])
        last = np.mean([h["nll"] for h in hist[-5:]])
        assert last < first - 0.3, (first, last)

    def test_all_modes_learn(self):
        for mode in ("none", "bitnet", "bitnet158"):
            hist, _ = _train(_tiny(mode), steps=40)
            assert hist[-1]["nll"] < hist[0]["nll"], mode


@pytest.mark.slow
class TestPaperClaims:
    """Scaled-down analogues of the paper's quantitative claims.

    SCALE NOTE (recorded in EXPERIMENTS.md §Paper-claims): the paper's
    quality advantage is demonstrated at 300M-2.6B params / 100B tokens.
    At this harness's CPU scale (2 layers, d=64, <200 steps) the measured
    deltas are ~0.08 NLL with BitNet slightly ahead across seeds — the
    decoupled branch needs training scale to pay off (its mechanism, the
    sensitivity differentiation, IS confirmed at this scale: see
    bench_sensitivity).  These tests therefore assert a PARITY BAND
    (pQuant within 0.15 NLL of the comparison), which catches real
    regressions (broken STE, dead branches, routing bugs all blow the
    band) without overclaiming scale effects CPU cannot reproduce.
    """

    def test_pquant_tracks_bitnet(self):
        """Table 2 (parity band at CPU scale, see class docstring)."""
        h_pq, _ = _train(_tiny("pquant"), steps=80)
        h_bn, _ = _train(_tiny("bitnet"), steps=80)
        pq = np.mean([h["nll"] for h in h_pq[-10:]])
        bn = np.mean([h["nll"] for h in h_bn[-10:]])
        assert pq < bn + 0.15, (pq, bn)

    def test_feature_scaling_band(self):
        """§4.6 ablation (parity band at CPU scale, see class docstring)."""
        good = _tiny("pquant")
        bad = dataclasses.replace(
            good, quant=dataclasses.replace(good.quant, alpha_init=0.2,
                                            beta_init=0.2),
        )
        h_good, _ = _train(good, steps=80)
        h_bad, _ = _train(bad, steps=80)
        g = np.mean([h["nll"] for h in h_good[-10:]])
        b = np.mean([h["nll"] for h in h_bad[-10:]])
        assert g < b + 0.15, (g, b)


class TestFaultTolerance:
    def test_resume_from_checkpoint(self):
        cfg = _tiny("pquant")
        with tempfile.TemporaryDirectory() as d:
            tcfg = TrainerConfig(total_steps=20, ckpt_every=10, ckpt_dir=d,
                                 log_every=1000)
            tr = Trainer(cfg, tcfg, _data_iter(cfg, 20))
            tr.run()
            # "crash" and restart: new Trainer resumes past step 0
            tcfg2 = TrainerConfig(total_steps=30, ckpt_every=10, ckpt_dir=d,
                                  log_every=1000)
            tr2 = Trainer(cfg, tcfg2, _data_iter(cfg, 30))
            assert tr2.start_step >= 10
            hist = tr2.run()
            assert hist[0]["step"] >= 10

    def test_elastic_restore_changes_nothing_numerically(self):
        """Checkpoint stores logical arrays; restore works regardless of
        sharding (single device here, multi-device covered in
        test_distributed)."""
        cfg = _tiny("pquant")
        state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
        with tempfile.TemporaryDirectory() as d:
            from repro.checkpoint.checkpointer import Checkpointer

            ck = Checkpointer(d)
            ck.save(3, state._asdict(), blocking=True)
            out = ck.restore(state._asdict())
            a = jax.tree.leaves(state.params)[0]
            b = jax.tree.leaves(out["params"])[0]
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_auto_recovery_on_nan(self):
        """Trainer reloads the last checkpoint when loss goes non-finite
        (paper Fig. 10 behaviour: BitNet divergence -> rollback)."""
        cfg = _tiny("pquant")
        with tempfile.TemporaryDirectory() as d:
            tcfg = TrainerConfig(total_steps=12, ckpt_every=5, ckpt_dir=d,
                                 log_every=1000)
            tr = Trainer(cfg, tcfg, _data_iter(cfg, 30))
            orig = tr.step_fn
            hits = {"n": 0}

            def poisoned(state, batch):
                state, m = orig(state, batch)
                hits["n"] += 1
                if hits["n"] == 8:  # one divergence event
                    m = dict(m)
                    m["loss"] = jnp.asarray(float("nan"))
                return state, m

            tr.step_fn = poisoned
            hist = tr.run()
            assert tr.recoveries == 1
            steps = [h for h in hist if "event" not in h]
            assert all(np.isfinite(h["loss"]) for h in steps)
            # the rollback is recorded, not silent (PR 10): the history
            # carries a recovery event with the restored-from step
            recs = [h for h in hist if h.get("event") == "recovery"]
            assert len(recs) == 1
            assert recs[0]["from_step"] == 6 and recs[0]["recoveries"] == 1


class TestGradAccum:
    def test_accum_matches_full_batch(self):
        cfg = _tiny("pquant")
        state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
        src = SyntheticSource(cfg.vocab_size, seed=0)
        batch = {
            k: jnp.asarray(v)
            for k, v in host_batch(src, DataConfig(seq_len=16, global_batch=8), 0).items()
        }
        s1, m1 = jax.jit(make_train_step(cfg, 10, accum=1))(state, batch)
        s2, m2 = jax.jit(make_train_step(cfg, 10, accum=4))(state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-3)
        w1 = jax.tree.leaves(s1.params)[0]
        w2 = jax.tree.leaves(s2.params)[0]
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=2e-2, atol=1e-5)
