"""Compiled decode engine: scan/loop equivalence, the single host-transfer
invariant, streaming, and the (B, V) logits contract."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig
from repro.models import api
from repro.serve.engine import DecodeEngine, SamplerConfig
from repro.train.serve import BatchedServer, make_serve_step

KEY = jax.random.PRNGKey(1)
CFG = ModelConfig(name="t", family="decoder", n_layers=3, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64,
                  quant=QuantConfig(mode="pquant", r=16, num_experts=1))


@pytest.fixture(scope="module")
def server():
    params, _ = api.init_model(KEY, CFG)
    return BatchedServer(params, CFG, max_len=32)


@pytest.fixture(scope="module")
def prompts():
    return jax.random.randint(KEY, (3, 6), 0, CFG.vocab_size).astype(jnp.int32)


def test_greedy_engine_matches_python_loop(server, prompts):
    """Bit-for-bit: lax.scan engine == legacy per-token loop at temp 0."""
    scfg = SamplerConfig(max_new_tokens=7, temperature=0.0)
    loop = server.generate_python_loop(prompts, scfg)
    engine = server.generate(prompts, scfg)
    np.testing.assert_array_equal(loop, engine)


def test_sampled_engine_matches_python_loop(server, prompts):
    """The key-split order matches too, so sampled paths agree per seed."""
    scfg = SamplerConfig(max_new_tokens=5, temperature=0.7, top_k=10)
    loop = server.generate_python_loop(prompts, scfg, seed=3)
    engine = server.generate(prompts, scfg, seed=3)
    np.testing.assert_array_equal(loop, engine)


def test_single_host_transfer_per_generate(server, prompts):
    scfg = SamplerConfig(max_new_tokens=4, temperature=0.0)
    before = server.engine.host_transfers
    out = server.generate(prompts, scfg)
    assert server.engine.host_transfers - before == 1
    assert out.shape == (3, 4)


def test_stream_matches_generate(server, prompts):
    scfg = SamplerConfig(max_new_tokens=7, temperature=0.0)
    want = server.generate(prompts, scfg)
    chunks = list(server.generate_stream(prompts, scfg, chunk=3))
    assert [c.shape[1] for c in chunks] == [4, 3]  # 1 + chunk, then chunk
    np.testing.assert_array_equal(np.concatenate(chunks, axis=1), want)


def test_single_token_budget(server, prompts):
    scfg = SamplerConfig(max_new_tokens=1, temperature=0.0)
    out = server.generate(prompts, scfg)
    assert out.shape == (3, 1)
    chunks = list(server.generate_stream(prompts, scfg))
    np.testing.assert_array_equal(np.concatenate(chunks, axis=1), out)


def test_engine_standalone_api(prompts):
    """DecodeEngine is usable without the BatchedServer wrapper."""
    params, _ = api.init_model(KEY, CFG)
    eng = DecodeEngine(params, CFG, max_len=32)
    out = eng.generate(prompts, SamplerConfig(max_new_tokens=3,
                                              temperature=0.0))
    assert out.shape == (3, 3)
    assert (out >= 0).all() and (out < CFG.vocab_size).all()


def test_stream_early_exits_on_stop_tokens(server, prompts):
    """Once every sequence has produced a stop token, the chunk loop ends:
    fewer yields, fewer transfers — and the done mask rides the existing
    per-chunk transfer (still exactly one fetch per chunk)."""
    base = SamplerConfig(max_new_tokens=12, temperature=0.0)
    full = server.generate(prompts, base)
    # every row has emitted one of these by step 3 -> all-done after chunk 1
    stops = tuple(int(t) for t in np.unique(full[:, :3]))
    scfg = SamplerConfig(max_new_tokens=12, temperature=0.0,
                         stop_tokens=stops)
    before = server.engine.host_transfers
    chunks = list(server.generate_stream(prompts, scfg, chunk=3))
    assert len(chunks) == 1  # early exit: 1 chunk instead of 4
    assert server.engine.host_transfers - before == 1
    # the emitted prefix is untruncated generate output (truncation at the
    # stop token itself is caller policy)
    np.testing.assert_array_equal(chunks[0], full[:, :4])


def test_stream_without_stop_tokens_runs_full_budget(server, prompts):
    """No stop tokens -> behavior unchanged: all chunks, full budget."""
    scfg = SamplerConfig(max_new_tokens=12, temperature=0.0)
    chunks = list(server.generate_stream(prompts, scfg, chunk=3))
    assert [c.shape[1] for c in chunks] == [4, 3, 3, 2]


def test_sampler_config_not_shared_mutable_default():
    """Regression: the old ``scfg: SamplerConfig = SamplerConfig()``
    default was a single shared instance across all calls."""
    import inspect

    from repro.serve.scheduler import ContinuousBatchingEngine

    for fn in (
        DecodeEngine.generate,
        DecodeEngine.generate_stream,
        BatchedServer.generate,
        BatchedServer.generate_stream,
        BatchedServer.generate_python_loop,
        ContinuousBatchingEngine.__init__,
    ):
        default = inspect.signature(fn).parameters["scfg"].default
        assert default is None, f"{fn.__qualname__} shares a SamplerConfig"
    # and the config itself is now immutable, killing the bug class
    with pytest.raises(dataclasses.FrozenInstanceError):
        SamplerConfig().temperature = 0.1


def test_serve_step_logits_contract():
    """make_serve_step surfaces (B, V) next-token logits — same contract as
    prefill, so samplers never branch on step index."""
    params, _ = api.init_model(KEY, CFG)
    toks = jax.random.randint(KEY, (2, 5), 0, CFG.vocab_size)
    logits_p, caches = api.prefill(params, {"tokens": toks}, CFG, cache_len=16)
    step = make_serve_step(CFG)
    logits_d, _ = step(params, toks[:, -1:], caches,
                       jnp.asarray(5, jnp.int32))
    assert logits_p.shape == (2, CFG.vocab_size)
    assert logits_d.shape == (2, CFG.vocab_size)
