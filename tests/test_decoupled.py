"""Decoupled linear layer invariants (paper §3.2-3.3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decoupled import (
    decoupled_ffn,
    decoupled_param_counts,
    decoupled_proj,
    init_decoupled_ffn,
    init_decoupled_proj,
    set_feature_scaling,
)
from repro.core.quantization import QuantConfig
from repro.core.routing import RouterConfig

KEY = jax.random.PRNGKey(0)


def _x(b=2, s=8, d=32):
    return jax.random.normal(jax.random.PRNGKey(7), (b, s, d))


class TestStructure:
    def test_r0_has_no_8bit_branch(self):
        p, _ = init_decoupled_ffn(KEY, 32, 64, 0)
        assert "w8_up" not in p and "alpha" not in p

    def test_dff0_is_pure_8bit(self):
        p, _ = init_decoupled_ffn(KEY, 32, 0, 16)
        assert "w1_up" not in p and "w8_up" in p

    def test_router_only_when_multi_expert(self):
        p1, _ = init_decoupled_ffn(KEY, 32, 64, 16, num_experts=1)
        p4, _ = init_decoupled_ffn(KEY, 32, 64, 16, num_experts=4)
        assert "router" not in p1 and "router" in p4

    def test_param_counts(self):
        n1, n8 = decoupled_param_counts(32, 64, 16, 4, glu=True)
        assert n1 == 3 * 32 * 64
        assert n8 == 3 * 32 * 16 * 4


class TestForward:
    def test_output_finite_all_modes(self):
        x = _x()
        for mode in ("none", "bitnet", "bitnet158", "pquant"):
            qc = QuantConfig(mode=mode, r=16 if mode == "pquant" else 0)
            p, _ = init_decoupled_ffn(KEY, 32, 64, qc.r)
            y, aux = decoupled_ffn(p, x, qc)
            assert y.shape == x.shape
            assert np.isfinite(np.asarray(y)).all(), mode

    def test_feature_scaling_scales_8bit_branch(self):
        """alpha multiplies the 8-bit output exactly (Eq. 11 linearity)."""
        qc = QuantConfig(mode="pquant", r=16)
        x = _x()
        p, _ = init_decoupled_ffn(KEY, 32, 0, 16)  # pure 8-bit branch
        y1, _ = decoupled_ffn(set_feature_scaling(dict(p), 1.0, 0.2), x, qc)
        y2, _ = decoupled_ffn(set_feature_scaling(dict(p), 2.0, 0.2), x, qc)
        np.testing.assert_allclose(
            np.asarray(y2), 2 * np.asarray(y1), rtol=1e-4, atol=1e-5
        )

    def test_branch_sum(self):
        """Full output == beta*branch1 + alpha*branch8 (paper Eq. 11)."""
        qc = QuantConfig(mode="pquant", r=16)
        p, _ = init_decoupled_ffn(KEY, 32, 64, 16, alpha_init=2.0, beta_init=0.2)
        x = _x()
        y, _ = decoupled_ffn(p, x, qc)
        p1 = {k: v for k, v in p.items() if not k.startswith("w8") and k not in ("alpha", "beta")}
        y1, _ = decoupled_ffn(p1, x, qc)  # beta defaults to 1 w/o 8-bit
        p8 = {k: v for k, v in p.items() if not k.startswith("w1")}
        p8 = set_feature_scaling(dict(p8), 1.0, 0.0)
        y8, _ = decoupled_ffn(p8, x, qc)
        np.testing.assert_allclose(
            np.asarray(y), 0.2 * np.asarray(y1) + 2.0 * np.asarray(y8),
            rtol=1e-3, atol=1e-4,
        )

    def test_routed_aux_loss_nonzero(self):
        qc = QuantConfig(mode="pquant", r=16, num_experts=4)
        p, _ = init_decoupled_ffn(KEY, 32, 64, 16, num_experts=4)
        y, aux = decoupled_ffn(
            p, _x(), qc, router_cfg=RouterConfig(num_experts=4, top_k=1)
        )
        assert float(aux) > 0

    def test_gradients_reach_every_param(self):
        qc = QuantConfig(mode="pquant", r=16, num_experts=2)
        p, _ = init_decoupled_ffn(KEY, 32, 64, 16, num_experts=2)
        x = _x()

        def loss(p):
            y, aux = decoupled_ffn(
                p, x, qc, router_cfg=RouterConfig(num_experts=2, top_k=1)
            )
            return jnp.mean(y**2) + aux

        g = jax.grad(loss)(p)
        for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
            assert np.isfinite(np.asarray(leaf)).all(), path
            assert float(jnp.abs(leaf).sum()) > 0, f"dead gradient at {path}"

    def test_alpha_gradient_dominates_beta_at_init(self):
        """alpha >> beta init biases gradient flow to the 8-bit branch —
        the mechanism the paper relies on (§3.2)."""
        qc = QuantConfig(mode="pquant", r=32)
        p, _ = init_decoupled_ffn(KEY, 32, 64, 32, alpha_init=2.0, beta_init=0.2)
        x = _x()

        def loss(p):
            y, _ = decoupled_ffn(p, x, qc)
            return jnp.mean(y**2)

        g = jax.grad(loss)(p)
        g8 = float(jnp.abs(g["w8_up"]).mean())
        g1 = float(jnp.abs(g["w1_up"]).mean())
        assert g8 > g1  # stronger feedback into the high-precision branch


class TestDecoupledProj:
    def test_forward_and_grads(self):
        qc = QuantConfig(mode="pquant", r=8)
        p, a = init_decoupled_proj(KEY, 32, 48, 8)
        x = _x()
        y, aux = decoupled_proj(p, x, qc)
        assert y.shape == (2, 8, 48)
        g = jax.grad(lambda p: jnp.mean(decoupled_proj(p, x, qc)[0] ** 2))(p)
        assert float(jnp.abs(g["w8_a"]).sum()) > 0

    def test_routed(self):
        qc = QuantConfig(mode="pquant", r=8, num_experts=4)
        p, _ = init_decoupled_proj(KEY, 32, 48, 8, num_experts=4)
        y, aux = decoupled_proj(
            p, _x(), qc, router_cfg=RouterConfig(num_experts=4, top_k=1)
        )
        assert np.isfinite(np.asarray(y)).all()
