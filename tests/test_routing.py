"""Sort-based top-k dispatch properties."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package"
)
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.routing import (
    RouterConfig,
    combine_scatter,
    dispatch_gather,
    expert_capacity,
    route_and_apply,
    init_router,
    topk_dispatch,
)


def _probs(t, n, seed=0):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (t, n))
    return jax.nn.softmax(logits, -1)


class TestDispatch:
    def test_identity_roundtrip(self):
        """gather->identity->scatter with weight 1 reproduces kept tokens."""
        t, n, d = 32, 4, 8
        cfg = RouterConfig(num_experts=n, top_k=1, capacity_factor=4.0)
        probs = _probs(t, n)
        disp = topk_dispatch(probs, cfg)
        disp["combine_weight"] = (disp["combine_weight"] > 0).astype(jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (t, d))
        xe = dispatch_gather(x, disp)
        y = combine_scatter(xe, disp, t)
        # with generous capacity nothing is dropped
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)

    def test_no_slot_collisions(self):
        t, n = 64, 8
        cfg = RouterConfig(num_experts=n, top_k=2, capacity_factor=2.0)
        disp = topk_dispatch(_probs(t, n), cfg)
        buf = np.asarray(disp["buffer_token"])
        used = buf[buf < t]
        # each expert slot holds at most one (token, slot) pair
        pairs = [(e, s) for e in range(n) for s in range(buf.shape[1]) if buf[e, s] < t]
        assert len(pairs) == len(set(pairs))

    def test_capacity_drops_lowest_ranked(self):
        t, n = 64, 2
        cfg = RouterConfig(num_experts=n, top_k=1, capacity_factor=0.25)
        disp = topk_dispatch(_probs(t, n), cfg)
        kept = (np.asarray(disp["combine_weight"]) > 0).sum()
        cap = expert_capacity(t, cfg)
        assert kept <= n * cap

    @hypothesis.settings(max_examples=20, deadline=None)
    @hypothesis.given(
        t=st.sampled_from([16, 33, 64]),
        n=st.sampled_from([2, 4, 7]),
        k=st.sampled_from([1, 2]),
        seed=st.integers(0, 5),
    )
    def test_property_combine_weights_valid(self, t, n, k, seed):
        hypothesis.assume(k <= n)
        cfg = RouterConfig(num_experts=n, top_k=k)
        disp = topk_dispatch(_probs(t, n, seed), cfg)
        cw = np.asarray(disp["combine_weight"])
        assert (cw >= 0).all() and (cw <= 1.0 + 1e-6).all()
        ei = np.asarray(disp["expert_index"])
        assert (ei >= 0).all() and (ei < n).all()

    def test_route_and_apply_shapes(self):
        t, n, d = 40, 4, 16
        rp, _ = init_router(jax.random.PRNGKey(0), d, RouterConfig(num_experts=n))
        x = jax.random.normal(jax.random.PRNGKey(1), (t, d))
        y, aux = route_and_apply(
            rp, x, RouterConfig(num_experts=n, top_k=1), lambda xe: xe * 2.0
        )
        assert y.shape == (t, d)
        assert np.isfinite(float(aux))
