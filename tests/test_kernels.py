"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernel body executes on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import export_bit_weight, pack_signs
from repro.kernels import ops, ref
from repro.kernels.decoupled_matmul import decoupled_matmul
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.rmsnorm_quant import rmsnorm_quant
from repro.kernels.w1a8_matmul import w1a8_matmul

RNG = np.random.default_rng(0)


def _inputs(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(-127, 128, (m, k)).astype(np.int8)
    signs = np.where(rng.random((k, n)) > 0.5, 1, -1).astype(np.int8)
    wp = np.asarray(pack_signs(jnp.asarray(signs)))
    gamma = (rng.random(m) + 0.5).astype(np.float32)
    lam = np.float32(0.042)
    return jnp.asarray(x), jnp.asarray(wp), jnp.asarray(gamma), jnp.asarray(lam)


W1A8_CASES = [
    # (m, k, n, bm, bk, bn)
    (8, 16, 8, 8, 8, 8),
    (8, 256, 128, 8, 128, 128),
    (128, 256, 256, 128, 256, 256),
    (64, 512, 128, 32, 256, 128),
    (256, 1024, 512, 128, 512, 256),
    (16, 128, 384, 8, 64, 128),
]


@pytest.mark.parametrize("m,k,n,bm,bk,bn", W1A8_CASES)
def test_w1a8_vs_ref(m, k, n, bm, bk, bn):
    x, wp, gamma, lam = _inputs(m, k, n, seed=m + k + n)
    got = w1a8_matmul(x, wp, gamma, lam, bm=bm, bk=bk, bn=bn, interpret=True)
    want = ref.w1a8_matmul_ref(x, wp, gamma, lam)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_w1a8_out_dtypes(out_dtype):
    x, wp, gamma, lam = _inputs(16, 64, 32)
    got = w1a8_matmul(x, wp, gamma, lam, bm=8, bk=32, bn=32,
                      out_dtype=out_dtype, interpret=True)
    want = ref.w1a8_matmul_ref(x, wp, gamma, lam, out_dtype=out_dtype)
    assert got.dtype == out_dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=1e-2
    )


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (128, 256, 128), (32, 512, 256)])
def test_int8_vs_ref(m, k, n):
    rng = np.random.default_rng(m + n)
    x = jnp.asarray(rng.integers(-127, 128, (m, k)).astype(np.int8))
    w = jnp.asarray(rng.integers(-127, 128, (k, n)).astype(np.int8))
    gamma = jnp.asarray((rng.random(m) + 0.5).astype(np.float32))
    ws = jnp.asarray(np.float32(3.7))
    got = int8_matmul(x, w, gamma, ws, bm=min(128, m), bk=min(256, k),
                      bn=min(256, n), interpret=True)
    want = ref.int8_matmul_ref(x, w, gamma, ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("m,d", [(8, 64), (256, 128), (32, 512), (64, 96)])
def test_rmsnorm_quant_vs_ref(m, d):
    rng = np.random.default_rng(m + d)
    x = jnp.asarray(rng.standard_normal((m, d)).astype(np.float32))
    sc = jnp.asarray((rng.random(d) + 0.5).astype(np.float32))
    q, g = rmsnorm_quant(x, sc, bm=min(256, m), interpret=True)
    qr, gr = ref.rmsnorm_quant_ref(x, sc)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=1e-5)
    # rounding at exactly .5 may differ by 1 ulp between paths
    assert (np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32)) <= 1).all()


@pytest.mark.parametrize("m,k,n,r", [(8, 16, 16, 8), (64, 256, 512, 128), (16, 512, 256, 64)])
def test_decoupled_vs_ref(m, k, n, r):
    x, wp, gamma, lam = _inputs(m, k, n, seed=r)
    rng = np.random.default_rng(r)
    w8 = jnp.asarray(rng.integers(-127, 128, (k, r)).astype(np.int8))
    w8s, alpha, beta = (jnp.asarray(np.float32(v)) for v in (2.1, 2.0, 0.2))
    y1, y8 = decoupled_matmul(
        x, wp, w8, gamma, lam, w8s, alpha, beta,
        bm=min(128, m), bk=min(256, k), bn=max(min(256, n), r), interpret=True,
    )
    r1, r8 = ref.decoupled_matmul_ref(x, wp, w8, gamma, lam, w8s, alpha, beta)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(r1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(r8), rtol=1e-5)


class TestOpsEndToEnd:
    def test_bit_linear_infer_matches_fake_quant(self):
        """The true-integer serving path equals the dequantized matmul."""
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((5, 256)).astype(np.float32) * 0.4)
        w = jnp.asarray(rng.standard_normal((256, 128)).astype(np.float32) * 0.03)
        pw = export_bit_weight(w)
        y = ops.bit_linear_infer(x, pw.packed, pw.lam, out_dtype=jnp.float32)
        yref = jnp.asarray(x) @ pw.dequantize()
        rel = np.abs(np.asarray(y) - np.asarray(yref)).max() / (
            np.abs(np.asarray(yref)).max() + 1e-9
        )
        assert rel < 2e-2  # activation-quant noise only

    def test_ragged_rows_padded(self):
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.standard_normal((3, 64)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32) * 0.1)
        pw = export_bit_weight(w)
        y = ops.bit_linear_infer(x, pw.packed, pw.lam)
        assert y.shape == (3, 32)
        assert np.isfinite(np.asarray(y, np.float32)).all()

    def test_fused_rmsnorm_quant_3d(self):
        x = jnp.asarray(np.random.default_rng(5).standard_normal((2, 7, 64)), jnp.float32)
        sc = jnp.ones((64,), jnp.float32)
        q, g = ops.fused_rmsnorm_quant(x, sc)
        assert q.shape == x.shape and g.shape == (2, 7)
