"""Decode-tile autotune persistence: swept winners survive a (simulated)
process restart via the per-backend JSON cache."""

import json

import pytest

from repro.kernels import ops, tile_cache


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """Route the on-disk cache into a tmpdir and reset ops' in-process
    state around each test (conftest disables persistence globally)."""
    monkeypatch.setenv("REPRO_TILE_CACHE", "1")
    monkeypatch.setenv("REPRO_TILE_CACHE_DIR", str(tmp_path))
    saved = dict(ops._DECODE_TILE_CACHE)
    saved_loaded = ops._TILE_CACHE_LOADED
    ops._DECODE_TILE_CACHE.clear()
    ops._TILE_CACHE_LOADED = False
    yield tmp_path
    ops._DECODE_TILE_CACHE.clear()
    ops._DECODE_TILE_CACHE.update(saved)
    ops._TILE_CACHE_LOADED = saved_loaded


def test_store_load_roundtrip(tmp_cache):
    table = {("w1a8_gemv", 8, 64, 32): (16, 32),
             ("decoupled_gemv", 8, 64, 32, 16): (64, 16)}
    tile_cache.store("cpu", table)
    assert tile_cache.load("cpu") == table
    # per-backend files are independent
    assert tile_cache.load("tpu") == {}


def test_variable_arity_kernel_families_share_one_file(tmp_cache):
    """Keys and values are variable-arity int tuples: the paged-attention
    family's 7-part key / 1-tuple winner coexists with the GEMV 2-tuples
    in the same per-backend file."""
    table = {
        ("w1a8_gemv", 8, 64, 32): (16, 32),
        ("paged_attn", 1, 4, 2, 64, 16, 8): (4,),
    }
    tile_cache.store("cpu", table)
    assert tile_cache.load("cpu") == table


def test_store_merges_with_existing(tmp_cache):
    tile_cache.store("cpu", {("w1a8_gemv", 8, 64, 32): (16, 32)})
    tile_cache.store("cpu", {("w1a8_gemv", 8, 128, 32): (32, 32)})
    assert len(tile_cache.load("cpu")) == 2


def test_wrong_arity_entries_dropped_not_crashing(tmp_cache):
    """A valid-JSON cache with family-impossible value arity (a truncated
    GEMV pair, an empty paged winner) must load as if those entries were
    absent — dispatch unpacks the tuples, so letting them through would
    crash inference instead of falling back to the heuristic."""
    import json as _json

    path = tile_cache.cache_path("cpu")
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(_json.dumps({
        "w1a8_gemv|8|64|32": [16],          # GEMV needs exactly (bk, bn)
        "w1a8_gemv|8|64|64": [16, 32, 4],   # over-long unpacks wrong too
        "paged_attn|1|4|2|64|16|8": [],     # paged needs exactly (pages,)
        "w1a8_gemv|8|128|32": [32, 32],     # fine
        "paged_attn|1|4|2|64|16|4": [2],    # fine
        "decoupled_gemv|8|64|32|bad": [64, 16],  # non-int key part
    }))
    assert tile_cache.load("cpu") == {
        ("w1a8_gemv", 8, 128, 32): (32, 32),
        ("paged_attn", 1, 4, 2, 64, 16, 4): (2,),
    }


def test_corrupt_file_is_ignored(tmp_cache):
    path = tile_cache.cache_path("cpu")
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text("{not json")
    assert tile_cache.load("cpu") == {}
    # and storing over it recovers
    tile_cache.store("cpu", {("w1a8_gemv", 8, 64, 32): (16, 32)})
    assert len(tile_cache.load("cpu")) == 1


def test_disabled_by_env(tmp_cache, monkeypatch):
    monkeypatch.setenv("REPRO_TILE_CACHE", "0")
    tile_cache.store("cpu", {("w1a8_gemv", 8, 64, 32): (16, 32)})
    assert not tile_cache.cache_path("cpu").exists()
    assert tile_cache.load("cpu") == {}


def test_sweep_winner_survives_restart(tmp_cache):
    """sweep -> winner on disk; clearing the in-process table (a process
    restart) and asking decode_tiles finds the persisted winner instead of
    the divisor heuristic default."""
    m, k, n = 1, 16, 16
    best = ops.sweep_decode_tiles(
        m, k, n, bk_candidates=(8, 16), bn_candidates=(8, 16),
        warmup=0, iters=1,
    )
    key = ("w1a8_gemv", m + (-m) % 8, k, n)
    on_disk = tile_cache.load("cpu")
    assert on_disk[key] == tuple(best)
    # simulated restart
    ops._DECODE_TILE_CACHE.clear()
    ops._TILE_CACHE_LOADED = False
    assert ops.decode_tiles(m + (-m) % 8, k, n) == tuple(best)
    payload = json.loads(tile_cache.cache_path("cpu").read_text())
    assert f"w1a8_gemv|{m + (-m) % 8}|{k}|{n}" in payload
