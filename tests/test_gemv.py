"""Decode GEMV tier vs references: fused-act-quant kernels in interpret
mode against the quantize_act + matmul oracle path, ragged-M dispatch
through ops, and the tile dispatch/autotune table."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import export_bit_weight, pack_signs
from repro.kernels import ops, ref
from repro.kernels.w1a8_gemv import decoupled_gemv, w1a8_gemv

TOL = 1e-4  # acceptance: max abs error vs the reference path


def _inputs(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    signs = np.where(rng.random((k, n)) > 0.5, 1, -1).astype(np.int8)
    wp = pack_signs(jnp.asarray(signs))
    lam = jnp.asarray(np.float32(0.042))
    return x, wp, lam


GEMV_CASES = [
    # (m, k, n, bk, bn)
    (8, 64, 64, 32, 32),
    (8, 256, 512, 128, 256),
    (16, 512, 256, 512, 128),
    (32, 128, 384, 64, 128),
    (8, 256, 512, 256, 512),  # single-tile N and K
]


@pytest.mark.parametrize("m,k,n,bk,bn", GEMV_CASES)
def test_w1a8_gemv_vs_ref(m, k, n, bk, bn):
    x, wp, lam = _inputs(m, k, n, seed=m + k + n)
    got = w1a8_gemv(x, wp, lam, bk=bk, bn=bn, interpret=True)
    want = ref.w1a8_gemv_ref(x, wp, lam)
    assert np.abs(np.asarray(got) - np.asarray(want)).max() <= TOL


def test_w1a8_gemv_fused_quant_matches_xla_pass():
    """The in-kernel prologue quantization equals the separate XLA pass +
    prefill kernel route on the same inputs."""
    x, wp, lam = _inputs(8, 256, 256, seed=7)
    got = w1a8_gemv(x, wp, lam, bk=128, bn=128, interpret=True)
    xq, gamma = ops.quantize_act_int8(x)
    want = ref.w1a8_matmul_ref(xq, wp, gamma, lam)
    assert np.abs(np.asarray(got) - np.asarray(want)).max() <= TOL


@pytest.mark.parametrize("m", [1, 3, 17])
def test_decode_dispatch_ragged_m(m):
    """ops.bit_linear_infer routes M <= 32 to the GEMV tier; ragged rows are
    padded to the 8-row sublane minimum, never to 128."""
    x, wp, lam = _inputs(m, 64, 96, seed=m)
    y = ops.bit_linear_infer(x, wp, lam, out_dtype=jnp.float32)
    want = ref.w1a8_gemv_ref(x, wp, lam)
    assert y.shape == (m, 96)
    assert np.isfinite(np.asarray(y)).all()
    assert np.abs(np.asarray(y) - np.asarray(want)).max() <= TOL


def test_decode_and_prefill_tiers_agree():
    """Both ops tiers compute the same linear for a decode shape."""
    x, wp, lam = _inputs(4, 128, 64, seed=11)
    y_dec = ops._bit_linear_decode(x, wp, lam, jnp.float32)
    y_pre = ops._bit_linear_prefill(x, wp, lam, jnp.float32)
    assert np.abs(np.asarray(y_dec) - np.asarray(y_pre)).max() <= TOL


@pytest.mark.parametrize("m,k,n,r", [(8, 256, 512, 64), (16, 128, 256, 32)])
def test_decoupled_gemv_vs_ref(m, k, n, r):
    x, wp, lam = _inputs(m, k, n, seed=r)
    rng = np.random.default_rng(r)
    w8 = jnp.asarray(rng.integers(-127, 128, (k, r)).astype(np.int8))
    w8s, alpha, beta = (jnp.asarray(np.float32(v)) for v in (2.1, 0.05, 0.2))
    y1, y8 = decoupled_gemv(
        x, wp, w8, lam, w8s, alpha, beta, bk=128, bn=128, interpret=True
    )
    r1, r8 = ref.decoupled_gemv_ref(x, wp, w8, lam, w8s, alpha, beta)
    assert np.abs(np.asarray(y1) - np.asarray(r1)).max() <= TOL
    assert np.abs(np.asarray(y8) - np.asarray(r8)).max() <= TOL


@pytest.mark.parametrize("m", [1, 3, 17])
def test_decoupled_dispatch_ragged_m(m):
    k, n, r = 64, 128, 16
    x, wp, lam = _inputs(m, k, n, seed=m + 1)
    rng = np.random.default_rng(m)
    w8 = jnp.asarray(rng.integers(-127, 128, (k, r)).astype(np.int8))
    w8s, alpha, beta = (jnp.asarray(np.float32(v)) for v in (1.7, 0.1, 0.3))
    y1, y8 = ops.decoupled_first_gemm(
        x, wp, w8, lam, w8s, alpha, beta, out_dtype=jnp.float32
    )
    r1, r8 = ref.decoupled_gemv_ref(x, wp, w8, lam, w8s, alpha, beta)
    assert y1.shape == (m, n) and y8.shape == (m, r)
    assert np.abs(np.asarray(y1) - np.asarray(r1)).max() <= TOL
    assert np.abs(np.asarray(y8) - np.asarray(r8)).max() <= TOL


def test_bit_linear_infer_3d_decode_shape():
    """(B, 1, K) decode activations flatten to M = B rows for dispatch."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((4, 1, 64)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32) * 0.1)
    pw = export_bit_weight(w)
    y = ops.bit_linear_infer(x, pw.packed, pw.lam, out_dtype=jnp.float32)
    assert y.shape == (4, 1, 32)
    yref = ref.w1a8_gemv_ref(x.reshape(4, 64), pw.packed, pw.lam)
    assert np.abs(np.asarray(y).reshape(4, 32) - np.asarray(yref)).max() <= TOL


class TestTileDispatch:
    @pytest.fixture(autouse=True)
    def _isolate_tile_cache(self):
        """Sweeps mutate the process-global cache; keep tests order-free."""
        saved = dict(ops._DECODE_TILE_CACHE)
        yield
        ops._DECODE_TILE_CACHE.clear()
        ops._DECODE_TILE_CACHE.update(saved)

    def test_heuristic_divides(self):
        for (m, k, n) in [(8, 64, 96), (8, 4096, 11008), (32, 48, 56)]:
            bk, bn = ops.decode_tiles(m, k, n)
            assert k % bk == 0 and n % bn == 0 and bk % 8 == 0

    def test_sweep_caches_and_wins_are_used(self):
        best = ops.sweep_decode_tiles(8, 64, 64, warmup=0, iters=1)
        assert ops._DECODE_TILE_CACHE[("w1a8_gemv", 8, 64, 64)] == best
        assert ops.decode_tiles(8, 64, 64) == best
        k, n = 64, 64
        bk, bn = best
        assert k % bk == 0 and n % bn == 0
        # the swept signature still computes correctly through the dispatcher
        x, wp, lam = _inputs(8, 64, 64, seed=2)
        y = ops.bit_linear_infer(x, wp, lam, out_dtype=jnp.float32)
        want = ref.w1a8_gemv_ref(x, wp, lam)
        assert np.abs(np.asarray(y) - np.asarray(want)).max() <= TOL

    def test_sweep_pads_m_to_dispatch_shape(self):
        """A sweep for an unpadded batch (e.g. 4) must land on the 8-padded
        signature _bit_linear_decode actually looks up."""
        best = ops.sweep_decode_tiles(4, 64, 32, warmup=0, iters=1)
        assert ("w1a8_gemv", 8, 64, 32) in ops._DECODE_TILE_CACHE
        assert ops.decode_tiles(8, 64, 32) == best

    def test_sweep_decoupled_op(self):
        best = ops.sweep_decode_tiles(
            8, 64, 64, op="decoupled_gemv", r=16, warmup=0, iters=1
        )
        assert ops._DECODE_TILE_CACHE[("decoupled_gemv", 8, 64, 64, 16)] == best
        assert best[1] >= 16  # bn fits the 8-bit branch
        assert ops.decode_tiles(8, 64, 64, op="decoupled_gemv", r=16) == best
        # a different branch width is a different signature, not a hit
        assert ("decoupled_gemv", 8, 64, 64, 32) not in ops._DECODE_TILE_CACHE
