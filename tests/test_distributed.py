"""Distribution tests: sharding rules in-process, plus multi-device tests
(quantized gather, gradient compression, sharded train step) in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 so the
main test process keeps its single CPU device."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


class TestRules:
    def test_logical_to_spec(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with sh.sharding_rules(mesh, None):
            spec = sh.logical_to_spec(("batch", None, "ffn"))
            assert spec == P("data", None, "model")

    def test_pod_axis_dropped_on_single_pod(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with sh.sharding_rules(mesh, None):
            # batch -> (pod, data); pod missing on this mesh
            assert sh.logical_to_spec(("batch",)) == P("data")

    def test_overrides(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with sh.sharding_rules(mesh, {"cache_seq": "data"}):
            assert sh.logical_to_spec(("cache_seq",)) == P("data")

    def test_no_mesh_noop(self):
        import jax.numpy as jnp

        x = jnp.ones((4, 4))
        assert sh.shard_hint(x, "batch", "ffn") is x

    def test_param_sharding_relaxes_indivisible(self):
        import jax.numpy as jnp

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with sh.sharding_rules(mesh, None):
            tree = {"w": jax.ShapeDtypeStruct((3, 4), jnp.float32)}
            axes = {"w": ("embed", "ffn")}
            out = sh.param_sharding_for(tree, axes, mesh)
            # dims divisible by 1 -> kept
            assert out["w"].spec == P("data", "model")


MULTIDEV_QGATHER = """
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.sharding import sharding_rules
from repro.distributed.qgather import binarize_gather
from repro.core.quantization import binarize_weights

mesh = jax.make_mesh((4, 2), ("data", "model"))
w = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
ws = jax.device_put(w, NamedSharding(mesh, P("data", "model")))

with sharding_rules(mesh, None):
    f = jax.jit(lambda w: binarize_gather(w, ("embed", "ffn")))
    out = f(ws)
    # value check: equals plain binarization
    ref, _ = binarize_weights(w)
    ok_val = bool(np.allclose(np.asarray(out), np.asarray(ref), rtol=1e-5))
    # gradient check: STE passthrough, resharded back
    g = jax.jit(jax.grad(lambda w: jnp.sum(binarize_gather(w, ("embed", "ffn")) * 3.0)))(ws)
    ok_grad = bool(np.isfinite(np.asarray(g)).all())
    # int8 payload in the HLO
    hlo = f.lower(ws).compile().as_text()
    ok_int8 = ("all-gather" in hlo and "s8[" in hlo)
print(json.dumps({"ok_val": ok_val, "ok_grad": ok_grad, "ok_int8": ok_int8}))
"""


MULTIDEV_COMPRESSION = """
import jax, jax.numpy as jnp, numpy as np, json, functools
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.distributed.compression import compress_psum

mesh = jax.make_mesh((8,), ("data",))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 0.1
res = jnp.zeros((8, 64))

@functools.partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                   out_specs=(P(), P("data")), check_rep=False)
def reduce_fn(g, r):
    mean, new_r = compress_psum(g[0], r[0], "data", chunk=16)
    return mean, new_r[None]

mean, new_res = reduce_fn(g, res)
true_mean = np.asarray(jnp.mean(g, axis=0))
err = np.abs(np.asarray(mean) - true_mean).max()
scale = np.abs(true_mean).max()
# error feedback: residual equals what was not transmitted
ok_res = bool(np.isfinite(np.asarray(new_res)).all())
print(json.dumps({"rel_err": float(err / (scale + 1e-9)), "ok_res": ok_res}))
"""


MULTIDEV_TRAIN = """
import jax, jax.numpy as jnp, numpy as np, json, dataclasses
from repro.configs.registry import get_config, reduced
from repro.distributed.sharding import sharding_rules, param_sharding_for
from repro.train.trainer import make_train_step, init_train_state
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(data=4, model=2)
cfg = reduced(get_config("pquant-300m"))
with sharding_rules(mesh, None):
    state, axes = init_train_state(jax.random.PRNGKey(0), cfg)
    st_sh = param_sharding_for(state, axes, mesh)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, cfg.vocab_size)}
    b_sh = param_sharding_for(batch, {"tokens": ("batch", None), "labels": ("batch", None)}, mesh)
    batch = jax.device_put(batch, b_sh)
    state = jax.device_put(state, st_sh)
    step = jax.jit(make_train_step(cfg, 10), in_shardings=(st_sh, b_sh))
    new_state, metrics = step(state, batch)
    # compare against single-device result
loss_sharded = float(metrics["loss"])
state1, _ = init_train_state(jax.random.PRNGKey(0), cfg)
batch1 = jax.tree.map(lambda x: jax.device_put(np.asarray(x)), batch)
step1 = jax.jit(make_train_step(cfg, 10))
_, m1 = step1(state1, batch1)
print(json.dumps({"sharded": loss_sharded, "single": float(m1["loss"])}))
"""


@pytest.mark.slow
class TestMultiDevice:
    def test_quantized_gather(self):
        out = run_subprocess(MULTIDEV_QGATHER)
        assert out["ok_val"] and out["ok_grad"] and out["ok_int8"]

    def test_gradient_compression_psum(self):
        out = run_subprocess(MULTIDEV_COMPRESSION)
        assert out["rel_err"] < 0.05 and out["ok_res"]

    def test_sharded_train_step_matches_single_device(self):
        out = run_subprocess(MULTIDEV_TRAIN)
        assert abs(out["sharded"] - out["single"]) / abs(out["single"]) < 1e-3
