"""Cache-consistency + serving-loop tests: prefill+decode must reproduce
the full forward pass for every mixer family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig
from repro.models import api
from repro.train.serve import BatchedServer, SamplerConfig, sample_token

KEY = jax.random.PRNGKey(1)
QC = QuantConfig(mode="pquant", r=16, num_experts=1)

CASES = {
    "dense": ModelConfig(name="t", family="decoder", n_layers=3, d_model=32,
                         n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64, quant=QC),
    "swa_global": ModelConfig(name="t2", family="decoder", n_layers=6, d_model=32,
                              n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64,
                              quant=QC, attn_type="swa", window_size=4,
                              global_every=3, rope_theta_local=1e3),
    "mla": ModelConfig(name="t3", family="decoder", n_layers=3, d_model=32,
                       n_heads=4, n_kv_heads=4, d_ff=48, vocab_size=64, quant=QC,
                       attn_type="mla", q_lora_rank=16, kv_lora_rank=8,
                       qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8),
    "ssm": ModelConfig(name="t4", family="ssm", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=64, quant=QC,
                       ssm_state=8, ssm_headdim=8, ssm_chunk=4, glu=False),
    "hybrid": ModelConfig(name="t5", family="hybrid", n_layers=5, d_model=32,
                          n_heads=4, n_kv_heads=1, d_ff=48, vocab_size=64,
                          quant=QC, block_pattern=("rec", "rec", "attn"),
                          lru_width=32, attn_type="swa", window_size=4),
    # capacity_factor high enough that no token drops: Switch-style capacity
    # depends on batch size, so prefill(T=10) vs forward(T=16) would
    # otherwise drop different tokens (expected semantics, not a bug)
    "moe": ModelConfig(name="t6", family="decoder", n_layers=3, d_model=32,
                       n_heads=4, n_kv_heads=4, d_ff=48, vocab_size=64, quant=QC,
                       moe=True, n_routed_experts=4, moe_top_k=2,
                       n_shared_experts=1, d_ff_expert=16, first_k_dense=1,
                       moe_capacity_factor=4.0),
}


@pytest.mark.parametrize("name", list(CASES))
def test_prefill_decode_matches_forward(name):
    cfg = CASES[name]
    params, _ = api.init_model(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    logits_full, _ = api.forward(params, {"tokens": toks}, cfg)
    lg, caches = api.prefill(params, {"tokens": toks[:, :5]}, cfg, cache_len=16)
    errs = [np.abs(np.asarray(lg) - np.asarray(logits_full[:, 4])).max()]
    for t in range(5, 8):
        lg, caches = api.decode_step(
            params, toks[:, t : t + 1], caches, jnp.asarray(t, jnp.int32), cfg
        )
        errs.append(np.abs(np.asarray(lg[:, 0]) - np.asarray(logits_full[:, t])).max())
    assert max(errs) < 2e-2, f"{name}: {errs}"


@pytest.mark.parametrize("name", list(CASES))
def test_forward_chunk_continues_from_cache(name):
    """``forward_chunk`` with T>1 from a NON-empty cache — the chunked-
    prefill primitive — matches the teacher-forced full forward for every
    mixer family: dense/paged span writes, the sequential ring path, the
    MLA latent spans, and the recurrent block-from-state forms."""
    cfg = CASES[name]
    params, _ = api.init_model(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    logits_full, _ = api.forward(params, {"tokens": toks}, cfg)
    _, caches = api.prefill(params, {"tokens": toks[:, :4]}, cfg,
                            cache_len=16)
    lg, caches = api.forward_chunk(
        params, toks[:, 4:8], caches, jnp.asarray(4, jnp.int32), cfg
    )
    assert lg.shape == (2, 4, cfg.vocab_size)
    err = np.abs(np.asarray(lg) - np.asarray(logits_full[:, 4:8])).max()
    assert err < 2e-2, f"{name}: {err}"
    # per-slot logits_at gather agrees with the full-chunk logits
    lg2, _ = api.forward_chunk(
        params, toks[:, 4:8],
        api.prefill(params, {"tokens": toks[:, :4]}, cfg, cache_len=16)[1],
        jnp.asarray(4, jnp.int32), cfg,
        logits_at=jnp.asarray([3, 3], jnp.int32),
    )
    np.testing.assert_allclose(
        np.asarray(lg2), np.asarray(lg[:, 3]), rtol=0, atol=1e-5
    )


class TestSampler:
    def test_greedy(self):
        logits = jnp.asarray([[0.0, 5.0, 1.0], [2.0, 0.0, -1.0]])
        tok = sample_token(KEY, logits, SamplerConfig(temperature=0.0))
        np.testing.assert_array_equal(np.asarray(tok), [1, 0])

    def test_top_k_restricts_support(self):
        logits = jnp.asarray([[10.0, 5.0, -20.0, -20.0]])
        for seed in range(10):
            tok = sample_token(
                jax.random.PRNGKey(seed), logits,
                SamplerConfig(temperature=1.0, top_k=2),
            )
            assert int(tok[0]) in (0, 1)


def test_batched_server_generates():
    cfg = CASES["dense"]
    params, _ = api.init_model(KEY, cfg)
    server = BatchedServer(params, cfg, max_len=32)
    prompts = jax.random.randint(KEY, (3, 6), 0, cfg.vocab_size)
    out = server.generate(prompts, SamplerConfig(max_new_tokens=5, temperature=0.7))
    assert out.shape == (3, 5)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
