"""Request-lifecycle hardening: typed admission errors, deadline / TTFT
enforcement (queued, mid-decode and mid-chunked-prefill), bounded-queue
load shedding under both overload policies, the pool-full admission wait
path, NaN/Inf logit quarantine (prefill and decode), and the no-progress
watchdog."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.quantization import QuantConfig
from repro.models import api
from repro.serve.engine import DecodeEngine, SamplerConfig
from repro.serve.faults import AllocFailure, FaultInjector, PoisonLogits
from repro.serve.scheduler import (
    FINISH_REASONS,
    ContinuousBatchingEngine,
    InadmissibleRequest,
    SchedulerStall,
)

KEY = jax.random.PRNGKey(1)
QC = QuantConfig(mode="pquant", r=16, num_experts=1)
CFG = ModelConfig(name="t", family="decoder", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64, quant=QC)
SWA_CFG = ModelConfig(name="t2", family="decoder", n_layers=6, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=48, vocab_size=64,
                      quant=QC, attn_type="swa", window_size=4,
                      global_every=3, rope_theta_local=1e3)
MAX_LEN = 32
SCFG = SamplerConfig(temperature=0.7, top_k=10, max_new_tokens=6)


@pytest.fixture(scope="module")
def params():
    return api.init_model(KEY, CFG)[0]


@pytest.fixture(scope="module")
def reference(params):
    return DecodeEngine(params, CFG, MAX_LEN)


def _prompt(seed, n):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, 64), np.int32
    )


def _oracle(reference, prompt, budget, seed):
    scfg = dataclasses.replace(SCFG, max_new_tokens=budget)
    return reference.generate(jnp.asarray(prompt[None]), scfg, seed=seed)[0]


# ---------------------------------------------------------------------------
# typed admission errors (no compile: rejected before any jit runs)
# ---------------------------------------------------------------------------


class TestInadmissibleRequest:
    def test_slot_capacity(self, params):
        eng = ContinuousBatchingEngine(
            params, CFG, num_slots=1, max_len=MAX_LEN, scfg=SCFG,
            layout="dense", chunk=4,
        )
        with pytest.raises(InadmissibleRequest, match="slot capacity"):
            eng.submit(_prompt(0, 30), max_new_tokens=10)
        # subclasses ValueError: callers catching the old type still work
        assert issubclass(InadmissibleRequest, ValueError)

    def test_pool_capacity(self, params):
        eng = ContinuousBatchingEngine(
            params, CFG, num_slots=2, max_len=MAX_LEN, scfg=SCFG,
            layout="paged", block_size=8, num_blocks=1, chunk=4,
        )
        with pytest.raises(InadmissibleRequest, match="pool has only"):
            eng.submit(_prompt(0, 10), max_new_tokens=4)

    def test_dead_on_arrival_is_rejected_not_raised(self, params):
        """A deadline unmeetable at submit is a *request* outcome
        (reason "rejected"), not an API error — the request still
        finishes exactly once, with zero tokens, via the next step."""
        eng = ContinuousBatchingEngine(
            params, CFG, num_slots=1, max_len=MAX_LEN, scfg=SCFG,
            layout="dense", chunk=4,
        )
        uid = eng.submit(_prompt(0, 4), max_new_tokens=4, arrival=5.0,
                         deadline=5.0)
        uid2 = eng.submit(_prompt(1, 4), max_new_tokens=4, ttft_budget=0.0)
        finished = eng.run()
        assert sorted(f.uid for f in finished) == sorted([uid, uid2])
        for f in finished:
            assert f.finish_reason == "rejected"
            assert len(f.tokens) == 0
        assert eng.rejected_requests == 2


# ---------------------------------------------------------------------------
# bounded queue / load shedding
# ---------------------------------------------------------------------------


def test_bounded_queue_reject_policy(params, reference):
    """Queue bound 2, policy "reject": the third concurrent submit is shed
    with zero tokens, the two queued requests run to their unchanged
    streams, and every request finishes exactly once with a valid
    reason."""
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=1, max_len=MAX_LEN, scfg=SCFG,
        layout="paged", block_size=8, chunk=4,
        max_queue=2, overload_policy="reject",
    )
    for uid in (0, 1, 2):
        eng.submit(_prompt(uid + 10, 4), max_new_tokens=6, seed=uid, uid=uid)
    finished = eng.run()
    by_uid = {f.uid: f for f in finished}
    assert sorted(by_uid) == [0, 1, 2]
    assert by_uid[2].finish_reason == "shed"
    assert len(by_uid[2].tokens) == 0
    for uid in (0, 1):
        assert by_uid[uid].finish_reason in FINISH_REASONS
        np.testing.assert_array_equal(
            by_uid[uid].tokens, _oracle(reference, _prompt(uid + 10, 4), 6, uid)
        )
    assert eng.shed_requests == 1 and eng.queue_peak == 2
    assert eng.allocator.free_count == eng.num_blocks


def test_bounded_queue_shed_oldest_policy(params, reference):
    """Policy "shed_oldest": the head of the queue is dropped to make room
    (freshest-work-wins); the survivor's stream is bit-for-bit the
    fault-free one."""
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=1, max_len=MAX_LEN, scfg=SCFG,
        layout="dense", chunk=4,
        max_queue=1, overload_policy="shed_oldest",
    )
    eng.submit(_prompt(10, 4), max_new_tokens=6, seed=0, uid=0)
    eng.submit(_prompt(11, 3), max_new_tokens=6, seed=1, uid=1)
    finished = eng.run()
    by_uid = {f.uid: f for f in finished}
    assert by_uid[0].finish_reason == "shed" and len(by_uid[0].tokens) == 0
    np.testing.assert_array_equal(
        by_uid[1].tokens, _oracle(reference, _prompt(11, 3), 6, 1)
    )
    assert eng.shed_requests == 1


def test_overload_policy_validated(params):
    with pytest.raises(ValueError, match="overload policy"):
        ContinuousBatchingEngine(
            params, CFG, num_slots=1, max_len=MAX_LEN, scfg=SCFG,
            overload_policy="drop_all",
        )
    with pytest.raises(ValueError, match="max_queue"):
        ContinuousBatchingEngine(
            params, CFG, num_slots=1, max_len=MAX_LEN, scfg=SCFG,
            max_queue=0,
        )


# ---------------------------------------------------------------------------
# deadlines / TTFT budgets
# ---------------------------------------------------------------------------


def test_deadline_evicts_mid_decode_with_prefix_stream(params, reference):
    """A live request whose deadline passes at a chunk boundary is evicted
    with reason "deadline"; its partial tokens are a strict prefix of the
    fault-free stream and its blocks are reclaimed."""
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=1, max_len=MAX_LEN, scfg=SCFG,
        layout="paged", block_size=8, chunk=4,
    )
    eng.submit(_prompt(10, 5), max_new_tokens=12, seed=0, uid=0,
               deadline=1.5)
    (f,) = eng.run()
    assert f.finish_reason == "deadline"
    full = _oracle(reference, _prompt(10, 5), 12, 0)
    assert 0 < len(f.tokens) < len(full)
    np.testing.assert_array_equal(f.tokens, full[: len(f.tokens)])
    assert eng.deadline_misses == 1
    assert eng.allocator.free_count == eng.num_blocks


def test_ttft_budget_expires_in_queue(params, reference):
    """A queued request whose TTFT budget lapses before a slot frees
    finishes "deadline" with zero tokens; the running request is
    untouched."""
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=1, max_len=MAX_LEN, scfg=SCFG,
        layout="dense", chunk=4,
    )
    eng.submit(_prompt(10, 5), max_new_tokens=8, seed=0, uid=0)
    eng.submit(_prompt(11, 4), max_new_tokens=8, seed=1, uid=1,
               ttft_budget=1.0)
    finished = eng.run()
    by_uid = {f.uid: f for f in finished}
    assert by_uid[1].finish_reason == "deadline"
    assert len(by_uid[1].tokens) == 0
    assert by_uid[1].first_token_at == by_uid[1].finished_at
    np.testing.assert_array_equal(
        by_uid[0].tokens, _oracle(reference, _prompt(10, 5), 8, 0)
    )


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_deadline_expiry_during_chunked_admission_prefill(params, layout):
    """The satellite case: a request evicted while its prompt is still
    streaming in (prefilled < prompt_len).  The mid-prefill slot must be
    vacated and — under the paged layout — its prompt blocks reclaimed at
    the expiry step, not at some later finish."""
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=SCFG,
        layout=layout, block_size=8, chunk=4, prefill_chunk=2,
    )
    eng.submit(_prompt(10, 7), max_new_tokens=4, seed=0, uid=0,
               deadline=1.5)
    finished = list(eng.step())  # slice 1 of 4: occupies slot + 1 block
    (rs,) = eng._live()
    assert 0 < rs.prefilled < 7 and rs.n_generated == 0
    if layout == "paged":
        assert eng.allocator.free_count == eng.num_blocks - 1
    finished += eng.step()  # slice 2; clock passes the deadline
    finished += eng.step()  # expiry fires at the chunk boundary
    assert [f.finish_reason for f in finished] == ["deadline"]
    assert len(finished[0].tokens) == 0
    assert eng._live() == []
    if layout == "paged":
        # mid-prefill reclamation: the blocks came back at expiry
        assert eng.allocator.free_count == eng.num_blocks
    assert not eng.run()  # nothing left; the finish happened exactly once


# ---------------------------------------------------------------------------
# pool-full admission path ("wait for evictions")
# ---------------------------------------------------------------------------


def test_pool_full_admission_waits_for_evictions(params, reference):
    """With a free slot but an exhausted pool, admission WAITS (requeue at
    head) instead of preempting the pool's owner; the waiter admits after
    the eviction and still produces its exact stream."""
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=SCFG,
        layout="paged", block_size=8, num_blocks=3, chunk=4,
    )
    eng.submit(_prompt(10, 9), max_new_tokens=8, seed=0, uid=0)
    eng.submit(_prompt(11, 9), max_new_tokens=4, seed=1, uid=1)
    finished = eng.run()
    assert [f.uid for f in finished] == [0, 1]  # 1 admitted only after 0
    assert eng.preemptions == 0  # waited, never preempted the owner
    np.testing.assert_array_equal(
        finished[0].tokens, _oracle(reference, _prompt(10, 9), 8, 0)
    )
    np.testing.assert_array_equal(
        finished[1].tokens, _oracle(reference, _prompt(11, 9), 4, 1)
    )
    assert eng.allocator.free_count == 3


# ---------------------------------------------------------------------------
# NaN/Inf quarantine
# ---------------------------------------------------------------------------


def test_decode_poison_quarantines_only_the_poisoned_stream(
    params, reference
):
    """An injected non-finite logit step finishes that request with reason
    "error" carrying exactly its pre-poison prefix, while the other live
    stream is bit-for-bit the fault-free run."""
    inj = FaultInjector([PoisonLogits(uid=0, gen_index=3)])
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=2, max_len=MAX_LEN, scfg=SCFG,
        layout="paged", block_size=8, chunk=4, faults=inj,
    )
    eng.submit(_prompt(10, 5), max_new_tokens=6, seed=0, uid=0)
    eng.submit(_prompt(11, 4), max_new_tokens=6, seed=1, uid=1)
    finished = eng.run()
    by_uid = {f.uid: f for f in finished}
    assert by_uid[0].finish_reason == "error"
    full = _oracle(reference, _prompt(10, 5), 6, 0)
    assert len(by_uid[0].tokens) == 3  # gen indices 0..2 survive
    np.testing.assert_array_equal(by_uid[0].tokens, full[:3])
    np.testing.assert_array_equal(
        by_uid[1].tokens, _oracle(reference, _prompt(11, 4), 6, 1)
    )
    assert eng.quarantined == 1
    assert inj.injected["poison_logits"] == 1
    assert eng.allocator.free_count == eng.num_blocks


@pytest.mark.parametrize(
    "cfg,prefill_chunk",
    [(CFG, None), (CFG, 3), (SWA_CFG, None)],
    ids=["bucketed", "chunked", "exact"],
)
def test_prefill_poison_quarantines_at_admission(cfg, prefill_chunk):
    """Non-finite logits at admission prefill (a poisoned embedding row)
    finish the request "error" with zero tokens and reclaim its blocks —
    on all three admission paths (bucketed one-shot, chunked slices, and
    exact-length one-shot for ring-cache configs)."""
    p = api.init_model(KEY, cfg)[0]
    bad_tok = 63
    p = dict(p, embed={"table": p["embed"]["table"].at[bad_tok].set(
        jnp.nan)})
    eng = ContinuousBatchingEngine(
        p, cfg, num_slots=1, max_len=24, scfg=SCFG,
        layout="paged", block_size=8, chunk=4, prefill_chunk=prefill_chunk,
    )
    prompt = np.asarray([1, 2, bad_tok, 3, 4], np.int32)
    eng.submit(prompt, max_new_tokens=4, seed=0, uid=0)
    (f,) = eng.run()
    assert f.finish_reason == "error"
    assert len(f.tokens) == 0
    assert eng.quarantined == 1
    assert eng.allocator.free_count == eng.num_blocks
    assert eng._live() == []


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------


def test_watchdog_raises_diagnosable_stall(params):
    """An admission that can never proceed (every alloc call failing) must
    raise SchedulerStall with the queue depth and allocator state in the
    message — not spin forever."""
    inj = FaultInjector([AllocFailure(i) for i in range(64)])
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=1, max_len=MAX_LEN, scfg=SCFG,
        layout="paged", block_size=8, chunk=4,
        watchdog_steps=4, faults=inj,
    )
    eng.submit(_prompt(10, 4), max_new_tokens=4, seed=0, uid=0)
    with pytest.raises(SchedulerStall, match="queue depth 1"):
        eng.run()
    assert issubclass(SchedulerStall, RuntimeError)


def test_watchdog_tolerates_idle_waiting(params):
    """No-progress steps while nothing has arrived are NOT a stall: the
    virtual clock advances to the next arrival and the request is still
    served."""
    eng = ContinuousBatchingEngine(
        params, CFG, num_slots=1, max_len=MAX_LEN, scfg=SCFG,
        layout="dense", chunk=4, watchdog_steps=2,
    )
    eng.submit(_prompt(10, 4), max_new_tokens=4, seed=0, uid=0,
               arrival=100.0)
    finished = eng.run()
    assert [f.finish_reason for f in finished] == ["length"]
