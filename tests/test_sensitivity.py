"""Sensitivity analysis / parameter-democratization metric tests (paper
§2.3, Figures 2 & 5a)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import binarize_weights
from repro.core.sensitivity import (
    democratization_score,
    input_hessian,
    max_pool_2d,
    obs_sensitivity,
    sensitivity_kurtosis,
    top_fraction_mass,
)

KEY = jax.random.PRNGKey(0)


class TestOBS:
    def test_shapes(self):
        w = jax.random.normal(KEY, (32, 16))
        x = jax.random.normal(KEY, (128, 32))
        s = obs_sensitivity(w, x)
        assert s.shape == w.shape
        assert (np.asarray(s) >= 0).all()

    def test_larger_weight_more_sensitive(self):
        """With isotropic inputs, sensitivity ~ w^2."""
        x = jax.random.normal(KEY, (4096, 16))
        w = jnp.zeros((16, 4)).at[0, 0].set(5.0).at[1, 1].set(0.1)
        s = np.asarray(obs_sensitivity(w, x))
        assert s[0, 0] > s[1, 1] * 100

    def test_hessian_dampened_invertible(self):
        # rank-deficient inputs still produce a usable Hessian
        x = jnp.ones((64, 8))
        h = input_hessian(x)
        assert np.isfinite(np.linalg.inv(np.asarray(h))).all()


class TestDemocratization:
    def test_uniform_vs_peaked(self):
        uniform = jnp.ones((64, 64))
        peaked = jnp.ones((64, 64)).at[0, 0].set(1e6)
        assert float(democratization_score(uniform)) > 0.999
        assert float(democratization_score(peaked)) < 0.5

    def test_1bit_weights_are_democratized(self):
        """The paper's core observation: binarized weights flatten the
        sensitivity landscape vs their FP latents."""
        w = jax.random.normal(KEY, (64, 32)) * jnp.exp(
            jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        )  # heavy-tailed FP weights
        x = jax.random.normal(KEY, (512, 64))
        s_fp = democratization_score(obs_sensitivity(w, x))
        wq, _ = binarize_weights(w)
        s_1b = democratization_score(obs_sensitivity(wq, x))
        assert float(s_1b) > float(s_fp)

    def test_top_fraction_mass(self):
        peaked = jnp.ones((100, 10)).at[0, 0].set(1e6)
        assert float(top_fraction_mass(peaked, 0.01)) > 0.9
        assert float(top_fraction_mass(jnp.ones((100, 10)), 0.01)) < 0.05

    def test_kurtosis_differentiates(self):
        rng = jax.random.PRNGKey(2)
        # minority of extreme outliers -> heavy-tailed log-sensitivity
        heavy = jnp.ones((64, 64)).at[:2].set(1e8)
        flat = jnp.ones((64, 64)) + 0.01 * jax.random.normal(rng, (64, 64))
        assert float(sensitivity_kurtosis(heavy)) > float(sensitivity_kurtosis(flat))

    def test_one_hot_limit(self):
        """All mass on a single weight: entropy -> 0, so the score hits
        the differentiated-landscape floor (the uniform limit is the
        other invariant, test_uniform_vs_peaked)."""
        one_hot = jnp.zeros((64, 64)).at[0, 0].set(1.0)
        assert float(democratization_score(one_hot)) < 0.01
        assert float(top_fraction_mass(one_hot, 0.01)) > 0.999

    def test_monotone_in_concentration(self):
        """Shrinking the outlier population (same total spike magnitude
        class) must move every statistic the same way: score up toward
        democratized, top-1% mass down, log-kurtosis down — the three
        views agree on the concentration ordering."""
        def spiked(k):
            return jnp.ones(4096).at[:k].set(1e6)

        pops = [spiked(k) for k in (4, 64, 512)]
        scores = [float(democratization_score(s)) for s in pops]
        top1 = [float(top_fraction_mass(s, 0.01)) for s in pops]
        kurt = [float(sensitivity_kurtosis(s)) for s in pops]
        assert scores == sorted(scores), scores
        assert top1 == sorted(top1, reverse=True), top1
        assert kurt == sorted(kurt, reverse=True), kurt


def test_max_pool_vis():
    s = jnp.arange(64.0).reshape(8, 8)
    p = max_pool_2d(s, (2, 2))
    assert p.shape == (2, 2)
    assert float(p[1, 1]) == 63.0


def test_max_pool_shapes_and_idempotence():
    s = jnp.arange(64.0).reshape(8, 8)
    p = max_pool_2d(s, (4, 4))
    assert p.shape == (4, 4)
    # pooling to the input's own shape is the identity...
    np.testing.assert_array_equal(np.asarray(max_pool_2d(p, (4, 4))),
                                  np.asarray(p))
    # ...and pooling to (1, 1) is the global max
    assert float(max_pool_2d(s, (1, 1))[0, 0]) == 63.0
