"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates a REDUCED same-family config and runs one forward/train
step on CPU asserting output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import param_count, shapes_for
from repro.configs.registry import ARCHS, ASSIGNED, get_config, reduced
from repro.models import api
from repro.train.trainer import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    batch = {
        "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.n_frontend_tokens, cfg.d_model)
        )
    if cfg.n_image_tokens:
        batch["image_embeds"] = jax.random.normal(
            KEY, (b, cfg.n_image_tokens, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    params, axes = api.init_model(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = api.forward(params, batch, cfg)
    b, s = batch["tokens"].shape
    s_total = s + (cfg.n_image_tokens if "image_embeds" in batch else 0)
    assert logits.shape == (b, s_total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step(arch):
    cfg = reduced(get_config(arch))
    state, _ = init_train_state(KEY, cfg)
    # advance the schedule past warmup step 0 (lr(0) == 0 by design)
    state = state._replace(opt=state.opt._replace(step=jnp.asarray(5, jnp.int32)))
    step = jax.jit(make_train_step(cfg, total_steps=10))
    new_state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum()),
        state.params, new_state.params,
    )
    assert sum(jax.tree.leaves(moved)) > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_step(arch):
    cfg = reduced(get_config(arch))
    params, _ = api.init_model(KEY, cfg)
    batch = {k: v for k, v in _batch(cfg).items() if k != "labels"}
    logits, caches = api.prefill(params, batch, cfg, cache_len=32)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.asarray(batch["tokens"].shape[1] + (cfg.n_image_tokens if "image_embeds" in batch else 0), jnp.int32)
    logits2, _ = api.decode_step(params, tok, caches, pos, cfg)
    assert logits2.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ASSIGNED)
def test_quant_mode_variants(arch):
    """Every arch supports all four quantization modes (baselines incl.)."""
    for mode in ("none", "bitnet", "bitnet158"):
        cfg = reduced(get_config(arch, quant_mode=mode))
        params, _ = api.init_model(KEY, cfg)
        logits, _ = api.forward(params, _batch(cfg), cfg)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), (arch, mode)


def test_cell_enumeration_matches_assignment():
    """40 cells total; long_500k skipped for the 6 pure-full-attention archs."""
    total = sum(len(shapes_for(get_config(a))) for a in ASSIGNED)
    assert total == 34  # 40 - 6 documented skips
    skipped = [a for a in ASSIGNED if len(shapes_for(get_config(a))) == 3]
    assert sorted(skipped) == sorted([
        "granite-20b", "deepseek-coder-33b", "whisper-large-v3",
        "deepseek-v2-236b", "deepseek-moe-16b", "phi-3-vision-4.2b",
    ])


@pytest.mark.parametrize(
    "arch,expect_b",
    [("granite-20b", 20.8), ("gemma3-27b", 28.0), ("deepseek-v2-236b", 236.0),
     ("mamba2-780m", 0.78), ("deepseek-moe-16b", 16.4)],
)
def test_full_param_counts(arch, expect_b):
    pc = param_count(get_config(arch))
    assert abs(pc["total"] / 1e9 - expect_b) / expect_b < 0.08


def test_pquant_paper_sizes():
    for name, expect in [("pquant-300m", 0.31), ("pquant-700m", 0.73),
                         ("pquant-1.3b", 1.27), ("pquant-2.6b", 2.48)]:
        pc = param_count(get_config(name))
        assert abs(pc["total"] / 1e9 - expect) < 0.12, name
