"""Tier-1 smoke for the serving benchmark: the whole lockstep-vs-continuous
comparison runs (CPU, tiny config, short Poisson trace) and reports
throughput + latency percentiles for both paths."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def test_bench_serving_smoke(capsys):
    from benchmarks import bench_serving

    rows = bench_serving.run(smoke=True, n_requests=4)
    names = [r.split(",")[0] for r in rows]
    assert "serving/lockstep" in names
    assert "serving/continuous" in names
    assert "serving/pool" in names
    by_name = dict(zip(names, rows))
    # both paths report tokens/sec and latency percentiles
    for name in ("serving/lockstep", "serving/continuous"):
        assert "tok_s=" in by_name[name]
        assert "p50_ms=" in by_name[name] and "p95_ms=" in by_name[name]
    # the paged pool leaks no blocks over the trace
    derived = by_name["serving/pool"].split(",", 2)[2]
    fields = dict(kv.split("=") for kv in derived.split(";"))
    assert fields["blocks"] == fields["free"]


def test_trace_is_deterministic_per_seed():
    from benchmarks import bench_serving

    a = bench_serving.make_trace(5, 3, 0.01, (4, 6), (4, 8))
    b = bench_serving.make_trace(5, 3, 0.01, (4, 6), (4, 8))
    assert [r["arrival"] for r in a] == [r["arrival"] for r in b]
    assert all((x["prompt"] == y["prompt"]).all() for x, y in zip(a, b))
