"""Tier-1 smoke for the serving benchmark: the whole lockstep-vs-continuous
comparison runs (CPU, tiny config, short Poisson trace) and reports
throughput + latency percentiles for both paths."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def test_bench_serving_smoke(capsys, tmp_path):
    import json

    from benchmarks import bench_serving
    from repro.serve.metrics import validate_snapshot

    metrics_out = tmp_path / "metrics.json"
    trace_out = tmp_path / "trace.jsonl"
    rows = bench_serving.run(smoke=True, n_requests=4,
                             metrics_out=str(metrics_out),
                             trace_out=str(trace_out))
    # the telemetry artifacts CI archives next to BENCH_serving.json:
    # a schema-valid engine metrics snapshot sourcing the row numbers...
    snap = json.loads(metrics_out.read_text())
    validate_snapshot(snap)
    assert snap["counters"]["requests_submitted_total"] == 4
    fin = [v for k, v in snap["counters"].items()
           if k.startswith("requests_finished_total{")]
    assert sum(fin) == 4  # conservation, straight from the artifact
    assert snap["histograms"]["ttft_seconds"]["count"] > 0
    # ...and the request lifecycle trace (post-warm: the timed run only)
    evs = [json.loads(line) for line in trace_out.read_text().splitlines()]
    kinds = {e["event"] for e in evs}
    assert {"submitted", "admitted", "first_token", "finished"} <= kinds
    assert sum(e["event"] == "submitted" for e in evs) == 4
    names = [r.split(",")[0] for r in rows]
    assert "serving/lockstep" in names
    assert "serving/continuous" in names
    assert "serving/continuous_chunked" in names
    assert "serving/pool" in names
    by_name = dict(zip(names, rows))
    # every serving tier reports tokens/sec, latency percentiles, TTFT
    # percentiles and inter-token p95 (the chunked-prefill story)
    for name in ("serving/lockstep", "serving/continuous",
                 "serving/continuous_chunked"):
        assert "tok_s=" in by_name[name]
        assert "p50_ms=" in by_name[name] and "p95_ms=" in by_name[name]
        assert "ttft_p50_ms=" in by_name[name]
        assert "ttft_p95_ms=" in by_name[name]
        assert "itl_p95_ms=" in by_name[name]
    assert "prefill_chunk=" in by_name["serving/continuous_chunked"]
    assert "itl_p95_vs_continuous=" in by_name["serving/continuous_chunked"]
    # the paged pool leaks no blocks over the trace
    derived = by_name["serving/pool"].split(",", 2)[2]
    fields = dict(kv.split("=") for kv in derived.split(";"))
    assert fields["blocks"] == fields["free"]
    # overload row: graceful-degradation stats under 2x-capacity load
    assert "serving/overload" in names
    ofields = dict(
        kv.split("=")
        for kv in by_name["serving/overload"].split(",", 2)[2].split(";")
    )
    assert {"tok_s", "shed_rate", "deadline_miss_rate",
            "served_rate"} <= set(ofields)
    for k in ("shed_rate", "deadline_miss_rate", "served_rate"):
        assert 0.0 <= float(ofields[k]) <= 1.0
    # the overload run leaks no pool blocks either
    free, total = ofields["free_blocks"].split("/")
    assert free == total
    # long-context read-path comparison: both paths report decode tok/s,
    # the kernel row carries the ratio, and greedy streams agree between
    # the Pallas kernel and the gather+SDPA fallback
    assert "serving/paged_long_gather" in names
    assert "serving/paged_long_kernel" in names
    for name in ("serving/paged_long_gather", "serving/paged_long_kernel"):
        assert "decode_tok_s=" in by_name[name]
        assert "ttft_p50_ms=" in by_name[name]
        assert "itl_p95_ms=" in by_name[name]
    kfields = dict(
        kv.split("=")
        for kv in by_name["serving/paged_long_kernel"].split(",", 2)[2].split(";")
    )
    assert "kernel_vs_gather" in kfields
    # the parity flag is reported; bit-level greedy-stream equality is
    # asserted by the dedicated CB parity suite (the kernel is documented
    # as allclose-at-f32, so the bench smoke only requires the flag)
    assert kfields["streams_match"] in ("0", "1")
    # prefix caching: the warm pass over a shared system prompt must cut
    # admission work (virtual-tick TTFT p50) by >= 3x, hit the cache, and
    # stay bit-for-bit with the no-cache engine on both admission paths —
    # with zero leaked blocks despite the warm LRU
    assert "serving/prefix_cache" in names
    pfields = dict(
        kv.split("=")
        for kv in by_name["serving/prefix_cache"].split(",", 2)[2].split(";")
    )
    assert float(pfields["warm_speedup"].rstrip("x")) >= 3.0
    assert float(pfields["hit_rate"]) > 0.0
    assert pfields["streams_match_oneshot"] == "1"
    assert pfields["streams_match_chunked"] == "1"
    assert pfields["leaked"] == "0"
    # the archived metrics artifact is schema-stable: the prefix-cache
    # counters ride along even for engines that never enable the cache
    for name in ("prefix_cache_hits_total", "prefix_cache_misses_total",
                 "prefix_cache_cow_total", "prefix_cache_hit_tokens_total"):
        assert name in snap["counters"]


def test_run_py_writes_serving_artifact(tmp_path, monkeypatch):
    """`benchmarks/run.py --smoke` writes the BENCH_serving.json artifact
    CI uploads — the per-PR perf trajectory record."""
    import json
    import subprocess
    import sys

    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(PYTHONPATH=str(root / "src"), PATH="/usr/bin:/bin",
               HOME=str(tmp_path))
    out = tmp_path / "BENCH_serving.json"
    # --only memory keeps it seconds-scale: the artifact plumbing is what
    # is under test, not the serving numbers
    r = subprocess.run(
        [sys.executable, str(root / "benchmarks" / "run.py"), "--smoke",
         "--only", "memory", "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=300,
    )
    assert r.returncode == 0, r.stderr
    payload = json.loads(out.read_text())
    assert payload["smoke"] is True
    # the numbers survive — dict-returning suites keep their structure
    mem = payload["suites"]["memory"]["rows"]
    assert isinstance(mem, dict) and mem["300m"], mem


def test_trace_is_deterministic_per_seed():
    from benchmarks import bench_serving

    a = bench_serving.make_trace(5, 3, 0.01, (4, 6), (4, 8))
    b = bench_serving.make_trace(5, 3, 0.01, (4, 6), (4, 8))
    assert [r["arrival"] for r in a] == [r["arrival"] for r in b]
    assert all((x["prompt"] == y["prompt"]).all() for x, y in zip(a, b))
